"""Batched serving example: continuous batching over fixed decode slots.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-7b]
"""
import argparse
import time

import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import BatchedServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    mesh = make_host_mesh()
    server = BatchedServer(cfg, mesh, batch_slots=args.slots, max_len=256)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.generated) for r in reqs)
    print(f"[serve_lm] {args.arch}(reduced): {len(reqs)} requests x "
          f"{args.max_new} tokens on {args.slots} slots")
    print(f"[serve_lm] {total} tokens in {dt:.2f}s = {total/dt:.1f} tok/s "
          f"(CPU, interpret-grade)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt[:4]}... -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
