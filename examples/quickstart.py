"""Quickstart: the paper's workflow end to end, in five minutes on a CPU.

1. Faithful layer — estimate an FPGA kernel's execution time from its LSU
   structure (Eqs. 1-10) and compare against the DRAM-simulator oracle.
2. TPU layer — lower a small training step, *without running it*, classify
   its memory traffic, and predict the step time / bottleneck.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import Design, Session
from repro.core import DDR4_1866, LsuType
from repro.core.dramsim import simulate

SESSION = Session(dram=DDR4_1866)


def faithful_demo() -> None:
    print("=" * 64)
    print("1. Faithful FPGA model (paper Eqs. 1-10)")
    print("=" * 64)
    for n_ga in (1, 2, 4):
        design = Design.microbench(LsuType.BC_ALIGNED, n_ga=n_ga, simd=16,
                                   n_elems=1 << 20)
        est = SESSION.estimate(design)
        sim = simulate(list(design.lsus), DDR4_1866)
        print(f"  sum-reduction #ga={n_ga}: "
              f"T_est={est.t_exe*1e3:6.3f} ms  T_sim={sim.t_total*1e3:6.3f} ms  "
              f"bw={est.effective_bandwidth/1e9:5.2f} GB/s  "
              f"memory_bound={est.memory_bound}")
    print("  -> the 14.9 -> 10.7 GB/s bandwidth drop with #lsu is the "
          "paper's Fig. 4a result.\n")


def tpu_demo() -> None:
    print("=" * 64)
    print("2. TPU adaptation: predict a training step before running it")
    print("=" * 64)
    from repro.configs import ARCHS, reduced_config
    from repro.configs.shapes import ShapeSpec
    from repro.core import hlo as HLO
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainConfig, build_step

    cfg = reduced_config(ARCHS["qwen2-7b"])
    mesh = make_host_mesh()
    built = build_step(cfg, ShapeSpec("demo", 128, 4, "train"), mesh,
                       TrainConfig())
    compiled = built.fn.lower(*built.args).compile()   # seconds, no TPU
    pred = SESSION.predict(compiled.as_text(),
                           HLO.cost_analysis_stats(compiled))
    print(f"  arch: {cfg.name} (reduced), mesh: {mesh.devices.shape}")
    print(f"  FLOPs/step:      {pred.flops:.3g}")
    print(f"  HBM bytes/step:  {pred.hbm_bytes:.3g}")
    for c in pred.memory_components:
        print(f"    {c.name:10s} {c.nbytes:12.3g} B")
    print(f"  t_compute={pred.t_compute*1e6:8.1f} us  "
          f"t_memory={pred.t_memory*1e6:8.1f} us  "
          f"t_collective={pred.t_collective*1e6:8.1f} us")
    print(f"  bottleneck: {pred.bottleneck}  "
          f"(arithmetic intensity {pred.arithmetic_intensity:.1f} FLOP/B, "
          f"v5e ridge ~241)")


if __name__ == "__main__":
    faithful_demo()
    tpu_demo()
