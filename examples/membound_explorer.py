"""Classify any jitted JAX function as memory- vs compute- vs collective-
bound without running it — the Eq. 3 criterion transplanted to XLA.

Demonstrates the membench Pallas kernels (the paper's Listing-4
microbenchmarks on TPU): contiguous streaming, strided, and data-dependent
gather — and shows how the access-class split moves between them.

Run:  PYTHONPATH=src python examples/membound_explorer.py
"""
import jax
import jax.numpy as jnp

from repro.core import hlo as HLO
from repro.core.predictor import predict


def explain(name: str, fn, *specs) -> None:
    compiled = jax.jit(fn).lower(*specs).compile()
    pred = predict(compiled.as_text(), HLO.cost_analysis_stats(compiled))
    classes = {c.name: c.nbytes for c in pred.memory_components}
    print(f"{name:28s} AI={pred.arithmetic_intensity:8.2f} FLOP/B  "
          f"bound={pred.bottleneck:9s} classes="
          + ", ".join(f"{k}:{v:.2g}B" for k, v in classes.items()))


def main() -> None:
    n = 1 << 20
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    m = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    idx = jax.ShapeDtypeStruct((n,), jnp.int32)

    print("TPU Eq.-3 analogue: arithmetic intensity vs the v5e ridge "
          "(197 TF/s / 819 GB/s ~ 241 FLOP/B)\n")
    explain("sum reduction (stream)", lambda a, b: (a + b).sum(), x, x)
    explain("strided sum", lambda a: a.reshape(-1, 4)[:, 0].sum(), x)
    explain("gather sum (write-ACK)", lambda a, i: a[i % n].sum(), x, idx)
    explain("matmul 1k (compute)", lambda a: (a @ a).sum(), m)
    explain("matmul chain x8",
            lambda a: jax.lax.fori_loop(0, 8, lambda _, y: y @ a, a).sum(), m)

    print("\nPallas membench kernels (interpret mode) — same taxonomy, "
          "kernel-level:")
    from repro.kernels.membench import ops as MB
    xs = tuple(jax.random.normal(jax.random.PRNGKey(i), (1 << 16,))
               for i in range(3))
    out = MB.aligned_sum(xs, block=2048)
    print(f"  aligned_sum   -> {out.shape}, {out.dtype}")
    out = MB.strided_sum(xs, delta=4, block=512)
    print(f"  strided_sum   -> {out.shape} (delta=4: 4x the fetched bytes)")
    i = jax.random.randint(jax.random.PRNGKey(9), (16,), 0, (1 << 16) // 512)
    out = MB.gather_sum(xs, i, block=512)
    print(f"  gather_sum    -> {out.shape} (block indirection via scalar "
          f"prefetch)")


if __name__ == "__main__":
    main()
