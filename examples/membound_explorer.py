"""Classify any jitted JAX function as memory- vs compute- vs collective-
bound without running it — the Eq. 3 criterion transplanted to XLA — and
sweep the paper's FPGA design space at exploration scale.

Demonstrates the membench Pallas kernels (the paper's Listing-4
microbenchmarks on TPU): contiguous streaming, strided, and data-dependent
gather — and shows how the access-class split moves between them.  Then
drives the vectorized sweep engine over thousands of LSU/SIMD/stride/DRAM
design points, printing the fastest configurations and the Pareto front of
predicted time vs interconnect resource use.

Finally closes the loop: ``--validate`` (also run by default) measures the
Pallas kernels and scores the analytical model against the measurement
(`repro.core.validate`), printing the paper-style error table.

Run:  python examples/membound_explorer.py   (pip install -e . or
PYTHONPATH=src; pass --sweep-only to skip the jax compilation part,
--validate for just the measured-vs-predicted table, --model for the
whole-model transformer walkthrough (``Session.estimate_model``),
--hw <name> to evaluate against a ``repro.hw`` registry spec, e.g.
--hw tpu_v4)

Everything routes through the unified ``repro.Design``/``repro.Session``
API — this file doubles as its end-to-end example.
"""
import sys
import time

from repro import Session, Space


def _session() -> Session:
    """The evaluation context, honoring a ``--hw <name>``/``--hw=<name>``
    registry flag."""
    argv = sys.argv[1:]
    name = None
    for i, arg in enumerate(argv):
        if arg == "--hw":
            if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
                sys.exit("usage: --hw <name>  (see repro.hw.names())")
            name = argv[i + 1]
        elif arg.startswith("--hw="):
            name = arg.split("=", 1)[1]
    if name is None:
        return Session()
    import repro.hw as hwreg

    try:
        return Session().with_hardware(hwreg.get(name))
    except KeyError as e:
        sys.exit(f"--hw: {e.args[0]}")


def sweep_demo() -> None:
    """Score a full design space in one pass and show the interesting slices."""
    from repro.core import DDR4_1866, DDR4_2666, LsuType

    sess = _session()
    axes = dict(
        lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
                  LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED],
        n_ga=[1, 2, 3, 4],
        simd=[1, 2, 4, 8, 16],
        n_elems=[1 << 16],
        delta=[1, 2, 4, 7],
    )
    if sess.hardware is None:     # --hw pins the memory system instead
        axes["dram"] = [DDR4_1866, DDR4_2666]
    t0 = time.perf_counter()
    res = sess.sweep(Space.grid(**axes))
    dt = time.perf_counter() - t0
    print(f"\nDesign-space sweep: {res.n_points} points scored in "
          f"{dt * 1e3:.1f} ms ({res.n_points / dt:,.0f} points/s)")
    print(f"memory-bound: {int(res.memory_bound.sum())}/{res.n_points}")

    print("\nfastest 5 designs (by predicted T_exe):")
    for row in res.top_k(5):
        print(f"  {row['lsu_type']:>14s} n_ga={row['n_ga']} simd={row['simd']:2d} "
              f"delta={row['delta']} {row['dram']}: {row['t_exe_ms']:.3f} ms "
              f"({row['eff_bw_gbs']:.1f} GB/s)")

    front = res.pareto()          # minimize (time, LSU interconnect width)
    print(f"\nPareto front (time vs resource): {len(front)} points")
    seen = set()    # collapse performance ties (inert axes, equal designs)
    for row in res.rows(front):
        key = (row["lsu_type"], row["resource_bytes"], row["t_exe_ms"])
        if key in seen:
            continue
        seen.add(key)
        print(f"  {row['lsu_type']:>14s} simd={row['simd']:2d} "
              f"res={row['resource_bytes']:.0f}B: {row['t_exe_ms']:.3f} ms")
        if len(seen) >= 5:
            break


def stream_demo() -> None:
    """Sweep a 200k+-point space in bounded memory: the streaming engine
    (the same path benchmarks/sweep_bench.py drives at >= 1M points)."""
    from repro.core import LsuType

    sess = _session()
    axes = dict(
        lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
                  LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED],
        n_ga=list(range(1, 11)),
        simd=[1, 2, 4, 8, 16],
        n_elems=[1 << e for e in range(14, 22)],
        delta=list(range(1, 17)),
        include_write=[False, True],
        val_constant=[False, True],
        elem_bytes=[4, 8],
    )
    space = Space.grid(**axes).stream(chunk_size=1 << 17)
    t0 = time.perf_counter()
    res = sess.sweep(space)       # folds into Pareto/top-k/stats reducers
    dt = time.perf_counter() - t0
    s = res.summary()
    print(f"\nStreaming sweep: {s['n_points']:,} points in {dt:.2f} s "
          f"({s['n_points'] / dt:,.0f} points/s), "
          f"{len(res.resource)} survivors held in memory")
    print(f"memory-bound: {s['memory_bound_points']:,}/{s['n_points']:,}; "
          f"Pareto front: {s['pareto_points']} points; "
          f"fastest {s['t_exe_min_ms']:.4f} ms")
    for row in res.top_k(3):
        print(f"  {row['lsu_type']:>14s} n_ga={row['n_ga']} "
              f"simd={row['simd']:2d} delta={row['delta']}: "
              f"{row['t_exe_ms']:.3f} ms ({row['eff_bw_gbs']:.1f} GB/s)")


def validate_demo() -> None:
    """Close the loop: measure the Pallas kernels and score the analytical
    model against the measurements (paper-style error table)."""
    rep = _session().validate()
    print(f"\nMeasured-vs-predicted validation "
          f"(backend={rep.results[0].backend if rep.results else '?'}, "
          f"stream anchor {rep.measured_bw / 1e9:.1f} GB/s, "
          f"host factor {rep.calibration_factor:.3g}):")
    print(f"  {'kernel':>18s} {'measured':>10s} {'predicted':>10s} "
          f"{'bytes':>9s} {'err':>7s}")
    for r in rep.results:
        print(f"  {r.name:>18s} {r.measured_s * 1e3:9.3f}ms "
              f"{r.predicted_s * 1e3:9.3f}ms {r.bytes_moved / 1e6:7.2f}MB "
              f"{r.err_pct:6.1f}%")
    for f in rep.failures:
        print(f"  {f['kernel']:>18s}  FAILED: {f['error']}")


def model_demo() -> None:
    """Whole-model estimation: walk the shipped transformer's train and
    decode steps, compose per-op Eqs. 1-10 estimates into an end-to-end
    latency/roofline report (``Session.estimate_model``)."""
    from repro.configs import ARCHS, reduced_config
    from repro.workload.report import op_table

    cfg = reduced_config(ARCHS[sorted(ARCHS)[0]], layers_scale=2)
    sess = _session()
    t0 = time.perf_counter()
    rep = sess.estimate_model(cfg, phases=("train", "decode"),
                              batch=2, seq_len=64)
    dt = time.perf_counter() - t0
    s = rep.summary()
    print(f"\nWhole-model estimation: {rep.name} on "
          f"{s['hardware']} ({dt:.1f} s to lower + walk + compose)")
    print(f"  total {rep.total_latency() * 1e3:.3f} ms, "
          f"AI={rep.arithmetic_intensity:.2f} FLOP/B "
          f"(ridge {rep.ridge_intensity:.0f}), "
          f"{'memory' if rep.memory_bound else 'compute'}-bound overall")
    for phase in rep.phases:
        print(f"\n  {phase.name}: {phase.t_total * 1e3:.3f} ms over "
              f"{phase.n_ops} ops ({len(phase.ops)} with DRAM traffic), "
              f"bottleneck={phase.bottleneck}")
        for d in phase.by_class():
            print(f"    {d['op_class']:>12s}: {d['t_exe'] * 1e3:8.3f} ms "
                  f"({d['share'] * 100:5.1f}%) {d['n_ops']:3d} ops "
                  f"{d['bytes'] / 1e6:8.2f} MB")
    print(f"\n  heaviest decode ops:\n{op_table(rep.phase('decode'), top=5)}")


def explain(name: str, fn, *specs) -> None:
    import jax

    from repro.core import hlo as HLO

    compiled = jax.jit(fn).lower(*specs).compile()
    pred = _session().predict(compiled.as_text(),
                              HLO.cost_analysis_stats(compiled))
    classes = {c.name: c.nbytes for c in pred.memory_components}
    print(f"{name:28s} AI={pred.arithmetic_intensity:8.2f} FLOP/B  "
          f"bound={pred.bottleneck:9s} classes="
          + ", ".join(f"{k}:{v:.2g}B" for k, v in classes.items()))


def main() -> None:
    import jax
    import jax.numpy as jnp

    n = 1 << 20
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    m = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    idx = jax.ShapeDtypeStruct((n,), jnp.int32)

    print("TPU Eq.-3 analogue: arithmetic intensity vs the v5e ridge "
          "(197 TF/s / 819 GB/s ~ 241 FLOP/B)\n")
    explain("sum reduction (stream)", lambda a, b: (a + b).sum(), x, x)
    explain("strided sum", lambda a: a.reshape(-1, 4)[:, 0].sum(), x)
    explain("gather sum (write-ACK)", lambda a, i: a[i % n].sum(), x, idx)
    explain("matmul 1k (compute)", lambda a: (a @ a).sum(), m)
    explain("matmul chain x8",
            lambda a: jax.lax.fori_loop(0, 8, lambda _, y: y @ a, a).sum(), m)

    print("\nPallas membench kernels (interpret mode) — same taxonomy, "
          "kernel-level:")
    from repro.kernels.membench import ops as MB
    xs = tuple(jax.random.normal(jax.random.PRNGKey(i), (1 << 16,))
               for i in range(3))
    out = MB.aligned_sum(xs, block=2048)
    print(f"  aligned_sum   -> {out.shape}, {out.dtype}")
    out = MB.strided_sum(xs, delta=4, block=512)
    print(f"  strided_sum   -> {out.shape} (delta=4: 4x the fetched bytes)")
    i = jax.random.randint(jax.random.PRNGKey(9), (16,), 0, (1 << 16) // 512)
    out = MB.gather_sum(xs, i, block=512)
    print(f"  gather_sum    -> {out.shape} (block indirection via scalar "
          f"prefetch)")

    sweep_demo()
    stream_demo()
    validate_demo()


if __name__ == "__main__":
    if "--sweep-only" in sys.argv[1:]:
        sweep_demo()
        stream_demo()
    elif "--validate" in sys.argv[1:]:
        validate_demo()
    elif "--model" in sys.argv[1:]:
        model_demo()
    else:
        main()
