"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps with the full production stack (sharded step, deterministic
data, async atomic checkpoints, preemption handling, straggler watchdog).

Default scale is CPU-friendly (a ~25M model, 200 steps, a couple of
minutes); pass ``--full`` for the ~110M/300-step configuration used in
EXPERIMENTS.md SExamples, or --arch to train any assigned architecture's
reduced config.

Run:  PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse
import dataclasses

from repro.configs import ARCHS, get_config, reduced_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainConfig, build_step
from repro.launch.train import train_loop
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig

SMALL = ModelConfig(
    name="lm-25m", family="dense", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=2, d_ff=1536, vocab_size=32768, block_pattern=("attn",),
    remat=False,
)

FULL = ModelConfig(
    name="lm-110m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=3072, vocab_size=32768, block_pattern=("attn",),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default=None, help="assigned arch id (reduced)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--data", default=None, help="memmap token file")
    args = ap.parse_args()

    if args.arch:
        cfg = reduced_config(get_config(args.arch))
    else:
        cfg = FULL if args.full else SMALL
    steps = args.steps or (300 if args.full else 200)
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps, seq={args.seq_len}, batch={args.batch}")

    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        lr=6e-4, warmup_steps=max(10, steps // 20), total_steps=steps))
    shape = ShapeSpec("train", args.seq_len, args.batch, "train")
    built = build_step(cfg, shape, mesh, tcfg)
    out = train_loop(
        cfg, built, tcfg, steps=steps, ckpt_dir=args.ckpt_dir,
        data_cfg=DataConfig(seq_len=args.seq_len, batch_size=args.batch),
        data_path=args.data, ckpt_every=50, log_every=10)
    print(f"[train_lm] final: {out}")


if __name__ == "__main__":
    main()
