"""The paper's end use-case on TPU: choose a deployment configuration from
early compile artifacts only — no accelerator time.

Compares candidate knob settings (KV-cache sharding axis, remat policy,
attention tiles, gradient compression) by lowering+compiling each on CPU and
ranking with the analytical model (core.autotune).

Run:  PYTHONPATH=src python examples/autotune_sharding.py [--kind decode]
"""
import argparse

from repro import Session
from repro.configs import get_config, reduced_config
from repro.configs.shapes import ShapeSpec
from repro.launch.mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="command-r-35b")
    ap.add_argument("--kind", default="decode", choices=["train", "decode"])
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    mesh = make_host_mesh()
    shape = (ShapeSpec("d", 256, 8, "decode") if args.kind == "decode"
             else ShapeSpec("t", 128, 8, "train"))
    print(f"[autotune] {args.arch} (reduced) {args.kind} on "
          f"{mesh.devices.shape} mesh — compiling candidates...")
    results = Session().autotune(cfg, shape, mesh)
    print(f"{'candidate':18s} {'t_step':>10s} {'bottleneck':>12s} "
          f"{'mem':>8s} {'compile':>8s}")
    for r in results:
        s = r.summary()
        print(f"{s['name']:18s} {s['t_step_ms']:8.3f}ms {s['bottleneck']:>12s} "
              f"{s['mem_gb']:6.2f}GB {s['compile_s']:6.1f}s")
    best = results[0].candidate.name
    print(f"[autotune] winner: {best} — chosen without ever running a step.")


if __name__ == "__main__":
    main()
