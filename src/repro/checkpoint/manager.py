"""Atomic, mesh-independent, optionally-async checkpointing.

Layout:  <dir>/step_<N>/{manifest.json, arr_<k>.npy}

* **Atomic**: written to ``step_<N>.tmp`` then ``os.rename``d — a crash
  mid-save can never corrupt the latest checkpoint (restore scans only
  finalized dirs).
* **Mesh-independent**: leaves are stored as full logical arrays; restore
  reshards onto whatever mesh the restarted job has — elastic rescale is a
  restore with different shardings (runtime/elastic.py).
* **Async**: ``save(..., blocking=False)`` device_gets then writes on a
  background thread so the training loop keeps stepping (checkpoint I/O
  overlaps compute — the standard large-run trick).

Production note (DESIGN.md): at 300 B+ parameters the per-leaf full-array
format would be replaced by per-shard files; the manifest/atomic-rename/
auto-resume logic is the part this framework contributes and is format-
agnostic.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[list[Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if blocking:
            self._write(step, host_leaves, treedef)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves: list[np.ndarray], treedef) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), leaf)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._cleanup()

    def _cleanup(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``like``.

        ``shardings``: optional pytree (or single sharding) — the restored
        arrays are placed with it, which is how a checkpoint written on one
        mesh is resumed on another (elastic rescale)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        leaves, treedef = _flatten(like)
        loaded = [np.load(os.path.join(d, f"arr_{i}.npy"))
                  for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves = (jax.tree.leaves(shardings)
                            if not hasattr(shardings, "memory_kind")
                            else [shardings] * len(loaded))
            loaded = [jax.device_put(x, s)
                      for x, s in zip(loaded, shard_leaves)]
        else:
            loaded = [jax.device_put(x.astype(l.dtype) if hasattr(l, "dtype")
                                     else x)
                      for x, l in zip(loaded, leaves)]
        return jax.tree.unflatten(treedef, loaded), step
