"""Deterministic, restart-safe data pipeline.

Design rule: a batch is a pure function of ``(seed, step, shard)`` — no
iterator state.  Checkpoint/restart and elastic rescaling then need to save
only the step counter; any host can recompute exactly its shard of any step
(the fault-tolerance contract in runtime/).

Two sources:
* ``SyntheticDataset`` — Zipf-ish token stream from a counter-based RNG
  (numpy Philox keyed by (seed, step, shard)); used by the smoke tests,
  examples and benchmarks.
* ``MemmapDataset``   — a binary token file (uint16/uint32) accessed at
  deterministic offsets; the production path for real corpora.

Both return the next-token-prediction batch {tokens, labels} and support
modality extras for the stub frontends (audio features / vision patches).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import numpy as np

from repro.models.config import ModelConfig
from repro.configs.shapes import vision_patches


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    batch_size: int            # per-shard batch
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


class SyntheticDataset:
    """Counter-based synthetic LM data: batch = f(seed, step, shard)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.data.seed, counter=[0, 0, self.data.shard, step]))

    def get_batch(self, step: int) -> dict[str, np.ndarray]:
        cfg, d = self.cfg, self.data
        rng = self._rng(step)
        B, S = d.batch_size, d.seq_len
        if cfg.frontend == "audio":
            feats = rng.standard_normal((B, S, cfg.frontend_dim)).astype(np.float32)
            labels = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
            mask = (rng.random((B, S)) < 0.08).astype(np.float32)  # HuBERT-style masking
            return {"features": feats, "labels": labels, "mask": mask}
        # Zipfian token stream (approximates natural-language unigrams)
        z = rng.zipf(1.2, size=(B, S + 1))
        toks = np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)
        if cfg.frontend == "vision":
            patches = vision_patches(S)
            n_text = S - patches
            feats = rng.standard_normal(
                (B, patches, cfg.frontend_dim)).astype(np.float32)
            return {"features": feats,
                    "tokens": toks[:, :n_text],
                    "labels": toks[:, 1:n_text + 1]}
        return {"tokens": toks[:, :S], "labels": toks[:, 1:S + 1]}


class MemmapDataset:
    """Token file dataset: deterministic strided windows over a memmap."""

    def __init__(self, cfg: ModelConfig, data: DataConfig, path: str,
                 dtype=np.uint16):
        self.cfg = cfg
        self.data = data
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        if len(self.tokens) < data.seq_len + 1:
            raise ValueError("token file shorter than one sequence")

    def get_batch(self, step: int) -> dict[str, np.ndarray]:
        d = self.data
        B, S = d.batch_size, d.seq_len
        n_windows = (len(self.tokens) - 1) // S
        rng = np.random.Generator(np.random.Philox(
            key=d.seed, counter=[0, 1, d.shard, step]))
        idx = rng.integers(0, n_windows, size=B)
        tokens = np.stack([self.tokens[i * S:i * S + S] for i in idx])
        labels = np.stack([self.tokens[i * S + 1:i * S + S + 1] for i in idx])
        v = self.cfg.vocab_size
        return {"tokens": (tokens % v).astype(np.int32),
                "labels": (labels % v).astype(np.int32)}


def make_dataset(cfg: ModelConfig, data: DataConfig,
                 path: str | None = None) -> Any:
    if path and os.path.exists(path):
        return MemmapDataset(cfg, data, path)
    return SyntheticDataset(cfg, data)
