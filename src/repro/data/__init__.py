from repro.data.pipeline import MemmapDataset, SyntheticDataset, make_dataset
