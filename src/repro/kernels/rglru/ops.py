"""jit'd wrapper for the RG-LRU scan kernel (with CPU interpret fallback)."""
from __future__ import annotations

import functools

import jax

from repro import compat
from repro.kernels.rglru.kernel import rglru_scan


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def scan(a, b, *, block_s=256, block_w=512, interpret=None):
    interpret = compat.default_interpret(interpret)
    B, S, W = a.shape
    bs = min(block_s, S)
    while S % bs:
        bs -= 1
    bw = min(block_w, W)
    while W % bw:
        bw -= 1
    return rglru_scan(a, b, block_s=bs, block_w=bw, interpret=interpret)
