"""Pure-jnp oracle for the RG-LRU scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t over axis 1 (f32 math).  a,b: (B,S,W)."""
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h.astype(a.dtype)
