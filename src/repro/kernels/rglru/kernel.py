"""RG-LRU sequence-scan Pallas TPU kernel.

The recurrence ``h_t = a_t * h_{t-1} + b_t`` is elementwise over the channel
dimension — pure VPU work streaming (B, S, W) once from HBM, i.e. strictly
memory-bound (arithmetic intensity ~0.5 FLOP/byte).  The kernel tiles
channels across the grid and keeps the carried state ``h`` in VMEM scratch
while marching over sequence blocks:

Grid: ``(B, n_w_blocks, n_s_blocks)`` (sequence innermost, sequential).
Within a block the time loop runs over rows of the (block_s, block_w) VMEM
tile — sequential in time but vectorized across the 128-lane channel tile,
which is how the TPU wants an elementwise recurrence (DESIGN.md S2
hardware-adaptation note: no warp-scan analogue; lane-parallel time-marching
instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)        # (block_s, block_w)
    b = b_ref[0].astype(jnp.float32)

    def body(t, carry):
        h = carry
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, body, h_ref[...])
    h_ref[...] = h


def rglru_scan(
    a: jax.Array,                  # (B, S, W) per-step decay in (0,1)
    b: jax.Array,                  # (B, S, W) per-step input
    *,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0, (S, W, block_s, block_w)
    n_s = S // block_s
    n_w = W // block_w
    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(B, n_w, n_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, j: (b_, j, w)),
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, j: (b_, j, w)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda b_, w, j: (b_, j, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
