"""The paper's SIV microbenchmarks as Pallas TPU kernels.

Three kernels mirror the three LSU classes of Listing 4 on the TPU memory
system (the access-class taxonomy of DESIGN.md S2):

* ``aligned_sum``   — ``z[i] = x1[i] + ... + xn[i]``: contiguous streaming,
  the burst-coalesced-aligned analogue; HBM-bandwidth bound.
* ``strided_sum``   — block-strided reads (stride delta at tile granularity,
  exactly like the paper's delta at DRAM-burst granularity): the
  burst-coalesced-non-aligned analogue.
* ``gather_sum``    — data-dependent block indices via scalar prefetch
  (paged-KV-style indirection): the Write-ACK analogue.

They are used by the fig4/fig5 benchmark harness to relate the TPU memory
model's per-class efficiency factors to real kernel structure, and are
validated against ``ref.py`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _sum_kernel(*refs):
    o_ref = refs[-1]
    acc = refs[0][...].astype(jnp.float32)
    for r in refs[1:-1]:
        acc = acc + r[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _sum_kernel_prefetch(idx_ref, *refs):
    del idx_ref  # consumed by the index maps
    _sum_kernel(*refs)


def aligned_sum(xs: list[jax.Array], *, block: int = 2048,
                interpret: bool = False) -> jax.Array:
    """z = sum of n contiguous arrays, tiled in `block`-element chunks."""
    n = xs[0].shape[0]
    block = min(block, n)
    assert n % block == 0
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _sum_kernel,
        grid=(n // block,),
        in_specs=[spec] * len(xs),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), xs[0].dtype),
        interpret=interpret,
    )(*xs)


def strided_sum(xs: list[jax.Array], *, delta: int, block: int = 2048,
                interpret: bool = False) -> jax.Array:
    """z[i-th block] = sum of x_g[delta * i-th block] — block-granularity
    stride, the Eq. 8 effective-burst picture."""
    n_out = xs[0].shape[0] // delta
    block = min(block, n_out)
    assert n_out % block == 0
    in_spec = pl.BlockSpec((block,), lambda i, d=delta: (i * d,))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _sum_kernel,
        grid=(n_out // block,),
        in_specs=[in_spec] * len(xs),
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n_out,), xs[0].dtype),
        interpret=interpret,
    )(*xs)


def gather_sum(xs: list[jax.Array], idx: jax.Array, *, block: int = 2048,
               interpret: bool = False) -> jax.Array:
    """z[i-th block] = sum of x_g[idx[i]-th block] — data-dependent block
    indirection via scalar prefetch."""
    n_blocks = idx.shape[0]
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i, idx_ref: (idx_ref[i],))
                  ] * len(xs),
        out_specs=pl.BlockSpec((block,), lambda i, idx_ref: (i,)),
    )
    return pl.pallas_call(
        _sum_kernel_prefetch,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * block,), xs[0].dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), *xs)
