"""Oracles for the membench kernels."""
from __future__ import annotations

import jax.numpy as jnp


def aligned_sum_ref(xs):
    out = xs[0].astype(jnp.float32)
    for x in xs[1:]:
        out = out + x.astype(jnp.float32)
    return out.astype(xs[0].dtype)


def strided_sum_ref(xs, *, delta, block):
    n_out = xs[0].shape[0] // delta
    n_blocks = n_out // block

    def pick(x):
        # i-th output block reads the (i*delta)-th input block
        blocks = x.reshape(-1, block)
        sel = blocks[jnp.arange(n_blocks) * delta]
        return sel.reshape(-1)

    out = pick(xs[0]).astype(jnp.float32)
    for x in xs[1:]:
        out = out + pick(x).astype(jnp.float32)
    return out.astype(xs[0].dtype)


def gather_sum_ref(xs, idx, *, block):
    def pick(x):
        return x.reshape(-1, block)[idx].reshape(-1)

    out = pick(xs[0]).astype(jnp.float32)
    for x in xs[1:]:
        out = out + pick(x).astype(jnp.float32)
    return out.astype(xs[0].dtype)
