"""jit'd wrappers for the membench kernels (CPU interpret fallback)."""
from __future__ import annotations

import functools

import jax

from repro import compat
from repro.kernels.membench import kernel as K


def _interp(v):
    return compat.default_interpret(v)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def aligned_sum(xs, *, block=2048, interpret=None):
    return K.aligned_sum(list(xs), block=block, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("delta", "block", "interpret"))
def strided_sum(xs, *, delta, block=2048, interpret=None):
    return K.strided_sum(list(xs), delta=delta, block=block,
                         interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gather_sum(xs, idx, *, block=2048, interpret=None):
    return K.gather_sum(list(xs), idx, block=block, interpret=_interp(interpret))
