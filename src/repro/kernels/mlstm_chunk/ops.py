"""jit'd wrapper for the chunked mLSTM kernel (CPU interpret fallback)."""
from __future__ import annotations

import functools

import jax

from repro import compat
from repro.kernels.mlstm_chunk.kernel import mlstm_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunked_mlstm(q, k, v, li, lf, *, chunk=256, interpret=None):
    """q,k,v: (B,S,H,dh); li/lf: (B,S,H) -> (B,S,H,dh)."""
    interpret = compat.default_interpret(interpret)
    B, S, H, dh = q.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    out = mlstm_chunk(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), li.transpose(0, 2, 1),
                      lf.transpose(0, 2, 1), chunk=c, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
