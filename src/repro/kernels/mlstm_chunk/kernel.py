"""Chunkwise-parallel mLSTM Pallas TPU kernel.

The XLA chunk scan carries the (dh x dh) matrix memory C through HBM every
chunk (the dominant memory term of the xlstm prefill cell after the
collective fixes — EXPERIMENTS.md SPerf Cell C).  This kernel keeps (C, n)
in VMEM scratch across the sequential chunk dimension, exactly as the
flash-attention kernel keeps the online-softmax state resident:

Grid: ``(B, H, n_chunks)`` (chunks innermost, sequential).  Per step it
loads one (c x dh) q/k/v chunk tile + the (c,) gate vectors, computes the
intra-chunk masked decay attention and the inter-chunk state contribution,
writes the (c x dh) output tile, and updates C/n in place.

Gating follows the model's sigmoid log-space form (log i, log f <= 0), so
every decay weight is exp(<=0) — overflow-free by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  C_ref, n_ref, *, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (c, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)        # (c,)
    lf = lf_ref[0, 0].astype(jnp.float32)

    cum = jnp.cumsum(lf)                         # (c,) log decay since start
    total = cum[-1]
    C = C_ref[...]
    n = n_ref[...]

    qd = q * jnp.exp(cum)[:, None]
    inter = jax.lax.dot_general(qd, C, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n_inter = qd @ n                             # (c,)

    w_log = cum[:, None] - cum[None, :] + li[None, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    w = jnp.where(mask, jnp.exp(w_log), 0.0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * w
    intra = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n_intra = jax.lax.dot_general(w, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    den = n_inter + jnp.sum(q * n_intra, axis=-1)
    h = (inter + intra) / jnp.maximum(jnp.abs(den), 1.0)[:, None]
    o_ref[0, 0] = h.astype(o_ref.dtype)

    decay_to_end = jnp.exp(total - cum + li)     # (c,)
    kw = k * decay_to_end[:, None]
    C_ref[...] = C * jnp.exp(total) + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[...] = n * jnp.exp(total) + kw.sum(axis=0)


def mlstm_chunk(
    q: jax.Array,                 # (B, H, S, dh)
    k: jax.Array,
    v: jax.Array,
    li: jax.Array,                # (B, H, S) log input gate (<= 0)
    lf: jax.Array,                # (B, H, S) log forget gate (<= 0)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, dh = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    qkv_spec = pl.BlockSpec((1, 1, chunk, dh), lambda b, h, j: (b, h, j, 0))
    gate_spec = pl.BlockSpec((1, 1, chunk), lambda b, h, j: (b, h, j))
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, li, lf)
