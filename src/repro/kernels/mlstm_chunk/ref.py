"""Oracle: strictly sequential mLSTM recurrence (per-head)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_chunk_ref(q, k, v, li, lf):
    """q,k,v: (B,H,S,dh); li/lf: (B,H,S) log gates.  f32 sequential scan."""
    B, H, S, dh = q.shape

    def step(carry, inp):
        C, n = carry
        qt, kt, vt, lit, lft = inp
        i = jnp.exp(lit)[..., None]
        f = jnp.exp(lft)[..., None]
        C = C * f[..., None] + i[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = n * f + i * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), 1.0)
        return (C, n), num / den[..., None]

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    xs = (q.transpose(2, 0, 1, 3).astype(jnp.float32),
          k.transpose(2, 0, 1, 3).astype(jnp.float32),
          v.transpose(2, 0, 1, 3).astype(jnp.float32),
          li.transpose(2, 0, 1).astype(jnp.float32),
          lf.transpose(2, 0, 1).astype(jnp.float32))
    _, hs = jax.lax.scan(step, (C0, n0), xs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype)
