"""Flash-attention forward Pallas TPU kernel (GQA, causal / sliding-window).

Grid: ``(B, Hq, n_q_blocks, n_kv_blocks)`` with the kv dimension innermost
(sequential).  Per (b, h, i) the kernel streams kv blocks through VMEM,
maintaining the online-softmax state (m, l, acc) in VMEM scratch, and writes
the normalized output on the last kv block.  Fully-masked (q, kv) block pairs
(beyond the causal diagonal or outside the sliding window) skip the matmul
via ``pl.when`` — the TPU analogue of not issuing the DRAM burst at all.

Block shapes are MXU/VMEM-aligned: ``block_q x d_head`` and
``block_kv x d_head`` tiles with d_head padded to a multiple of 128 by the
ops.py wrapper when needed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, block_q: int, block_kv: int, n_kv: int,
                 causal: bool, window: int | None, softcap: float,
                 seq_kv: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = i * block_q
    k_lo = j * block_kv
    # static-shape block skip decision (computed on scalars)
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + block_q - 1
    if window is not None:
        live &= k_lo + block_kv - 1 > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # (B, Hq, Sq, D)
    k: jax.Array,                  # (B, Hkv, Skv, D)
    v: jax.Array,                  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    block_q: int = 512,
    block_kv: int = 512,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    n_q = -(-Sq // block_q)
    n_kv = -(-Skv // block_kv)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        n_kv=n_kv, causal=causal, window=window, softcap=softcap,
        seq_kv=Skv)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
