"""jit'd public wrapper for the flash-attention kernel.

Accepts the model's (B, S, H, D) layout, handles GQA head mapping, pads
``seq`` to block multiples and ``d_head`` to the 128-lane MXU width, and
falls back to interpret mode off-TPU (CPU CI / tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "interpret"))
def mha(q, k, v, *, causal=True, window=None, softcap=0.0,
        block_q=512, block_kv=512, interpret=None):
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D)."""
    interpret = compat.default_interpret(interpret)
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    # kernel layout: (B, H, S, D)
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    d_pad = (-D) % 128 if not interpret else 0
    if d_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    sq_pad = (-Sq) % bq
    skv_pad = (-Skv) % bkv
    if sq_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if skv_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap, block_q=bq, block_kv=bkv,
                          scale=1.0 / (D ** 0.5), interpret=interpret)
    out = out[:, :, :Sq, :D]
    return out.swapaxes(1, 2)
