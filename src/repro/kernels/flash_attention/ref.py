"""Pure-jnp oracle for the flash-attention kernel (GQA, causal/local)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import dense_attention


def attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0):
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D).  f32 math."""
    out = dense_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=causal,
                          window=window, softcap=softcap)
    return out.astype(q.dtype)
