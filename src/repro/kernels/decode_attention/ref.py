"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, kv_len, *, softcap=0.0):
    """q: (B,Hkv,G,D); caches (B,S,Hkv,D); kv_len scalar -> (B,Hkv,G,D)."""
    B, Hkv, G, D = q.shape
    S = k_cache.shape[1]
    qf = q.astype(jnp.float32) / math.sqrt(D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(S)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.astype(q.dtype)
