"""Memory-bound GQA decode-attention Pallas TPU kernel.

One new token attends over a (B, S, Hkv, D) KV cache — per step the kernel
*streams the whole cache once* with zero reuse, which makes it the canonical
memory-bound workload of this framework (arithmetic intensity ~ G flops/byte
for G q-heads per kv head; far below the v5e ridge of ~241).

Grid: ``(B, Hkv, n_s_blocks)`` with the cache-block dimension innermost and
sequential; online-softmax state for the G grouped q heads lives in VMEM
scratch.  The cache keeps the model's native (B, S, Hkv, D) layout so decode
reads are contiguous (burst-coalesced-aligned class); positions ``>= kv_len``
are masked via the scalar-prefetch length.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, block_s: int, n_s: int, softcap: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    live = j * block_s < kv_len

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        pos = j * block_s + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,                  # (B, Hkv, G, D) — grouped q heads
    k_cache: jax.Array,            # (B, S, Hkv, D)
    v_cache: jax.Array,            # (B, S, Hkv, D)
    kv_len: jax.Array,             # () int32 — valid cache length
    *,
    softcap: float = 0.0,
    block_s: int = 512,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, D = q.shape
    S = k_cache.shape[1]
    block_s = min(block_s, S)
    n_s = -(-S // block_s)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                               n_s=n_s, softcap=softcap)
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D),
                         lambda b, h, j, len_ref: (b, j, h, 0)),
            pl.BlockSpec((1, block_s, 1, D),
                         lambda b, h, j, len_ref: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), q, k_cache, v_cache)
