"""jit'd wrapper: model-layout decode attention via the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("softcap", "block_s", "interpret"))
def gqa_decode(q, k_cache, v_cache, kv_len, *, softcap=0.0, block_s=512,
               interpret=None):
    """q: (B, 1, Hq, D); caches: (B, S, Hkv, D) -> (B, 1, Hq, D)."""
    interpret = compat.default_interpret(interpret)
    B, one, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, G, D)
    out = decode_attention(qg, k_cache, v_cache, kv_len,
                           softcap=softcap, block_s=block_s,
                           scale=1.0 / (D ** 0.5), interpret=interpret)
    return out.reshape(B, 1, Hq, D)
