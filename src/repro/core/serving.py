"""Micro-batched design-advisor serving: the long-lived query front door.

The estimator is a pure function that answers "how fast will this design
run" in microseconds — the missing piece for the interactive-advisor use
case is a concurrent front door.  :class:`Server` (built by
``Session.serve()``) turns one :class:`~repro.api.Session` into a query
service:

* **Micro-batching** — ``estimate``/``submit`` calls from many threads land
  on a bounded queue; a background batcher thread collects up to
  ``max_batch`` requests (waiting at most ``max_wait_ms`` after the first),
  scores them in **one** batched ``estimate_many`` pass, and scatters the
  per-row results back to per-request futures.  Row ``i`` of a batch is
  bit-equal to the same design scored alone (the array core is row-
  independent; tests/test_serve.py hammers this), so batching is invisible
  to callers except in latency.
* **Fixed-shape chunks on jax-jit** — the jit backend compiles once per
  input shape, so ragged batches are padded the same way the streaming
  engine pads its last chunk (:mod:`repro.core.stream`): the kernel axis is
  padded to ``max_batch`` and the group axis to a power-of-two bucket by
  repeating a real row under a padding kernel id, then the padded tail is
  masked off the scattered results.  A handful of bucket shapes serve every
  request mix.
* **Result caching** — a content-hash LRU (:class:`repro.core.cache.LruCache`,
  keyed on the canonical ``Design`` + hardware + calibration hash) sits in
  front of the batcher, one level above the on-disk HLO-analysis cache of
  :mod:`repro.core.cache`: repeat queries (the advisor steady state) return
  without touching the queue, marked ``Estimate.cached``.  Identical
  designs *in flight* coalesce onto one future, so a miss storm for one hot
  design costs one batch slot.
* **Operability** — ``stats()`` exposes hit/miss/latency counters (p50/p99
  over a sliding window), ``close(drain=True)`` performs a graceful drain,
  per-request deadlines fast-fail expired work before scoring, and a full
  queue fast-fails new submissions with :class:`ServerOverloaded` instead
  of building unbounded backlog.

This module is thread-and-stdlib only on top of the numpy core — jax loads
only if the session's backend asks for it.
"""
from __future__ import annotations

import dataclasses
import queue
import statistics
import threading
import time
from collections import deque
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core import model_batch as _mb
from repro.core.cache import LruCache, config_hash

if TYPE_CHECKING:  # pragma: no cover — import cycle is runtime-lazy
    from repro.api import Design, Estimate, Session


class ServerError(RuntimeError):
    """Base class of serving-layer failures."""


class ServerClosed(ServerError):
    """The server no longer accepts (or could not finish) this request."""


class ServerOverloaded(ServerError):
    """The bounded request queue is full — fast-fail, caller may retry."""


class RequestTimeout(ServerError, TimeoutError):
    """The request's deadline passed before a result was produced."""


@dataclasses.dataclass
class _Request:
    """One queued estimate request (internal currency of the batcher)."""

    design: "Design"
    key: str
    future: Future
    t_enqueue: float
    deadline: float | None        # monotonic seconds; None = no deadline


_SHUTDOWN = object()              # queue sentinel: drain then exit


def _design_key(design: "Design", salt: str) -> str:
    """Canonical content hash of one design under one session context.

    ``name`` participates so coalesced requests always get back a result
    carrying *their* design verbatim; ``flops`` rides along in the repr.
    The session salt folds in hardware, calibration and backend, so one
    server never serves another context's numbers.
    """
    return config_hash({
        "lsus": [repr(l) for l in design.lsus],
        "dram": repr(design.dram), "bsp": repr(design.bsp),
        "f": design.f, "name": design.name, "flops": design.flops,
    }, salt=salt)


def _session_salt(session: "Session") -> str:
    return config_hash({
        "dram": repr(session.dram), "bsp": repr(session.bsp),
        "hw": repr(session.hw), "backend": session.backend,
        "calibration": session.calibration_factor,
        "hardware": repr(session.hardware),
    }, salt="serve-session")


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def pad_group_batch(batch: "_mb.GroupBatch", n_kernels: int, m_groups: int,
                    ) -> "_mb.GroupBatch":
    """Pad a ragged GroupBatch to fixed ``(n_kernels, m_groups)`` shape.

    Padding groups repeat row 0 (a real, finite row — no divide-by-zero
    surprises under jit) but belong to fresh *padding kernels* beyond the
    real ones, so every real kernel's segment sums are untouched and rows
    ``[0, real_n)`` of the padded estimate are bit-equal to the unpadded
    ones.  Mirrors the streaming engine's pad-the-last-chunk trick
    (:func:`repro.core.stream.run_stream`), applied to the request axis.
    """
    m = len(np.asarray(batch.kernel))
    if batch.n_kernels > n_kernels or m > m_groups:
        raise ValueError(
            f"batch ({batch.n_kernels} kernels, {m} groups) exceeds the "
            f"padding target ({n_kernels}, {m_groups})")
    if (batch.n_kernels == n_kernels and m == m_groups) or m == 0:
        return batch        # nothing to pad from (or with): keep as-is
    pad = m_groups - m
    kernel = np.concatenate([
        np.asarray(batch.kernel, dtype=np.int64),
        # spread padding rows over the padding kernels (wrapping) so no
        # padding kernel ever aggregates an outsized segment
        (n_kernels - 1 - (np.arange(pad, dtype=np.int64)
                          % max(1, n_kernels - batch.n_kernels)))
        if pad else np.empty(0, dtype=np.int64)])
    out = {"kernel": kernel, "n_kernels": n_kernels}
    for fld in dataclasses.fields(_mb.GroupBatch):
        if fld.name in out:
            continue
        col = np.asarray(getattr(batch, fld.name))
        out[fld.name] = np.concatenate(
            [col, np.repeat(col[:1], pad, axis=0)]) if pad else col
    return _mb.GroupBatch(**out)


class Server:
    """Concurrent micro-batching front door over one :class:`Session`.

    Build one with ``Session.serve(...)``; use it from any number of
    threads; close it (or use it as a context manager) when done::

        with Session().serve(max_batch=64) as srv:
            est = srv.estimate(design)            # blocking
            fut = srv.submit(design)              # Future[Estimate]
            print(srv.stats()["latency_ms"])

    Results are bit-equal to ``session.estimate(design)`` called serially,
    whatever batch a request lands in (tests/test_serve.py).
    """

    def __init__(self, session: "Session", *, max_batch: int = 64,
                 max_wait_ms: float = 1.0, cache_size: int = 4096,
                 max_queue: int = 1024, timeout_ms: float | None = None,
                 latency_window: int = 4096):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if timeout_ms is not None and timeout_ms <= 0:
            raise ValueError("timeout_ms must be > 0 (or None)")
        self.session = session
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.timeout_s = None if timeout_ms is None else float(timeout_ms) / 1e3
        self._salt = _session_salt(session)
        self._queue: queue.Queue = queue.Queue(maxsize=int(max_queue))
        self._cache: LruCache = LruCache(int(cache_size))
        self._lock = threading.Lock()
        self._inflight: dict[str, Future] = {}
        # id -> (design, key): advisor clients replay the same Design
        # objects, so skip re-hashing them on the hot path.  The strong ref
        # in the value pins the id for as long as the entry lives, and the
        # `is` check on read makes a stale id harmless either way.
        self._key_memo: dict[int, tuple] = {}
        self._closed = False
        self._latencies: deque[float] = deque(maxlen=int(latency_window))
        self._counters = {
            "submitted": 0, "served": 0, "errors": 0, "coalesced": 0,
            "rejected_overload": 0, "expired": 0, "batches": 0,
            "batched_requests": 0, "max_batch_seen": 0,
        }
        self._thread = threading.Thread(
            target=self._batcher, name="repro-serve-batcher", daemon=True)
        self._thread.start()

    # -- client surface -----------------------------------------------------

    def submit(self, design: "Design",
               timeout_ms: float | None = None) -> Future:
        """Enqueue one design; returns a ``Future[Estimate]``.

        Fast paths: a cache hit resolves immediately without touching the
        queue; an identical design already in flight shares that request's
        future.  A full queue raises :class:`ServerOverloaded` *now* (the
        fast-fail overload policy) rather than queueing unboundedly.
        """
        if self._closed:
            raise ServerClosed("server is closed")
        memo = self._key_memo.get(id(design))
        if memo is not None and memo[0] is design:
            key = memo[1]
        else:
            key = _design_key(design, self._salt)
            if len(self._key_memo) >= 4 * self._cache.capacity + 64:
                self._key_memo.clear()
            self._key_memo[id(design)] = (design, key)
        t0 = time.monotonic()
        with self._lock:
            self._counters["submitted"] += 1
            hit = self._cache.get(key)
            if hit is not None:
                fut: Future = Future()
                fut.set_result(self._as_cached(hit, design))
                self._latencies.append(time.monotonic() - t0)
                self._counters["served"] += 1
                return fut
            shared = self._inflight.get(key)
            if shared is not None:
                self._counters["coalesced"] += 1
                return shared
            fut = Future()
            self._inflight[key] = fut
        t = timeout_ms if timeout_ms is not None else (
            None if self.timeout_s is None else self.timeout_s * 1e3)
        req = _Request(design=design, key=key, future=fut, t_enqueue=t0,
                       deadline=None if t is None else t0 + t / 1e3)
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            with self._lock:
                self._counters["rejected_overload"] += 1
                self._inflight.pop(key, None)
            raise ServerOverloaded(
                f"request queue full ({self._queue.maxsize} pending); "
                f"retry later or raise max_queue") from None
        return fut

    def estimate(self, design: "Design", *,
                 timeout_ms: float | None = None) -> "Estimate":
        """Blocking estimate through the batcher (the advisor entry point).

        ``timeout_ms`` (or the server default) bounds the wait; expiry
        raises :class:`RequestTimeout`.  The result is bit-equal to
        ``self.session.estimate(design)``.
        """
        fut = self.submit(design, timeout_ms=timeout_ms)
        t = timeout_ms if timeout_ms is not None else (
            None if self.timeout_s is None else self.timeout_s * 1e3)
        try:
            return fut.result(timeout=None if t is None else t / 1e3)
        # pre-3.11 concurrent.futures.TimeoutError is not the builtin one
        except (TimeoutError, _FutureTimeout):
            raise RequestTimeout(
                f"no result within {t:.1f} ms (queue depth "
                f"{self._queue.qsize()})") from None

    def predict(self, hlo_text: str, cost: dict | None = None, *,
                gather_row_bytes: float = 512.0):
        """Cached TPU-transplant step prediction (``Session.predict``).

        Predictions are pure in (hlo_text, cost, hw), so they share the
        server's LRU under a distinct key prefix; the heavy HLO parse runs
        at most once per unique executable text.
        """
        key = config_hash({"hlo": hlo_text, "cost": cost,
                           "gather_row_bytes": gather_row_bytes},
                          salt="predict-" + self._salt)
        with self._lock:
            hit = self._cache.get(key)
        if hit is not None:
            return hit
        out = self.session.predict(hlo_text, cost,
                                   gather_row_bytes=gather_row_bytes)
        with self._lock:
            self._cache.put(key, out)
        return out

    def sweep(self, space=None, *, chunk_size: int | None = None,
              reducers=None, executor: str = "threads",
              workers: int | None = None, **axes):
        """Design-space sweep behind the serving front door.

        Same calling surface as :meth:`repro.api.Session.sweep` (including
        ``executor="processes"`` for the coordinator/worker pool), plus the
        server's result cache: a grid space canonicalizes to its
        :class:`~repro.core.stream.SweepPlan` JSON, so repeat queries for
        the same space under the same session context return the finished
        :class:`~repro.api.SweepReport` without re-scoring, and identical
        sweeps *in flight* coalesce onto one run.  Custom ``reducers``
        (mutable instances) and ``Space.random`` spaces run uncached.
        """
        if self._closed:
            raise ServerClosed("server is closed")
        sp = self.session._as_space(space, axes)

        def run():
            return self.session.sweep(sp, chunk_size=chunk_size,
                                      reducers=reducers, workers=workers,
                                      executor=executor)

        if reducers is not None:
            return run()        # reducer instances carry uncanonical state
        try:
            plan = self.session.plan(sp, chunk_size=chunk_size)
        except TypeError:
            return run()        # non-grid space: no canonical plan to key on
        # Streaming and materialized reports answer different queries (held
        # rows vs the whole space), so the mode is part of the key even
        # though it never changes the numbers.
        streaming = (chunk_size is not None or sp.chunk_size is not None
                     or workers is not None or executor == "processes")
        key = config_hash({"plan": plan.to_json(), "streaming": streaming},
                          salt="sweep-" + self._salt)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                return hit
            shared = self._inflight.get(key)
            if shared is None:
                fut: Future = Future()
                self._inflight[key] = fut
        if shared is not None:
            with self._lock:
                self._counters["coalesced"] += 1
            return shared.result()
        try:
            report = run()
        except BaseException as exc:
            with self._lock:
                if self._inflight.get(key) is fut:
                    self._inflight.pop(key, None)
            fut.set_exception(exc)
            raise
        with self._lock:
            self._cache.put(key, report)
            if self._inflight.get(key) is fut:
                self._inflight.pop(key, None)
        fut.set_result(report)
        return report

    # -- lifecycle ----------------------------------------------------------

    def drain(self, timeout_s: float | None = None) -> None:
        """Block until every queued request has been scored."""
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while not self._queue.empty() or self._inflight:
            if deadline is not None and time.monotonic() > deadline:
                raise RequestTimeout(
                    f"drain incomplete after {timeout_s:.1f}s "
                    f"(queue depth {self._queue.qsize()})")
            time.sleep(0.5e-3)

    def close(self, *, drain: bool = True, timeout_s: float = 30.0) -> None:
        """Stop accepting requests and shut the batcher down.

        ``drain=True`` (graceful) scores everything already queued first;
        ``drain=False`` fails pending futures with :class:`ServerClosed`.
        Idempotent; also runs on ``__exit__``.
        """
        if self._closed:
            return
        self._closed = True
        if not drain:
            # pull whatever is still queued and fail it
            while True:
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    break
                if req is not _SHUTDOWN:
                    self._fail(req, ServerClosed("server closed before "
                                                 "this request was scored"))
        self._queue.put(_SHUTDOWN)
        self._thread.join(timeout=timeout_s)
        with self._lock:
            pending = list(self._inflight.values())
            self._inflight.clear()
        for fut in pending:
            if not fut.done():
                fut.set_exception(ServerClosed("server closed"))

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    @property
    def closed(self) -> bool:
        return self._closed

    # -- telemetry ----------------------------------------------------------

    def stats(self) -> dict:
        """Hit/miss/latency counters (one consistent snapshot).

        ``latency_ms`` summarizes the last ``latency_window`` completed
        requests (submit -> result, cache hits included): p50/p99/mean.
        """
        with self._lock:
            lat = sorted(self._latencies)
            counters = dict(self._counters)
            cache = self._cache.stats()
        n = len(lat)
        pct = lambda q: (lat[min(n - 1, int(q * (n - 1) + 0.999999))] * 1e3  # noqa: E731
                         if n else 0.0)
        served = max(1, counters["served"])
        return {
            **counters,
            "queue_depth": self._queue.qsize(),
            "inflight": len(self._inflight),
            "cache": cache,
            "cache_hit_rate": cache["hits"] / max(1, cache["hits"]
                                                  + cache["misses"]),
            "mean_batch": counters["batched_requests"] / max(
                1, counters["batches"]),
            "latency_ms": {
                "n": n,
                "p50": statistics.median(lat) * 1e3 if n else 0.0,
                "p99": pct(0.99),
                "mean": sum(lat) / n * 1e3 if n else 0.0,
            },
            "served_per_batch": counters["served"] / max(
                1, counters["batches"]) if counters["batches"] else 0.0,
            "error_rate": counters["errors"] / served,
        }

    # -- batcher ------------------------------------------------------------

    def _collect(self) -> "list[_Request] | None":
        """Block for the first request, then fill the batch.

        Everything already queued is drained immediately; only a *partial*
        batch then lingers up to ``max_wait_ms`` for stragglers, so a lone
        request never waits longer than the window and a hot queue never
        waits at all.  Returns ``None`` on shutdown (after requeueing the
        sentinel so the drain path still scores what it collected).
        """
        try:
            first = self._queue.get()
        except (OSError, ValueError):  # pragma: no cover — interpreter exit
            return None
        if first is _SHUTDOWN:
            return None
        batch = [first]
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            try:
                nxt = self._queue.get_nowait()
            except queue.Empty:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
            if nxt is _SHUTDOWN:
                self._queue.put(_SHUTDOWN)     # keep the signal for the loop
                break
            batch.append(nxt)
        return batch

    def _batcher(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            now = time.monotonic()
            live: list[_Request] = []
            for req in batch:
                if req.deadline is not None and now > req.deadline:
                    self._fail(req, RequestTimeout(
                        "request expired in queue before scoring"))
                    with self._lock:
                        self._counters["expired"] += 1
                else:
                    live.append(req)
            if not live:
                continue
            try:
                results = self._score([r.design for r in live])
            except BaseException as exc:  # noqa: BLE001 — fail the batch, not the thread
                for req in live:
                    self._fail(req, exc)
                continue
            now = time.monotonic()
            with self._lock:
                self._counters["batches"] += 1
                self._counters["batched_requests"] += len(live)
                self._counters["max_batch_seen"] = max(
                    self._counters["max_batch_seen"], len(live))
                for req, est in zip(live, results):
                    self._cache.put(req.key, est)
                    self._inflight.pop(req.key, None)
                    self._latencies.append(now - req.t_enqueue)
                    self._counters["served"] += 1
            for req, est in zip(live, results):
                req.future.set_result(est)

    def _score(self, designs: "Sequence[Design]") -> "list[Estimate]":
        """One batched scoring pass (the only caller of the estimator).

        On the jax-jit backend the ragged design batch is padded to a fixed
        ``(max_batch, group-bucket)`` shape first so the jit core compiles
        once per bucket, like the streaming engine's fixed-shape chunks.
        """
        if self.session.backend != "jax-jit":
            return self.session.estimate_many(list(designs))
        from repro import api as _api

        batch = self.session._batch_for(designs)
        m = len(np.asarray(batch.kernel))
        padded = pad_group_batch(
            batch, self.max_batch + 1,     # +1: a home for padding groups
            _next_pow2(max(m, self.max_batch)))
        est = _api._jax_estimate_batch(padded)
        return self.session._rows_from(est, designs)

    # -- helpers ------------------------------------------------------------

    @staticmethod
    def _as_cached(est: "Estimate", design: "Design") -> "Estimate":
        return dataclasses.replace(est, design=design, cached=True)

    def _fail(self, req: _Request, exc: BaseException) -> None:
        with self._lock:
            self._counters["errors"] += 1
            cur = self._inflight.get(req.key)
            if cur is req.future:
                self._inflight.pop(req.key, None)
        if not req.future.done():
            req.future.set_exception(exc)
