"""Model-guided configuration search — the paper's end use-case, on TPU.

The paper's pitch: a fast model over early compiler artifacts lets you
explore the design space without paying for the full build (bitstream there,
a pod reservation here).  ``autotune`` does exactly that: enumerate candidate
knob settings (KV-cache sharding axis, gradient compression, remat policy,
attention tile sizes), *lower + compile on CPU* (seconds per candidate),
predict each candidate's step time with the analytical model, and rank —
no TPU time spent.

Used by examples/autotune_sharding.py and the SPerf hillclimb.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable

import jax

from repro.core import hlo_counter as _hc
from repro.core import predictor as _pred
from repro.core.hbm import TpuParams, TPU_V5E


@dataclasses.dataclass(frozen=True)
class Candidate:
    name: str
    overrides: dict            # ModelConfig field overrides
    train_overrides: dict      # TrainConfig field overrides


@dataclasses.dataclass
class TrialResult:
    candidate: Candidate
    prediction: _pred.StepPrediction
    compile_s: float
    memory_bytes: float | None

    @property
    def t_step(self) -> float:
        return self.prediction.t_step_overlapped

    def summary(self) -> dict:
        p = self.prediction
        return {
            "name": self.candidate.name,
            "t_step_ms": p.t_step_overlapped * 1e3,
            "bottleneck": p.bottleneck,
            "t_compute_ms": p.t_compute * 1e3,
            "t_memory_ms": p.t_memory * 1e3,
            "t_collective_ms": p.t_collective * 1e3,
            "mem_gb": (self.memory_bytes or 0) / 1e9,
            "compile_s": self.compile_s,
        }


def default_candidates(kind: str) -> list[Candidate]:
    out = [Candidate("baseline", {}, {})]
    if kind in ("decode", "long_decode"):
        out += [
            Candidate("kv-heads", {}, {"kv_shard": "heads"}),
            Candidate("kv-seq", {}, {"kv_shard": "seq"}),
        ]
    if kind == "train":
        out += [
            Candidate("grad-bf16", {}, {"grad_compression": "bf16"}),
            Candidate("no-remat", {"remat": False}, {}),
            Candidate("attn-big-tiles", {"attn_block_q": 1024,
                                         "attn_block_kv": 2048}, {}),
        ]
    return out


def run_trial(cfg, shape, mesh, candidate: Candidate,
              hw: TpuParams = TPU_V5E) -> TrialResult:
    """Lower+compile one candidate and predict its step time (no execution)."""
    import time

    from repro.core import hlo as HLO
    from repro.launch.steps import TrainConfig, build_step

    cfg_c = dataclasses.replace(cfg, **candidate.overrides)
    tcfg = TrainConfig(**candidate.train_overrides) \
        if candidate.train_overrides else TrainConfig()
    t0 = time.time()
    built = build_step(cfg_c, shape, mesh, tcfg)
    compiled = built.fn.lower(*built.args).compile()
    dt = time.time() - t0
    text = compiled.as_text()
    pred = _pred.predict(text, HLO.cost_analysis_stats(compiled), hw)
    mem = HLO.memory_analysis_stats(compiled).get("total_bytes")
    return TrialResult(candidate=candidate, prediction=pred, compile_s=dt,
                       memory_bytes=mem)


def autotune(cfg, shape, mesh, candidates: Iterable[Candidate] | None = None,
             hw: TpuParams = TPU_V5E) -> list[TrialResult]:
    """Rank candidates by predicted step time (ascending)."""
    cands = list(candidates) if candidates is not None \
        else default_candidates(shape.kind)
    results = []
    for c in cands:
        try:
            results.append(run_trial(cfg, shape, mesh, c, hw))
        except Exception as e:  # noqa: BLE001 — a failed candidate is data
            print(f"[autotune] {c.name} failed: {type(e).__name__}: {e}")
    results.sort(key=lambda r: r.t_step)
    return results
