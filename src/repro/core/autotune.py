"""Model-guided configuration search — the paper's end use-case, on TPU.

The paper's pitch: a fast model over early compiler artifacts lets you
explore the design space without paying for the full build (bitstream there,
a pod reservation here).  ``autotune`` does exactly that: enumerate candidate
knob settings (KV-cache sharding axis, gradient compression, remat policy,
attention tile sizes), *lower + compile on CPU* (seconds per candidate),
then score and rank **all candidates in one batched pass** of the analytical
model (`hbm.memory_time_batch`) — no TPU time spent.

Compiled-HLO analyses are cached on disk (`cache.HloAnalysisCache`), keyed
by a hash of the full candidate configuration, so re-ranking a design space
(different hardware parameters, resumed runs) skips the compile entirely.

Used by examples/autotune_sharding.py and the SPerf hillclimb.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Mapping

import numpy as np

from repro.core import predictor as _pred
from repro.core.cache import HloAnalysisCache, config_hash
from repro.core.hbm import AccessClass, TpuParams, Traffic, _as_tpu_params
from repro.core import hbm as _hbm


def _hw_fingerprint(hw) -> dict:
    """JSON-able description of the active hardware spec for cache keying.

    A calibrated or swapped memory system must never silently reuse cached
    rankings produced under different hardware, so the spec (a
    ``repro.hw.Hardware``, a legacy ``TpuParams``, or ``None`` for the
    registry default) is folded into every cache key.  The fingerprint is
    canonicalized to the :class:`TpuParams` view (what ``rank_records``
    actually consumes) plus the persisted calibration, so the same
    effective hardware keys identically across every entry point.
    """
    return {"tpu": dataclasses.asdict(_as_tpu_params(hw)),
            "host_factor": float(getattr(hw, "host_factor", 1.0))}


@dataclasses.dataclass(frozen=True)
class Candidate:
    name: str
    overrides: dict            # ModelConfig field overrides
    train_overrides: dict      # TrainConfig field overrides


@dataclasses.dataclass
class TrialResult:
    candidate: Candidate
    prediction: _pred.StepPrediction
    compile_s: float
    memory_bytes: float | None
    cached: bool = False

    @property
    def t_step(self) -> float:
        return self.prediction.t_step_overlapped

    def summary(self) -> dict:
        p = self.prediction
        return {
            "name": self.candidate.name,
            "t_step_ms": p.t_step_overlapped * 1e3,
            "bottleneck": p.bottleneck,
            "t_compute_ms": p.t_compute * 1e3,
            "t_memory_ms": p.t_memory * 1e3,
            "t_collective_ms": p.t_collective * 1e3,
            "mem_gb": (self.memory_bytes or 0) / 1e9,
            "compile_s": self.compile_s,
            "cached": self.cached,
        }


@dataclasses.dataclass(frozen=True)
class TrialFailure:
    """Structured record of one candidate that failed to compile/analyze."""

    candidate: Candidate
    error_type: str
    error_msg: str

    def summary(self) -> dict:
        return {"name": self.candidate.name, "error_type": self.error_type,
                "error_msg": self.error_msg}


class AutotuneResults(list):
    """Ranked ``TrialResult`` list carrying the per-candidate failures.

    Behaves exactly like a plain list of results (so existing callers keep
    working); ``.failures`` holds one :class:`TrialFailure` per candidate
    that could not be analyzed.
    """

    def __init__(self, results=(), failures: list[TrialFailure] = ()):
        super().__init__(results)
        self.failures = list(failures)


def default_candidates(kind: str) -> list[Candidate]:
    out = [Candidate("baseline", {}, {})]
    if kind in ("decode", "long_decode"):
        out += [
            Candidate("kv-heads", {}, {"kv_shard": "heads"}),
            Candidate("kv-seq", {}, {"kv_shard": "seq"}),
        ]
    if kind == "train":
        out += [
            Candidate("grad-bf16", {}, {"grad_compression": "bf16"}),
            Candidate("no-remat", {"remat": False}, {}),
            Candidate("attn-big-tiles", {"attn_block_q": 1024,
                                         "attn_block_kv": 2048}, {}),
        ]
    return out


_CODE_FPR: str | None = None


def _code_fingerprint() -> str:
    """Content hash of the source that determines the lowered HLO.

    Editing repro.launch / repro.models / repro.configs changes what
    build_step compiles for the *same* configuration, so cached analyses
    must not survive such edits.  Hashing a few dozen small files costs
    ~1 ms once per process — negligible next to a compile.
    """
    global _CODE_FPR
    if _CODE_FPR is None:
        import hashlib
        import pathlib

        import repro

        h = hashlib.sha256()
        # kernels/ is included recursively: models lazily route through the
        # Pallas kernels, so a kernel edit changes the compiled step too.
        root = pathlib.Path(next(iter(repro.__path__)))
        for sub in ("launch", "models", "configs", "kernels"):
            for p in sorted((root / sub).rglob("*.py")):
                h.update(str(p.relative_to(root)).encode())
                h.update(p.read_bytes())
        h.update((root / "compat.py").read_bytes())
        _CODE_FPR = h.hexdigest()[:16]
    return _CODE_FPR


def candidate_key(cfg, shape, mesh, candidate: Candidate, hw=None) -> str:
    """Config hash identifying one (model, shape, mesh, candidate, hw) record.

    Salted with the jax version (different compiler, different HLO), the
    analyzer version (different analysis semantics), and a content hash of
    the step-building source (different program for the same config), so
    cached records are invalidated when any of them changes.  The active
    hardware spec is part of the key: a calibrated or swapped memory system
    must not reuse records ranked under different hardware.
    """
    import jax

    from repro.core.hlo_counter import ANALYZER_VERSION

    return config_hash({
        "cfg": dataclasses.asdict(cfg),
        "shape": dataclasses.asdict(shape),
        "mesh": {"shape": dict(getattr(mesh, "shape", {}) or {}),
                 "n_devices": getattr(getattr(mesh, "devices", None),
                                      "size", None)},
        "candidate": {"overrides": candidate.overrides,
                      "train_overrides": candidate.train_overrides},
        "hw": _hw_fingerprint(hw),
    }, salt=f"jax-{jax.__version__}-analyzer-{ANALYZER_VERSION}"
            f"-src-{_code_fingerprint()}")


def analyze_candidate(cfg, shape, mesh, candidate: Candidate,
                      cache: HloAnalysisCache | None = None,
                      hw=None) -> dict:
    """Compiled-HLO analysis record for one candidate (cache-aware).

    Returns a JSON-able dict with the trip-count-aware static counts — all
    the model needs; the HLO text itself is never stored.  ``hw`` enters the
    cache key only (the counts are hardware-independent, the key is not).
    """
    from repro.core import hlo as HLO
    from repro.core import hlo_counter as _hc
    from repro.launch.steps import TrainConfig, build_step

    key = candidate_key(cfg, shape, mesh, candidate, hw)
    if cache is not None:
        rec = cache.get(key)
        if rec is not None:
            return {**rec, "cached": True}

    cfg_c = dataclasses.replace(cfg, **candidate.overrides)
    tcfg = TrainConfig(**candidate.train_overrides) \
        if candidate.train_overrides else TrainConfig()
    t0 = time.time()
    built = build_step(cfg_c, shape, mesh, tcfg)
    compiled = built.fn.lower(*built.args).compile()
    dt = time.time() - t0
    hc = _hc.analyze(compiled.as_text())
    rec = {
        "flops": hc.flops,
        "bytes_by_class": dict(hc.bytes_by_class),
        "collective_wire_bytes": hc.collective_wire_bytes,
        "collective_operand_bytes": hc.collective_operand_bytes,
        "collective_by_kind": dict(hc.collective_by_kind),
        "n_collectives": hc.n_collectives,
        "memory_bytes": HLO.memory_analysis_stats(compiled).get("total_bytes"),
        "xla_cost": HLO.cost_analysis_stats(compiled),
        "compile_s": dt,
        "cached": False,
    }
    if cache is not None:
        cache.put(key, rec)
    return rec


def rank_records(records: list[Mapping], hw: TpuParams | None = None, *,
                 gather_row_bytes: float = 512.0) -> dict[str, np.ndarray]:
    """Score N analysis records in one vectorized pass.

    ``hw`` may be a :class:`TpuParams`, a ``repro.hw.Hardware`` spec, or
    ``None`` (the registry's ``tpu_v5e`` preset).  Returns per-candidate
    arrays: ``t_compute``, ``t_memory``, ``t_collective``, ``t_step``
    (overlapped roofline max) and ``order`` (argsort of ``t_step``,
    ascending — the ranking).
    """
    hw = _as_tpu_params(hw)
    n = len(records)
    class_names = sorted({k for r in records for k in r["bytes_by_class"]})
    by_class = {}
    for name in class_names:
        cls = _pred._CLASS_BY_NAME.get(name, AccessClass.STREAM)
        arr = np.asarray([float(r["bytes_by_class"].get(name, 0.0))
                          for r in records])
        by_class[name] = (cls, arr)

    # Row-granularity differs between stream and non-stream classes exactly
    # like predictor.components_from_cost: score the two groups separately.
    t_memory = np.zeros(n)
    stream = {nm: a for nm, (c, a) in by_class.items()
              if c is AccessClass.STREAM}
    other = {nm: (c, a) for nm, (c, a) in by_class.items()
             if c is not AccessClass.STREAM}
    if stream:
        t_memory = t_memory + _hbm.memory_time_batch(
            {AccessClass.STREAM: sum(stream.values())}, hw, row_bytes=512.0)
    for _, (cls, arr) in sorted(other.items()):
        t_memory = t_memory + _hbm.memory_time_batch(
            {cls: arr}, hw, row_bytes=gather_row_bytes)

    flops = np.asarray([float(r["flops"]) for r in records])
    wire = np.asarray([float(r["collective_wire_bytes"]) for r in records])
    n_coll = np.asarray([float(r["n_collectives"]) for r in records])
    t_compute = flops / hw.peak_flops
    t_collective = wire / (hw.ici_bw * hw.ici_links) + n_coll * hw.ici_hop_latency
    t_step = np.maximum(np.maximum(t_compute, t_memory), t_collective)
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "t_step": t_step,
        "order": np.argsort(t_step, kind="stable"),
    }


def _prediction_from(rec: Mapping, scores: dict, i: int,
                     gather_row_bytes: float) -> _pred.StepPrediction:
    comps = []
    for name, b in sorted(rec["bytes_by_class"].items()):
        cls = _pred._CLASS_BY_NAME.get(name, AccessClass.STREAM)
        row = gather_row_bytes if cls is not AccessClass.STREAM else 512.0
        comps.append(Traffic(cls, float(b), row_bytes=row, name=name))
    return _pred.StepPrediction(
        t_compute=float(scores["t_compute"][i]),
        t_memory=float(scores["t_memory"][i]),
        t_collective=float(scores["t_collective"][i]),
        memory_components=tuple(comps),
        flops=float(rec["flops"]),
        hbm_bytes=float(sum(rec["bytes_by_class"].values())),
        collective_wire_bytes=float(rec["collective_wire_bytes"]),
        collective_operand_bytes=float(rec["collective_operand_bytes"]),
        n_collectives=float(rec["n_collectives"]),
        collective_by_kind=dict(rec["collective_by_kind"]),
        xla_cost=dict(rec.get("xla_cost") or {}),
    )


def run_trial(cfg, shape, mesh, candidate: Candidate,
              hw: TpuParams | None = None,
              cache: HloAnalysisCache | None = None) -> TrialResult:
    """Lower+compile one candidate and predict its step time (no execution)."""
    rec = analyze_candidate(cfg, shape, mesh, candidate, cache, hw)
    scores = rank_records([rec], hw)
    return TrialResult(candidate=candidate,
                       prediction=_prediction_from(rec, scores, 0, 512.0),
                       compile_s=float(rec["compile_s"]),
                       memory_bytes=rec.get("memory_bytes"),
                       cached=bool(rec.get("cached")))


def _autotune(cfg, shape, mesh, candidates: Iterable[Candidate] | None = None,
              hw: TpuParams | None = None, *,
              cache: HloAnalysisCache | bool | None = True,
              gather_row_bytes: float = 512.0) -> AutotuneResults:
    """Rank candidates by predicted step time (ascending).

    Per-candidate compiles go through the on-disk analysis cache (pass
    ``cache=False`` to disable, or an ``HloAnalysisCache`` to control the
    location); the scoring itself is one batched pass over all candidates.

    A candidate whose compile/analysis raises is recorded as a
    :class:`TrialFailure` on the returned list's ``.failures`` instead of
    being silently dropped.  If *every* candidate fails with the same error,
    the failure is environmental rather than candidate-specific and the last
    exception is re-raised — returning an empty ranking there would hide a
    broken toolchain as "no viable designs".
    """
    if cache is True:
        cache = HloAnalysisCache()
    elif cache is False:
        cache = None
    cands = list(candidates) if candidates is not None \
        else default_candidates(shape.kind)
    kept, records, failures = [], [], []
    last_exc: Exception | None = None
    for c in cands:
        try:
            records.append(analyze_candidate(cfg, shape, mesh, c, cache, hw))
            kept.append(c)
        except Exception as e:  # noqa: BLE001 — a failed candidate is data
            failures.append(TrialFailure(c, type(e).__name__, str(e)))
            last_exc = e
            print(f"[autotune] {c.name} failed: {type(e).__name__}: {e}")
    if not records:
        distinct = {(f.error_type, f.error_msg) for f in failures}
        # One candidate failing proves nothing about the toolchain; only an
        # identical error across several candidates is environmental.
        if len(failures) > 1 and len(distinct) == 1:
            raise RuntimeError(
                f"autotune: all {len(failures)} candidates failed with the "
                f"same error (not candidate-specific): "
                f"{failures[0].error_type}: {failures[0].error_msg}"
            ) from last_exc
        return AutotuneResults([], failures)
    scores = rank_records(records, hw, gather_row_bytes=gather_row_bytes)
    return AutotuneResults([
        TrialResult(candidate=kept[i],
                    prediction=_prediction_from(records[i], scores, int(i),
                                                gather_row_bytes),
                    compile_s=float(records[i]["compile_s"]),
                    memory_bytes=records[i].get("memory_bytes"),
                    cached=bool(records[i].get("cached")))
        for i in scores["order"]
    ], failures)
