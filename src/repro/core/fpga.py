"""Hardware parameter sets for the faithful FPGA/DRAM model (paper Table III).

The paper evaluates on an Intel Stratix 10 GX Development Kit with one DDR4
DIMM.  Table III gives the DRAM datasheet values; the BSP/IP parameters
(``burst_cnt``, ``max_th``) come from the generated Verilog (param
BURSTCOUNT_WIDTH / MAX_THREADS).  Defaults below are the values that make the
paper's own numbers self-consistent:

* ``burst_cnt = 4`` -> max transaction = 2**4 * dq * bl = 1024 B, which
  reproduces the paper's measured effective-bandwidth drop from 14.2 GB/s
  (1 LSU) to 10.5 GB/s (many LSUs):  1024 B / (1024/bw + T_row) = 10.7 GB/s.
* ``max_th = 128`` -> the Fig. 5b "max_th knee" appears exactly at stride 7
  for SIMD=16 int accesses (max_reqs = 128*64/(7+1) = 1024 = page).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DramParams:
    """DRAM datasheet values (paper Table II `Datasheet` rows + Table III)."""

    name: str
    f_mem: float        # memory frequency [Hz] (I/O bus clock)
    dq: int             # memory data width [bytes]
    bl: int             # memory burst length [beats]
    t_rcd: float        # row activation time [s]
    t_rp: float         # precharge (row miss) time [s]
    t_wr: float         # write recovery time [s]
    banks: int = 4      # paper SIV: "2GB DDR4 ... 4 memory banks"
    row_bytes: int = 8192  # DDR4 page size per bank

    @property
    def bw_mem(self) -> float:
        """Peak DRAM bandwidth [B/s]: dq * 2 * f_mem (Eq. 2, DDR double rate)."""
        return self.dq * 2.0 * self.f_mem

    @property
    def t_row(self) -> float:
        """Row-miss inter-command delay (Eq. 6): T_RCD + T_RP."""
        return self.t_rcd + self.t_rp

    @property
    def min_burst_bytes(self) -> int:
        """Minimum DRAM burst transaction size: dq * bl."""
        return self.dq * self.bl


@dataclasses.dataclass(frozen=True)
class BspParams:
    """BSP / generated-IP parameters (paper Table II `Verilog` rows)."""

    burst_cnt: int = 4   # BURSTCOUNT_WIDTH: log2(max #min-bursts per transaction)
    max_th: int = 128    # MAX_THREADS: max coalesced threads per request

    def max_transaction_bytes(self, dram: DramParams) -> int:
        """Eq. 5 upper bound: 2**burst_cnt * dq * bl."""
        return (1 << self.burst_cnt) * dram.min_burst_bytes


# The module constants (DDR4_1866, DDR4_2666, DRAM_CONFIGS, STRATIX10_BSP)
# moved to the registry-backed spec layer (repro.hw.presets) in 0.4, warned
# as PEP-562 aliases through 0.5, and are gone as of 0.6 — read the views
# off a registry entry instead: repro.hw.get("stratix10_ddr4_1866")
# .dram_params() / .bsp_params() (or the curated repro.core re-exports).
