"""Compiled-HLO traffic extraction — the `aocl -rtl` report reader analogue.

The paper reads the early compilation report (LSU types) and the generated
Verilog (IP parameters) instead of waiting for the bitstream.  Here we read
``jax.jit(step).lower(...)`` / ``.compile()`` artifacts instead of running on
a pod:

* ``parse_collectives``  -- every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute in the module, with operand/result/wire
  byte counts and group sizes;
* ``classify_module``    -- per-access-class byte shares from opcode-level
  scanning (the LSU-type classification analogue);
* ``module_stats``       -- one-call summary used by the predictor/roofline.

Byte accounting notes:

* ``operand_bytes`` follows the grading formula ("sum operand sizes of every
  collective"); result-shape-derived when operand shapes are not printed.
* ``wire_bytes`` models ring algorithms: AG/A2A move (g-1)/g of the result,
  RS moves (g-1)x the shard, AR moves 2(g-1)/g of the tensor, CP moves the
  full tensor.  The refined roofline uses wire bytes; the baseline table
  reports the formula-mandated operand bytes as well.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# `%name = <shape-or-tuple> opcode(`  — post-optimization HLO instruction
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][a-z0-9\-]*)\(",
    re.MULTILINE,
)
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)


def shape_bytes(shape_str: str) -> float:
    """Bytes of one HLO shape string, e.g. ``bf16[2,16,4096]{2,1,0}``.

    Tuple shapes sum their components."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    kind: str            # base kind without -start/-done suffix
    result_bytes: float
    operand_bytes: float
    wire_bytes: float    # ring-algorithm bytes per participating device
    group_size: int
    raw: str = ""


def _group_size(line: str, default: int = 1) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        num_groups, group_size = map(int, m.groups())
        del num_groups
        if group_size:
            return group_size
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},")[0].strip("{}")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


def _collective_from(kind: str, result_bytes: float, g: int) -> tuple[float, float]:
    """(operand_bytes, wire_bytes) for a collective with result R, group g."""
    g = max(1, g)
    r = result_bytes
    if kind == "all-gather":
        operand = r / g
        wire = r * (g - 1) / g
    elif kind == "reduce-scatter":
        operand = r * g
        wire = r * (g - 1)
    elif kind == "all-reduce":
        operand = r
        wire = 2.0 * r * (g - 1) / g
    elif kind in ("all-to-all", "ragged-all-to-all"):
        operand = r
        wire = r * (g - 1) / g
    elif kind == "collective-broadcast":
        operand = r
        wire = r
    else:  # collective-permute
        operand = r
        wire = r
    return operand, wire


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = re.match(
            r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
            r"([a-z\-]+)(?:-start)?\(",
            line,
        )
        if not m:
            continue
        shape_str, opcode = m.group(1), m.group(2)
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base not in COLLECTIVE_KINDS:
            continue
        if opcode.endswith("-done"):
            continue
        result = shape_bytes(shape_str)
        g = _group_size(line)
        operand, wire = _collective_from(base, result, g)
        ops.append(CollectiveOp(kind=base, result_bytes=result,
                                operand_bytes=operand, wire_bytes=wire,
                                group_size=g, raw=line.strip()[:200]))
    return ops


# opcode -> access class name (DESIGN.md S2 taxonomy)
_OPCODE_CLASS = {
    "gather": "gather", "scatter": "gather",
    "dynamic-slice": "gather", "dynamic-update-slice": "gather",
    "transpose": "strided", "reverse": "strided", "pad": "strided",
    "slice": "strided", "concatenate": "strided", "copy": "strided",
    "sort": "strided",
}


@dataclasses.dataclass
class ModuleStats:
    """Summary of one compiled module's memory/collective structure."""

    class_bytes: dict[str, float]
    collectives: list[CollectiveOp]
    opcode_bytes: dict[str, float]
    n_instructions: int

    @property
    def total_class_bytes(self) -> float:
        return sum(self.class_bytes.values())

    @property
    def collective_operand_bytes(self) -> float:
        return sum(c.operand_bytes for c in self.collectives)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives)

    def collective_bytes_by_kind(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.kind] += c.operand_bytes
        return dict(out)


def classify_module(hlo_text: str) -> ModuleStats:
    """Scan every instruction (fusion bodies included) and attribute its
    result bytes to an access class.

    This yields byte *shares* per class; the predictor rescales shares to the
    exact total from ``compiled.cost_analysis()['bytes accessed']`` so that
    totals are authoritative while the split reflects the module's access
    patterns (DESIGN.md S2)."""
    class_bytes: dict[str, float] = defaultdict(float)
    opcode_bytes: dict[str, float] = defaultdict(float)
    n = 0
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, opcode = m.group(1), m.group(2)
        if opcode in ("parameter", "constant", "tuple", "get-tuple-element"):
            continue
        b = shape_bytes(shape_str)
        n += 1
        opcode_bytes[opcode] += b
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in COLLECTIVE_KINDS:
            continue  # counted separately
        cls = _OPCODE_CLASS.get(base, "stream")
        class_bytes[cls] += b
    return ModuleStats(
        class_bytes=dict(class_bytes),
        collectives=parse_collectives(hlo_text),
        opcode_bytes=dict(opcode_bytes),
        n_instructions=n,
    )


def cost_analysis_stats(compiled) -> dict[str, float]:
    """Extract flops / bytes from ``compiled.cost_analysis()`` robustly."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        v = ca.get(k)
        if v is not None and not (isinstance(v, float) and math.isnan(v)):
            out[k.replace(" ", "_")] = float(v)
    # per-memory-space byte entries like 'bytes accessed0{}' / 'bytes accessedout{}'
    for k, v in ca.items():
        if k.startswith("bytes accessed") and k != "bytes accessed":
            out[("bytes_" + k[len("bytes accessed"):]).strip()] = float(v)
    return out


def memory_analysis_stats(compiled) -> dict[str, float]:
    """Extract per-device memory footprint from ``compiled.memory_analysis()``."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "host_argument_size_in_bytes",
                 "host_output_size_in_bytes", "host_temp_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    if out:
        out["total_bytes"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0)
        )
    return out
