"""LSU taxonomy of the Intel FPGA SDK Global Memory Interconnect (paper Table I).

Each *global access* (GA) in the OpenCL source is translated by the HLS
compiler into one or several Load/Store Units.  The LSU type is decided by a
static analysis of the index expression:

=====================  ==========  =====  ======  =============================
LSU type               Pipelined   Burst  Atomic  index pattern
=====================  ==========  =====  ======  =============================
BC_ALIGNED             yes         yes    --      ``x[i]`` contiguous, page-aligned
BC_NON_ALIGNED         yes         yes    --      ``x[3*i+1]`` strided / offset
BC_WRITE_ACK           yes         yes    --      ``x[j]`` data-dependent index
BC_CACHE               yes         yes    --      repeated data-dependent index
PREFETCHING            --          yes    --      compiled as BC_ALIGNED (high-end)
CONSTANT_PIPELINED     yes         --     --      ``cn[i]`` constant cache (on-chip)
PIPELINED              yes         --     --      local-memory access (on-chip)
ATOMIC_PIPELINED       yes         --     yes     ``atomic_add(&x[0], 1)``
=====================  ==========  =====  ======  =============================

Only the GMI types (burst-coalesced family + atomic) touch DRAM and are
modelled; the on-chip types never reach the memory controller.
"""
from __future__ import annotations

import dataclasses
import enum


class LsuType(enum.Enum):
    BC_ALIGNED = "bc_aligned"
    BC_NON_ALIGNED = "bc_non_aligned"
    BC_WRITE_ACK = "bc_write_ack"
    BC_CACHE = "bc_cache"
    PREFETCHING = "prefetching"
    CONSTANT_PIPELINED = "constant_pipelined"
    PIPELINED = "pipelined"
    ATOMIC_PIPELINED = "atomic_pipelined"

    @property
    def is_global(self) -> bool:
        """True if this LSU issues DRAM traffic through the GMI."""
        return self in _GLOBAL_TYPES

    @property
    def is_burst(self) -> bool:
        return self in (
            LsuType.BC_ALIGNED,
            LsuType.BC_NON_ALIGNED,
            LsuType.BC_WRITE_ACK,
            LsuType.BC_CACHE,
            LsuType.PREFETCHING,
        )


_GLOBAL_TYPES = frozenset(
    {
        LsuType.BC_ALIGNED,
        LsuType.BC_NON_ALIGNED,
        LsuType.BC_WRITE_ACK,
        LsuType.BC_CACHE,
        LsuType.PREFETCHING,
        LsuType.ATOMIC_PIPELINED,
    }
)


@dataclasses.dataclass(frozen=True)
class Lsu:
    """One load/store unit, as read from the early compilation report.

    Attributes mirror paper Table II (``Report``/``Verilog``/``User`` rows):

    * ``lsu_type``  -- from the HTML report (``aocl -rtl``).
    * ``ls_width``  -- memory width of the LSU in bytes; SIMD vectorization by
      factor ``f`` widens the LSU: ``ls_width = f * elem_bytes`` (except
      WRITE_ACK/atomic, where the compiler instead replicates the LSU).
    * ``ls_acc``    -- number of accesses this LSU performs (dynamic count;
      user-supplied for dynamic loops, inferable otherwise).
    * ``ls_bytes``  -- bytes of a single access.
    * ``delta``     -- address stride of the access pattern (1 = contiguous).
    * ``is_write``  -- direction (read/write arbiters are independent).
    * ``val_constant`` -- atomic only: the summed value is loop-constant, so
      the compiler merges ``f`` atomic updates into one (Eq. 10 `/f` case).
    """

    lsu_type: LsuType
    ls_width: int
    ls_acc: int
    ls_bytes: int
    delta: int = 1
    is_write: bool = False
    val_constant: bool = False
    name: str = ""
    # Address footprint of the accessed array [bytes].  Only used by the
    # simulator oracle (row-locality of data-dependent accesses); defaults to
    # the streamed extent.
    span_bytes: int | None = None

    def __post_init__(self):
        if self.ls_width <= 0 or self.ls_bytes <= 0:
            raise ValueError(f"LSU {self.name}: widths must be positive")
        if self.ls_acc < 0:
            raise ValueError(f"LSU {self.name}: ls_acc must be >= 0")
        if self.delta < 1:
            raise ValueError(f"LSU {self.name}: delta (stride) must be >= 1")
        if self.lsu_type is LsuType.ATOMIC_PIPELINED and self.delta != 1:
            raise ValueError("atomic-pipelined LSUs always have stride 1")

    @property
    def total_bytes(self) -> int:
        """Useful bytes this LSU moves: ls_acc * ls_bytes."""
        return self.ls_acc * self.ls_bytes


def make_global_access(
    lsu_type: LsuType,
    *,
    n_elems: int,
    elem_bytes: int = 4,
    f: int = 1,
    delta: int = 1,
    is_write: bool = False,
    val_constant: bool = False,
    name: str = "",
) -> list[Lsu]:
    """Expand one source-level *global access* into its LSU list.

    Mirrors the compiler behaviour described in the paper:

    * burst-coalesced aligned / non-aligned: one LSU whose ``ls_width`` is
      widened by the vectorization factor ``f`` (SIMD * unroll) and that
      performs ``n_elems / f`` vector accesses;
    * burst-coalesced write-ACK: ``ls_width`` stays at ``elem_bytes``; the
      compiler instead instantiates ``f`` LSUs per GA (paper SV-A3: "the
      compiler generates so many LSU as the desired SIMD by each global
      access"), each covering ``n_elems / f`` scalar accesses;
    * atomic-pipelined: like write-ACK, width never grows; one LSU per GA
      (atomics serialize; ``f`` enters via Eq. 10 instead).
    """
    if n_elems % max(f, 1):
        raise ValueError("n_elems must be divisible by the vectorization factor")
    if lsu_type in (LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED, LsuType.PREFETCHING, LsuType.BC_CACHE):
        return [
            Lsu(
                lsu_type=LsuType.BC_ALIGNED if lsu_type is LsuType.PREFETCHING else lsu_type,
                ls_width=f * elem_bytes,
                ls_acc=n_elems // f,
                ls_bytes=f * elem_bytes,
                delta=delta,
                is_write=is_write,
                name=name,
            )
        ]
    if lsu_type is LsuType.BC_WRITE_ACK:
        return [
            Lsu(
                lsu_type=lsu_type,
                ls_width=elem_bytes,
                ls_acc=n_elems // f,
                ls_bytes=elem_bytes,
                delta=delta,
                is_write=is_write,
                name=f"{name}[{k}]" if name else "",
            )
            for k in range(f)
        ]
    if lsu_type is LsuType.ATOMIC_PIPELINED:
        return [
            Lsu(
                lsu_type=lsu_type,
                ls_width=elem_bytes,
                ls_acc=n_elems,
                ls_bytes=elem_bytes,
                delta=1,
                is_write=True,
                val_constant=val_constant,
                name=name,
            )
        ]
    raise ValueError(f"{lsu_type} is an on-chip LSU; it has no GMI traffic")
