"""Vectorized design-space sweeps over the paper's analytical model.

The paper's pitch is *fast* exploration: the closed-form Eqs. 1-10 exist so
thousands of candidate designs can be scored without building any of them.
This module turns the array core (:mod:`repro.core.model_batch`) into that
workflow: describe a design space over the SIV microbenchmark knobs — LSU
type, number of global accesses, SIMD width, input size, stride, element
size, DRAM part, BSP variant — and score every point in one pass.

The public entry points are :class:`repro.Space` and
``repro.Session.sweep``:

    >>> from repro import Session, Space
    >>> res = Session().sweep(Space.grid(
    ...     lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK],
    ...     n_ga=[1, 2, 4], simd=[1, 4, 16],
    ...     delta=[1, 2, 4], dram=[DDR4_1866, DDR4_2666]))
    >>> best = res.top_k(5)
    >>> front = res.pareto()          # time vs interconnect-width cost

``sweep_grid``/``sweep_random`` below are deprecated aliases of that path,
kept for one release.  Every design point maps to exactly the LSU list
`apps.microbench` would build, so batched results match the scalar
estimate path element-wise (tested to rtol 1e-6 in tests/test_sweep.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.core import model_batch as _mb
from repro.core.fpga import BspParams, DramParams
from repro.core.lsu import LsuType
from repro.deprecation import warn_deprecated

#: Sweepable axes, in canonical order.  ``lsu_type``/``dram``/``bsp``/
#: ``hardware`` are categorical; the rest are numeric.  A ``hardware`` axis
#: value is a :class:`repro.hw.Hardware` spec (or ``None``): its DRAM/BSP
#: views and persisted calibration override the ``dram``/``bsp`` axes at
#: that point, so a single sweep fans out over (design x memory system).
AXES = ("lsu_type", "n_ga", "simd", "n_elems", "delta", "elem_bytes",
        "include_write", "val_constant", "dram", "bsp", "hardware")

_CATEGORICAL = {"lsu_type", "dram", "bsp", "hardware"}


def _as_list(v) -> list:
    if isinstance(v, (list, tuple)):
        return list(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    return [v]


def pareto_front(values: np.ndarray) -> np.ndarray:
    """Indices of the Pareto-minimal rows of ``values`` [N, d].

    A row dominates another if it is <= in every objective and < in at least
    one.  Duplicated non-dominated rows are all kept.  The returned indices
    are sorted ascending, and the *set* of selected points is invariant under
    any permutation of the input rows.
    """
    vals = np.asarray(values, dtype=np.float64)
    if vals.ndim == 1:
        vals = vals[:, None]
    n = len(vals)
    if n == 0:
        return np.empty(0, dtype=np.int64)
    # Lexicographic order makes any dominator of row i appear before i, so a
    # single forward scan against the kept front is complete.
    order = np.lexsort(tuple(vals[:, d] for d in range(vals.shape[1] - 1, -1, -1)))
    # The front lives in a preallocated [n, d] buffer filled left to right;
    # each candidate is checked against the fv[:m] *view*, so keeping a point
    # is O(F) instead of the former copy-the-front-per-point O(F^2).
    fv = np.empty_like(vals)
    m = 0
    keep: list[int] = []
    for idx in order:
        v = vals[idx]
        if m:
            front = fv[:m]
            if np.any((front <= v).all(axis=1) & (front < v).any(axis=1)):
                continue
        fv[m] = v
        m += 1
        keep.append(int(idx))
    return np.asarray(sorted(keep), dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Scored design space: per-point config values + batched model output."""

    points: dict[str, np.ndarray]     # axis -> per-point values [N]
    estimate: _mb.BatchEstimate
    resource: np.ndarray              # total LSU interconnect width [B] per point

    @property
    def n_points(self) -> int:
        return int(len(self.resource))

    @property
    def t_exe(self) -> np.ndarray:
        return np.asarray(self.estimate.t_exe)

    @property
    def memory_bound(self) -> np.ndarray:
        return np.asarray(self.estimate.memory_bound)

    @property
    def effective_bandwidth(self) -> np.ndarray:
        return np.asarray(self.estimate.effective_bandwidth)

    def pareto(self, objectives: Sequence[Any] | None = None) -> np.ndarray:
        """Indices of the Pareto front, minimizing every objective.

        Default objectives: predicted time vs. total LSU width (the
        interconnect/resource cost of the design).  Pass an explicit list of
        arrays or names in (``t_exe``, ``resource``, ``bound_ratio``,
        ``total_bytes``) to change the trade-off.
        """
        if objectives is None:
            objectives = ["t_exe", "resource"]
        cols = []
        for obj in objectives:
            if isinstance(obj, str):
                if obj == "t_exe":
                    cols.append(self.t_exe)
                elif obj == "resource":
                    cols.append(self.resource)
                elif obj == "bound_ratio":
                    cols.append(np.asarray(self.estimate.bound_ratio))
                elif obj == "total_bytes":
                    cols.append(np.asarray(self.estimate.total_bytes))
                else:
                    raise KeyError(f"unknown objective {obj!r}")
            else:
                cols.append(np.asarray(obj, dtype=np.float64))
        return pareto_front(np.stack(cols, axis=1))

    def top_k(self, k: int = 10, key: str = "t_exe") -> list[dict]:
        """The ``k`` best rows by ``key`` (ascending), as config dicts."""
        vals = {"t_exe": self.t_exe, "resource": self.resource}[key] \
            if key in ("t_exe", "resource") else np.asarray(getattr(self.estimate, key))
        idx = np.argsort(vals, kind="stable")[:k]
        return self.rows(idx)

    def rows(self, indices: Sequence[int] | None = None) -> list[dict]:
        """CSV-ready dict rows for the selected (default: all) points."""
        est = self.estimate
        ebw = self.effective_bandwidth
        if indices is None:
            indices = range(self.n_points)
        out = []
        for i in indices:
            i = int(i)
            row = {}
            for name, vals in self.points.items():
                v = vals[i]
                if name == "lsu_type":
                    v = LsuType(v).value if not isinstance(v, LsuType) else v.value
                elif name == "bsp":
                    v = _bsp_name(v)
                elif name == "dram":
                    v = getattr(v, "name", repr(v))
                elif name == "hardware":
                    v = getattr(v, "name", "") if v is not None else ""
                elif isinstance(v, (np.integer, np.bool_)):
                    v = v.item()
                row[name] = v
            row.update(
                t_exe_ms=float(est.t_exe[i]) * 1e3,
                t_ovh_ms=float(est.t_ovh[i]) * 1e3,
                bound_ratio=float(est.bound_ratio[i]),
                memory_bound=bool(est.memory_bound[i]),
                eff_bw_gbs=float(ebw[i]) / 1e9,
                resource_bytes=float(self.resource[i]),
            )
            out.append(row)
        return out


def _bsp_name(b: BspParams) -> str:
    return f"bsp(burst_cnt={b.burst_cnt},max_th={b.max_th})"


def _factorize(objs) -> tuple[list, np.ndarray]:
    """(unique objects, per-row codes) — attribute extraction then runs per
    unique value instead of per design point (the batched-path hotspot)."""
    table: list = []
    index: dict[int, int] = {}
    codes = np.empty(len(objs), dtype=np.int64)
    for i, o in enumerate(objs):
        j = index.get(id(o))
        if j is None:
            j = index[id(o)] = len(table)
            table.append(o)
        codes[i] = j
    return table, codes


def _apply_hardware_axis(points: dict[str, np.ndarray], n: int,
                         ) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Resolve the ``hardware`` axis into effective dram/bsp columns.

    Points whose hardware spec is not ``None`` get that spec's DRAM/BSP
    views in their ``dram``/``bsp`` columns (so reported configurations
    describe what was actually scored) and its persisted ``host_factor`` in
    the returned per-point scale array.  Views are constructed once per
    unique spec, so downstream ``_factorize`` dedup still works.  Shared by
    ``_build`` and the scalar Session backend — the two paths must resolve
    identically for backend equivalence to hold.
    """
    hw_col = points.get("hardware")
    scale = np.ones(n)
    if hw_col is None or all(h is None for h in hw_col):
        return points, scale
    views: dict[int, tuple] = {}
    dram_col = np.asarray(points["dram"], dtype=object).copy()
    bsp_col = np.asarray(points["bsp"], dtype=object).copy()
    for i, h in enumerate(hw_col):
        if h is None:
            continue
        v = views.get(id(h))
        if v is None:
            v = views[id(h)] = (h.dram_params(), h.bsp_params(),
                                float(h.host_factor))
        dram_col[i], bsp_col[i], scale[i] = v
    return {**points, "dram": dram_col, "bsp": bsp_col}, scale


def _normalize_inert_axes(points: dict[str, np.ndarray],
                          is_atomic: np.ndarray,
                          is_ack: np.ndarray) -> dict[str, np.ndarray]:
    """Normalize axes that are inert for a point's LSU type.

    Stride is inert for ACK/atomic, ``val_constant`` for non-atomics, and
    ``include_write`` for atomics (the atomic *is* the write), so reported
    configs describe exactly what was scored; grid products over inert axes
    thus show up as *visibly* identical rows rather than phantom distinct
    designs.  Shared by ``_build`` and the scalar Session backend — the two
    paths must normalize identically for backend equivalence to hold.
    """
    delta = np.where(is_atomic | is_ack, 1,
                     np.asarray(points["delta"], dtype=np.int64))
    val_constant = np.asarray(points["val_constant"], dtype=bool) & is_atomic
    include_write = (np.asarray(points["include_write"], dtype=bool)
                     & ~is_atomic)
    return {**points, "delta": delta, "val_constant": val_constant,
            "include_write": include_write}


def _build(points: dict[str, np.ndarray], n: int,
           cats: dict[str, tuple[list, np.ndarray]] | None = None,
           estimator: Callable[[_mb.GroupBatch], _mb.BatchEstimate] | None = None,
           ) -> SweepResult:
    """Score ``n`` design points described by per-point axis arrays.

    ``estimator`` maps the assembled :class:`model_batch.GroupBatch` to a
    :class:`model_batch.BatchEstimate`; it defaults to the NumPy array core
    and is how ``Session`` backends (jax-jit) plug into the same expansion.

    Each point expands to the LSU list ``apps.microbench`` would build,
    expressed as at most two homogeneous LSU *groups* per point:

    * burst-coalesced aligned/non-aligned/cache: one group of
      ``n_ga + include_write`` identical LSUs;
    * write-ACK: a group of ``n_ga`` aligned reads plus a group of ``simd``
      scalar ACK stores (the compiler replicates the store LSU);
    * atomic: a group of ``n_ga`` atomic units (stride is always 1).
    """
    cats = cats or {}
    points, hw_scale = _apply_hardware_axis(points, n)
    if np.any(hw_scale != 1.0) or (points.get("hardware") is not None
                                   and any(h is not None
                                           for h in points["hardware"])):
        # dram/bsp columns were rewritten per point; the precomputed
        # factorizations no longer describe them.
        cats = {k: v for k, v in cats.items() if k not in ("dram", "bsp")}

    def _cat(name):
        if name in cats:
            return cats[name]
        return _factorize(points[name])

    type_table, type_idx = _cat("lsu_type")
    type_codes = np.asarray([_mb.TYPE_CODE[t] for t in type_table],
                            dtype=np.int64)[type_idx]
    n_ga = np.asarray(points["n_ga"], dtype=np.int64)
    simd = np.asarray(points["simd"], dtype=np.int64)
    n_elems = np.asarray(points["n_elems"], dtype=np.int64)
    delta = np.asarray(points["delta"], dtype=np.int64)
    elem_bytes = np.asarray(points["elem_bytes"], dtype=np.int64)
    include_write = np.asarray(points["include_write"], dtype=bool)
    val_constant = np.asarray(points["val_constant"], dtype=bool)
    dram_table, dram_idx = _cat("dram")
    bsp_table, bsp_idx = _cat("bsp")

    if np.any(n_ga < 1) or np.any(simd < 1) or np.any(delta < 1):
        raise ValueError("n_ga, simd and delta must be >= 1")
    if np.any(n_elems % simd):
        raise ValueError("n_elems must be divisible by simd at every point")

    is_atomic = type_codes == _mb.ATOMIC
    is_ack = type_codes == _mb.WRITE_ACK

    points = _normalize_inert_axes(points, is_atomic, is_ack)
    delta = points["delta"]
    val_constant = points["val_constant"]
    include_write = points["include_write"]

    # Group 1: the read side (plus the same-type write for plain BC types).
    g1_type = np.where(is_ack, _mb.ALIGNED, type_codes)
    g1_count = np.where(is_atomic | is_ack, n_ga, n_ga + include_write)
    g1_width = np.where(is_atomic, elem_bytes, simd * elem_bytes)
    g1_acc = np.where(is_atomic, n_elems, n_elems // simd)
    g1_delta = delta                      # already normalized above

    # Group 2: the replicated write-ACK store LSUs (count 0 elsewhere).
    g2_count = np.where(is_ack & include_write, simd, 0)

    kernel = np.concatenate([np.arange(n), np.arange(n)])
    vec = np.concatenate
    dram_f = {k: np.asarray([getattr(d, k) for d in dram_table])[dram_idx]
              for k in ("dq", "bl", "f_mem", "t_rcd", "t_rp", "t_wr")}
    bsp_f = {k: np.asarray([getattr(b, k) for b in bsp_table])[bsp_idx]
             for k in ("burst_cnt", "max_th")}

    batch = _mb.GroupBatch(
        kernel=kernel,
        n_kernels=n,
        count=vec([g1_count, g2_count]),
        lsu_type=vec([g1_type, np.full(n, _mb.WRITE_ACK, dtype=np.int64)]),
        ls_width=vec([g1_width, elem_bytes]),
        ls_acc=vec([g1_acc, n_elems // simd]),
        ls_bytes=vec([g1_width, elem_bytes]),
        delta=vec([g1_delta, np.ones(n, dtype=np.int64)]),
        val_constant=vec([val_constant, np.zeros(n, dtype=bool)]),
        f=vec([simd, simd]),
        **{k: vec([v, v]) for k, v in {**dram_f, **bsp_f}.items()},
    )
    est = (estimator or _mb.estimate_batch)(batch)
    if np.any(hw_scale != 1.0):
        # apply each point's persisted hardware calibration (host_factor)
        est = dataclasses.replace(
            est, t_exe=np.asarray(est.t_exe) * hw_scale,
            t_ideal=np.asarray(est.t_ideal) * hw_scale,
            t_ovh=np.asarray(est.t_ovh) * hw_scale)
    resource = np.bincount(kernel,
                           weights=np.asarray(batch.count * batch.ls_width,
                                              dtype=np.float64),
                           minlength=n)
    return SweepResult(points=points, estimate=est, resource=resource)


def _normalize_axes(overrides: Mapping[str, Any]) -> dict[str, list]:
    from repro.hw import DEFAULT_BOARD, get as _hw_get

    board = _hw_get(DEFAULT_BOARD)
    defaults = {
        "lsu_type": LsuType.BC_ALIGNED,
        "n_ga": 1,
        "simd": 16,
        "n_elems": 1 << 22,
        "delta": 1,
        "elem_bytes": 4,
        "include_write": True,
        "val_constant": False,
        "dram": board.dram_params(),
        "bsp": board.bsp_params(),
        "hardware": None,
    }
    unknown = set(overrides) - set(AXES)
    if unknown:
        raise KeyError(f"unknown sweep axes: {sorted(unknown)}")
    return {k: _as_list(overrides.get(k, defaults[k])) for k in AXES}


def _grid_points(axes: Mapping[str, Any],
                 ) -> tuple[dict[str, np.ndarray], int,
                            dict[str, tuple[list, np.ndarray]]]:
    """Per-point axis arrays for the full Cartesian product of ``axes``."""
    lists = _normalize_axes(axes)
    sizes = [len(v) for v in lists.values()]
    n = int(np.prod(sizes))
    if n == 0:
        raise ValueError("empty sweep: every axis needs at least one value")
    grids = np.meshgrid(*[np.arange(s) for s in sizes], indexing="ij")
    points: dict[str, np.ndarray] = {}
    cats: dict[str, tuple[list, np.ndarray]] = {}
    for (name, vals), g in zip(lists.items(), grids):
        idx = g.reshape(-1)
        if name in _CATEGORICAL:
            points[name] = np.asarray(vals, dtype=object)[idx]
            cats[name] = (vals, idx)
        else:
            points[name] = np.asarray(vals)[idx]
    return points, n, cats


def _random_points(n: int, seed: int, axes: Mapping[str, Any],
                   ) -> tuple[dict[str, np.ndarray], int,
                              dict[str, tuple[list, np.ndarray]]]:
    """Per-point axis arrays for ``n`` uniformly sampled design points.

    Numeric axes given as a 2-tuple ``(lo, hi)`` are sampled as integers in
    the inclusive range; any axis given as a list is sampled uniformly from
    it; scalars are held fixed.  Each ``n_elems`` sample is rounded down to
    a multiple of *that point's own* ``simd`` (floored at ``simd``), so the
    sampled values stay inside the requested range whenever it contains any
    multiple of the point's simd — rounding to the global LCM of all sampled
    simd values could leave the range entirely.
    """
    rng = np.random.default_rng(seed)
    tuples = {k: v for k, v in axes.items()
              if isinstance(v, tuple) and len(v) == 2
              and k not in _CATEGORICAL and not isinstance(v[0], (LsuType,))}
    lists = _normalize_axes({k: v for k, v in axes.items() if k not in tuples})

    points: dict[str, np.ndarray] = {}
    cats: dict[str, tuple[list, np.ndarray]] = {}
    for name in AXES:
        if name in tuples:
            lo, hi = tuples[name]
            points[name] = rng.integers(int(lo), int(hi) + 1, size=n)
        else:
            vals = lists[name]
            idx = rng.integers(0, len(vals), size=n)
            if name in _CATEGORICAL:
                points[name] = np.asarray(vals, dtype=object)[idx]
                cats[name] = (vals, idx)
            else:
                points[name] = np.asarray(vals)[idx]
    simd = np.asarray(points["simd"], dtype=np.int64)
    n_elems = np.asarray(points["n_elems"], dtype=np.int64)
    points["n_elems"] = np.maximum((n_elems // simd) * simd, simd)
    return points, n, cats


def sweep_grid(**axes) -> SweepResult:
    """Deprecated: use ``repro.Session().sweep(repro.Space.grid(**axes))``.

    Scores the full Cartesian product of the given axes in one pass.  Every
    axis (see ``AXES``) accepts a single value or a sequence; stride applies
    to the burst-coalesced aligned/non-aligned types only (write-ACK reads
    and atomics are stride-1 by construction, like ``apps.microbench``).
    """
    warn_deprecated("repro.core.sweep.sweep_grid()",
                    "repro.Session().sweep(repro.Space.grid(...))")
    return _build(*_grid_points(axes))


def sweep_random(n: int, *, seed: int = 0, **axes) -> SweepResult:
    """Deprecated: use ``repro.Session().sweep(repro.Space.random(n, ...))``.

    Scores ``n`` uniformly sampled design points (see ``_random_points`` for
    the sampling rules).
    """
    warn_deprecated("repro.core.sweep.sweep_random()",
                    "repro.Session().sweep(repro.Space.random(n, ...))")
    return _build(*_random_points(n, seed, axes))
