"""Vectorized design-space sweeps over the paper's analytical model.

The paper's pitch is *fast* exploration: the closed-form Eqs. 1-10 exist so
thousands of candidate designs can be scored without building any of them.
This module turns the array core (:mod:`repro.core.model_batch`) into that
workflow: describe a design space over the SIV microbenchmark knobs — LSU
type, number of global accesses, SIMD width, input size, stride, element
size, DRAM part, BSP variant — and score every point in one pass.

The public entry points are :class:`repro.Space` and
``repro.Session.sweep``:

    >>> from repro import Session, Space
    >>> res = Session().sweep(Space.grid(
    ...     lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK],
    ...     n_ga=[1, 2, 4], simd=[1, 4, 16],
    ...     delta=[1, 2, 4], dram=[DDR4_1866, DDR4_2666]))
    >>> best = res.top_k(5)
    >>> front = res.pareto()          # time vs interconnect-width cost

Design points are described by integer codes end-to-end: every categorical
axis (LSU type, DRAM part, BSP variant, hardware spec) is factorized once
into a ``(table, codes)`` pair and per-point values are table gathers, so
the hot path never touches an object-dtype array.  The same scoring core
(:func:`_score`) backs both the materialized path below and the
bounded-memory streaming path (:mod:`repro.core.stream` +
``Space.grid(...).stream()``), which is how million-point spaces are swept.

Every design point maps to exactly the LSU list `apps.microbench` would
build, so batched results match the scalar estimate path element-wise
(tested to rtol 1e-6 in tests/test_sweep.py).
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.core import model_batch as _mb
from repro.core.fpga import BspParams
from repro.core.lsu import LsuType

#: Sweepable axes, in canonical order.  ``lsu_type``/``dram``/``bsp``/
#: ``hardware`` are categorical; the rest are numeric.  A ``hardware`` axis
#: value is a :class:`repro.hw.Hardware` spec (or ``None``): its DRAM/BSP
#: views and persisted calibration override the ``dram``/``bsp`` axes at
#: that point, so a single sweep fans out over (design x memory system).
AXES = ("lsu_type", "n_ga", "simd", "n_elems", "delta", "elem_bytes",
        "include_write", "val_constant", "dram", "bsp", "hardware")

_CATEGORICAL = {"lsu_type", "dram", "bsp", "hardware"}
_NUMERIC = tuple(a for a in AXES if a not in _CATEGORICAL)


def _as_list(v) -> list:
    if isinstance(v, (list, tuple)):
        return list(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    return [v]


def _object_array(values) -> np.ndarray:
    """1-D object array from a list (safe for dataclass/None elements)."""
    arr = np.empty(len(values), dtype=object)
    arr[:] = list(values)
    return arr


def _pareto_scan(vals: np.ndarray) -> np.ndarray:
    """Reference O(N·F) front: lexsort + per-candidate scan (any dimension).

    This was the only implementation before the streaming engine landed;
    it is kept both as the d != 2 fallback and as the measured baseline of
    ``benchmarks/sweep_bench.py`` (the "materialize everything, then scan"
    legacy cost).
    """
    n = len(vals)
    # Lexicographic order makes any dominator of row i appear before i, so a
    # single forward scan against the kept front is complete.
    order = np.lexsort(tuple(vals[:, d] for d in range(vals.shape[1] - 1, -1, -1)))
    # The front lives in a preallocated [n, d] buffer filled left to right;
    # each candidate is checked against the fv[:m] *view*, so keeping a point
    # is O(F) instead of a copy-the-front-per-point O(F^2).
    fv = np.empty_like(vals)
    m = 0
    keep: list[int] = []
    for idx in order:
        v = vals[idx]
        if m:
            front = fv[:m]
            if np.any((front <= v).all(axis=1) & (front < v).any(axis=1)):
                continue
        fv[m] = v
        m += 1
        keep.append(int(idx))
    return np.asarray(sorted(keep), dtype=np.int64)


def _pareto_2d(vals: np.ndarray) -> np.ndarray:
    """Fully vectorized 2-objective front, O(N log N), no Python loop.

    Sort by (v0, v1); a row is dominated iff some row in a strictly
    smaller v0 group has v1 <= its own (strict v0 makes the domination
    strict), or a row in its *own* v0 group has strictly smaller v1.
    Duplicated non-dominated rows all survive, exactly like the scan.
    """
    n = len(vals)
    order = np.lexsort((vals[:, 1], vals[:, 0]))
    v0 = vals[order, 0]
    v1 = vals[order, 1]
    new_group = np.empty(n, dtype=bool)
    new_group[0] = True
    new_group[1:] = v0[1:] != v0[:-1]
    start = np.maximum.accumulate(np.where(new_group, np.arange(n), 0))
    gmin = v1[start]                       # group min (v1 ascending in group)
    cm = np.minimum.accumulate(v1)         # min v1 over all earlier rows
    prev_end = start - 1                   # last row of the previous group
    m_strict = np.where(prev_end >= 0, cm[np.maximum(prev_end, 0)], np.inf)
    dominated = (m_strict <= v1) | (gmin < v1)
    return np.sort(order[~dominated]).astype(np.int64)


def pareto_front(values: np.ndarray) -> np.ndarray:
    """Indices of the Pareto-minimal rows of ``values`` [N, d].

    A row dominates another if it is <= in every objective and < in at least
    one.  Duplicated non-dominated rows are all kept.  The returned indices
    are sorted ascending, and the *set* of selected points is invariant under
    any permutation of the input rows.

    The 2-objective case (the default time-vs-resource trade-off) runs a
    fully vectorized O(N log N) pass — this is what lets the streaming
    reducers fold million-point sweeps without a per-point Python loop;
    higher dimensions fall back to the lexsort + scan reference.
    """
    vals = np.asarray(values, dtype=np.float64)
    if vals.ndim == 1:
        vals = vals[:, None]
    if len(vals) == 0:
        return np.empty(0, dtype=np.int64)
    if vals.shape[1] == 2:
        return _pareto_2d(vals)
    return _pareto_scan(vals)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Scored design space: per-point config values + batched model output."""

    points: dict[str, np.ndarray]     # axis -> per-point values [N]
    estimate: _mb.BatchEstimate
    resource: np.ndarray              # total LSU interconnect width [B] per point

    @property
    def n_points(self) -> int:
        return int(len(self.resource))

    @property
    def t_exe(self) -> np.ndarray:
        return np.asarray(self.estimate.t_exe)

    @property
    def memory_bound(self) -> np.ndarray:
        return np.asarray(self.estimate.memory_bound)

    @property
    def effective_bandwidth(self) -> np.ndarray:
        return np.asarray(self.estimate.effective_bandwidth)

    def pareto(self, objectives: Sequence[Any] | None = None) -> np.ndarray:
        """Indices of the Pareto front, minimizing every objective.

        Default objectives: predicted time vs. total LSU width (the
        interconnect/resource cost of the design).  Pass an explicit list of
        arrays or names in (``t_exe``, ``resource``, ``bound_ratio``,
        ``total_bytes``) to change the trade-off.
        """
        if objectives is None:
            objectives = ["t_exe", "resource"]
        cols = []
        for obj in objectives:
            if isinstance(obj, str):
                if obj == "t_exe":
                    cols.append(self.t_exe)
                elif obj == "resource":
                    cols.append(self.resource)
                elif obj == "bound_ratio":
                    cols.append(np.asarray(self.estimate.bound_ratio))
                elif obj == "total_bytes":
                    cols.append(np.asarray(self.estimate.total_bytes))
                else:
                    raise KeyError(f"unknown objective {obj!r}")
            else:
                cols.append(np.asarray(obj, dtype=np.float64))
        return pareto_front(np.stack(cols, axis=1))

    def top_k(self, k: int = 10, key: str = "t_exe") -> list[dict]:
        """The ``k`` best rows by ``key`` (ascending), as config dicts."""
        vals = {"t_exe": self.t_exe, "resource": self.resource}[key] \
            if key in ("t_exe", "resource") else np.asarray(getattr(self.estimate, key))
        idx = np.argsort(vals, kind="stable")[:k]
        return self.rows(idx)

    def rows(self, indices: Sequence[int] | None = None) -> list[dict]:
        """CSV-ready dict rows for the selected (default: all held) points."""
        est = self.estimate
        ebw = self.effective_bandwidth
        if indices is None:
            indices = range(len(self.resource))
        out = []
        for i in indices:
            i = int(i)
            row = {}
            for name, vals in self.points.items():
                v = vals[i]
                if name == "lsu_type":
                    v = LsuType(v).value if not isinstance(v, LsuType) else v.value
                elif name == "bsp":
                    v = _bsp_name(v)
                elif name == "dram":
                    v = getattr(v, "name", repr(v))
                elif name == "hardware":
                    v = getattr(v, "name", "") if v is not None else ""
                elif isinstance(v, (np.integer, np.bool_)):
                    v = v.item()
                row[name] = v
            row.update(
                t_exe_ms=float(est.t_exe[i]) * 1e3,
                t_ovh_ms=float(est.t_ovh[i]) * 1e3,
                bound_ratio=float(est.bound_ratio[i]),
                memory_bound=bool(est.memory_bound[i]),
                eff_bw_gbs=float(ebw[i]) / 1e9,
                resource_bytes=float(self.resource[i]),
            )
            out.append(row)
        return out


def _bsp_name(b: BspParams) -> str:
    return f"bsp(burst_cnt={b.burst_cnt},max_th={b.max_th})"


def _factorize(objs) -> tuple[list, np.ndarray]:
    """(unique objects, per-row codes) — attribute extraction then runs per
    unique value instead of per design point (the batched-path hotspot)."""
    table: list = []
    index: dict[int, int] = {}
    codes = np.empty(len(objs), dtype=np.int64)
    for i, o in enumerate(objs):
        j = index.get(id(o))
        if j is None:
            j = index[id(o)] = len(table)
            table.append(o)
        codes[i] = j
    return table, codes


def _hardware_views(table: Sequence) -> tuple[list, list, np.ndarray, np.ndarray]:
    """Per-unique-spec (dram view, bsp view, host factor, is-None mask).

    Views are constructed once per unique spec — the dedup contract the old
    per-point loop kept via identity caching, now explicit in the table.
    ``None`` entries get placeholder views that are never gathered.
    """
    drams, bsps, hf, is_none = [], [], [], []
    for h in table:
        if h is None:
            drams.append(None)
            bsps.append(None)
            hf.append(1.0)
            is_none.append(True)
        else:
            drams.append(h.dram_params())
            bsps.append(h.bsp_params())
            hf.append(float(h.host_factor))
            is_none.append(False)
    return drams, bsps, np.asarray(hf), np.asarray(is_none, dtype=bool)


def _apply_hardware_axis(points: dict[str, np.ndarray], n: int,
                         ) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Resolve the ``hardware`` axis into effective dram/bsp columns.

    Points whose hardware spec is not ``None`` get that spec's DRAM/BSP
    views in their ``dram``/``bsp`` columns (so reported configurations
    describe what was actually scored) and its persisted ``host_factor`` in
    the returned per-point scale array.  Fully vectorized: the hardware
    column is factorized once and the views are table gathers — no
    per-point Python loop.  Used by the scalar Session backend (the coded
    batched path resolves through :func:`_resolve_hardware_codes`); the two
    paths must resolve identically for backend equivalence to hold.
    """
    hw_col = points.get("hardware")
    scale = np.ones(n)
    if hw_col is None or all(h is None for h in hw_col):
        return points, scale
    table, codes = _factorize(hw_col)
    drams, bsps, hf, is_none = _hardware_views(table)
    own = is_none[codes]
    scale = np.where(own, 1.0, hf[codes])
    dram_col = np.where(own, np.asarray(points["dram"], dtype=object),
                        _object_array(drams)[codes])
    bsp_col = np.where(own, np.asarray(points["bsp"], dtype=object),
                       _object_array(bsps)[codes])
    return {**points, "dram": dram_col, "bsp": bsp_col}, scale


def _resolve_hardware_codes(cats: dict[str, tuple[list, np.ndarray]], n: int,
                            ) -> tuple[dict, np.ndarray, np.ndarray]:
    """Coded counterpart of :func:`_apply_hardware_axis`.

    Rewrites the ``dram``/``bsp`` ``(table, codes)`` pairs so points with a
    hardware spec index that spec's views (appended to the tables), and
    returns ``(cats, host-factor scale [n], own mask [n])`` where ``own``
    marks points running on the session's own hardware (spec is ``None``).
    No object-dtype column is ever built.
    """
    hw_table, hw_codes = cats["hardware"]
    if all(h is None for h in hw_table):
        return cats, np.ones(n), np.ones(n, dtype=bool)
    drams, bsps, hf, is_none = _hardware_views(hw_table)
    own = is_none[np.asarray(hw_codes)]
    scale = np.where(own, 1.0, hf[hw_codes])
    d_table, d_codes = cats["dram"]
    b_table, b_codes = cats["bsp"]
    new_d = (list(d_table) + drams,
             np.where(own, d_codes, len(d_table) + np.asarray(hw_codes)))
    new_b = (list(b_table) + bsps,
             np.where(own, b_codes, len(b_table) + np.asarray(hw_codes)))
    return {**cats, "dram": new_d, "bsp": new_b}, scale, own


def _normalize_inert_axes(points: dict[str, np.ndarray],
                          is_atomic: np.ndarray,
                          is_ack: np.ndarray) -> dict[str, np.ndarray]:
    """Normalize axes that are inert for a point's LSU type.

    Stride is inert for ACK/atomic, ``val_constant`` for non-atomics, and
    ``include_write`` for atomics (the atomic *is* the write), so reported
    configs describe exactly what was scored; grid products over inert axes
    thus show up as *visibly* identical rows rather than phantom distinct
    designs.  Shared by ``_score`` and the scalar Session backend — the two
    paths must normalize identically for backend equivalence to hold.
    """
    delta = np.where(is_atomic | is_ack, 1,
                     np.asarray(points["delta"], dtype=np.int64))
    val_constant = np.asarray(points["val_constant"], dtype=bool) & is_atomic
    include_write = (np.asarray(points["include_write"], dtype=bool)
                     & ~is_atomic)
    return {**points, "delta": delta, "val_constant": val_constant,
            "include_write": include_write}


def _score(numeric: dict[str, np.ndarray],
           cats: dict[str, tuple[list, np.ndarray]], n: int,
           estimator: Callable[[_mb.GroupBatch], _mb.BatchEstimate] | None = None,
           ) -> tuple[_mb.BatchEstimate, np.ndarray, dict, dict, np.ndarray]:
    """Score ``n`` design points given numeric columns + coded categoricals.

    This is the shared core of the materialized (:func:`_build`) and
    streaming (``Session.sweep(chunk_size=...)``) paths: per-point numeric
    arrays for the numeric axes, ``(table, codes)`` pairs for every
    categorical axis, no object arrays anywhere.  ``estimator`` maps the
    assembled :class:`model_batch.GroupBatch` to a
    :class:`model_batch.BatchEstimate`; it defaults to the NumPy array core
    and is how ``Session`` backends (jax-jit) plug into the same expansion.

    Each point expands to the LSU list ``apps.microbench`` would build,
    expressed as at most two homogeneous LSU *groups* per point:

    * burst-coalesced aligned/non-aligned/cache: one group of
      ``n_ga + include_write`` identical LSUs;
    * write-ACK: a group of ``n_ga`` aligned reads plus a group of ``simd``
      scalar ACK stores (the compiler replicates the store LSU);
    * atomic: a group of ``n_ga`` atomic units (stride is always 1).

    Returns ``(estimate, resource, resolved cats, normalized numeric,
    own-hardware mask)``.
    """
    cats, hw_scale, own = _resolve_hardware_codes(cats, n)

    type_table, type_idx = cats["lsu_type"]
    type_codes = np.asarray([_mb.TYPE_CODE[t] for t in type_table],
                            dtype=np.int64)[type_idx]
    n_ga = np.asarray(numeric["n_ga"], dtype=np.int64)
    simd = np.asarray(numeric["simd"], dtype=np.int64)
    n_elems = np.asarray(numeric["n_elems"], dtype=np.int64)
    elem_bytes = np.asarray(numeric["elem_bytes"], dtype=np.int64)
    dram_table, dram_idx = cats["dram"]
    bsp_table, bsp_idx = cats["bsp"]

    if np.any(n_ga < 1) or np.any(simd < 1) \
            or np.any(np.asarray(numeric["delta"], dtype=np.int64) < 1):
        raise ValueError("n_ga, simd and delta must be >= 1")
    if np.any(n_elems % simd):
        raise ValueError("n_elems must be divisible by simd at every point")

    is_atomic = type_codes == _mb.ATOMIC
    is_ack = type_codes == _mb.WRITE_ACK

    numeric = _normalize_inert_axes(numeric, is_atomic, is_ack)
    delta = numeric["delta"]
    val_constant = numeric["val_constant"]
    include_write = numeric["include_write"]

    # Group 1: the read side (plus the same-type write for plain BC types).
    g1_type = np.where(is_ack, _mb.ALIGNED, type_codes)
    g1_count = np.where(is_atomic | is_ack, n_ga, n_ga + include_write)
    g1_width = np.where(is_atomic, elem_bytes, simd * elem_bytes)
    g1_acc = np.where(is_atomic, n_elems, n_elems // simd)
    g1_delta = delta                      # already normalized above

    # Group 2: the replicated write-ACK store LSUs (count 0 elsewhere).
    g2_count = np.where(is_ack & include_write, simd, 0)

    kernel = np.concatenate([np.arange(n), np.arange(n)])
    vec = np.concatenate
    dram_f = {k: np.asarray([getattr(d, k) if d is not None else 0
                             for d in dram_table])[dram_idx]
              for k in ("dq", "bl", "f_mem", "t_rcd", "t_rp", "t_wr")}
    bsp_f = {k: np.asarray([getattr(b, k) if b is not None else 0
                            for b in bsp_table])[bsp_idx]
             for k in ("burst_cnt", "max_th")}

    batch = _mb.GroupBatch(
        kernel=kernel,
        n_kernels=n,
        count=vec([g1_count, g2_count]),
        lsu_type=vec([g1_type, np.full(n, _mb.WRITE_ACK, dtype=np.int64)]),
        ls_width=vec([g1_width, elem_bytes]),
        ls_acc=vec([g1_acc, n_elems // simd]),
        ls_bytes=vec([g1_width, elem_bytes]),
        delta=vec([g1_delta, np.ones(n, dtype=np.int64)]),
        val_constant=vec([val_constant, np.zeros(n, dtype=bool)]),
        f=vec([simd, simd]),
        **{k: vec([v, v]) for k, v in {**dram_f, **bsp_f}.items()},
    )
    est = (estimator or _mb.estimate_batch)(batch)
    if np.any(hw_scale != 1.0):
        # apply each point's persisted hardware calibration (host_factor)
        est = dataclasses.replace(
            est, t_exe=np.asarray(est.t_exe) * hw_scale,
            t_ideal=np.asarray(est.t_ideal) * hw_scale,
            t_ovh=np.asarray(est.t_ovh) * hw_scale)
    resource = np.bincount(kernel,
                           weights=np.asarray(batch.count * batch.ls_width,
                                              dtype=np.float64),
                           minlength=n)
    return est, resource, cats, numeric, own


def _score_scalar(points: dict, n: int,
                  cats: dict[str, tuple[list, np.ndarray]]) -> SweepResult:
    """Reference scalar loop over the same points :func:`_score` would score.

    Each point expands through ``apps.microbench`` (the proven-equal scalar
    path) and is estimated by the readable per-LSU model
    (:func:`repro.core.model._estimate`); the hardware axis and inert axes
    are resolved exactly like ``_score`` so the reported configurations
    match across backends.  A free function of its inputs only — no
    session state — so :class:`repro.core.stream.SweepPlan` can rebuild
    the scalar backend in a fresh worker process.
    """
    from repro.core import apps as _apps
    from repro.core import model as _model

    points = {name: (points[name] if name in points
                     else _object_array(cats[name][0])[cats[name][1]])
              for name in AXES}   # canonical column order
    points, hw_scale = _apply_hardware_axis(points, n)
    lsu_types = [points["lsu_type"][i] for i in range(n)]
    is_atomic = np.array([t is LsuType.ATOMIC_PIPELINED
                          for t in lsu_types], dtype=bool)
    is_ack = np.array([t is LsuType.BC_WRITE_ACK for t in lsu_types],
                      dtype=bool)
    points = _normalize_inert_axes(points, is_atomic, is_ack)
    delta = points["delta"]
    val_constant = points["val_constant"]
    include_write = points["include_write"]

    cols = {k: np.empty(n) for k in
            ("t_exe", "t_ideal", "t_ovh", "bound_ratio", "total_bytes")}
    memory_bound = np.empty(n, dtype=bool)
    # float64 like the batched path, whose np.bincount segment sum promotes
    # the integer LSU counts — reducer states must agree across backends
    n_lsu = np.empty(n)
    resource = np.empty(n)
    for i in range(n):
        simd = int(points["simd"][i])
        lsus = _apps.microbench(
            lsu_types[i],
            n_ga=int(points["n_ga"][i]),
            simd=simd,
            n_elems=int(points["n_elems"][i]),
            delta=int(delta[i]),               # inert axes normalized above
            elem_bytes=int(points["elem_bytes"][i]),
            include_write=bool(include_write[i]),
            val_constant=bool(val_constant[i]))
        ke = _model._estimate(list(lsus), points["dram"][i], points["bsp"][i],
                              f=simd)
        cols["t_exe"][i] = ke.t_exe * hw_scale[i]
        cols["t_ideal"][i] = ke.t_ideal * hw_scale[i]
        cols["t_ovh"][i] = ke.t_ovh * hw_scale[i]
        cols["bound_ratio"][i] = ke.bound_ratio
        cols["total_bytes"][i] = ke.total_bytes
        memory_bound[i] = ke.memory_bound
        n_lsu[i] = len(ke.per_lsu)
        resource[i] = sum(l.ls_width for l in lsus if l.lsu_type.is_global)
    est = _mb.BatchEstimate(
        t_exe=cols["t_exe"], t_ideal=cols["t_ideal"],
        t_ovh=cols["t_ovh"], bound_ratio=cols["bound_ratio"],
        memory_bound=memory_bound, total_bytes=cols["total_bytes"],
        n_lsu=n_lsu, groups={})
    return SweepResult(points=points, estimate=est, resource=resource)


def _materialize_points(numeric: dict[str, np.ndarray],
                        cats: dict[str, tuple[list, np.ndarray]],
                        ) -> dict[str, np.ndarray]:
    """Per-point axis columns in canonical ``AXES`` order (object gathers
    for the categorical axes — the one place they are built)."""
    points: dict[str, np.ndarray] = {}
    for name in AXES:
        if name in _CATEGORICAL:
            table, codes = cats[name]
            points[name] = _object_array(table)[codes]
        else:
            points[name] = np.asarray(numeric[name])
    return points


def _build(points: dict[str, np.ndarray], n: int,
           cats: dict[str, tuple[list, np.ndarray]],
           estimator: Callable[[_mb.GroupBatch], _mb.BatchEstimate] | None = None,
           ) -> SweepResult:
    """Materialized scoring: every point's config + estimate held in memory.

    ``points`` carries the numeric per-point columns; ``cats`` must carry a
    ``(table, codes)`` pair for every categorical axis (``_grid_points`` /
    ``_random_points`` always do).  The returned ``SweepResult.points``
    holds the *resolved* configuration — hardware-axis dram/bsp overrides
    applied, inert axes normalized — exactly what was scored.
    """
    numeric = {k: points[k] for k in _NUMERIC}
    est, resource, cats, numeric, _ = _score(numeric, cats, n, estimator)
    return SweepResult(points=_materialize_points(numeric, cats),
                       estimate=est, resource=resource)


def _normalize_axes(overrides: Mapping[str, Any]) -> dict[str, list]:
    from repro.hw import DEFAULT_BOARD, get as _hw_get

    board = _hw_get(DEFAULT_BOARD)
    defaults = {
        "lsu_type": LsuType.BC_ALIGNED,
        "n_ga": 1,
        "simd": 16,
        "n_elems": 1 << 22,
        "delta": 1,
        "elem_bytes": 4,
        "include_write": True,
        "val_constant": False,
        "dram": board.dram_params(),
        "bsp": board.bsp_params(),
        "hardware": None,
    }
    unknown = set(overrides) - set(AXES)
    if unknown:
        raise KeyError(f"unknown sweep axes: {sorted(unknown)}")
    return {k: _as_list(overrides.get(k, defaults[k])) for k in AXES}


def _grid_points(axes: Mapping[str, Any],
                 ) -> tuple[dict[str, np.ndarray], int,
                            dict[str, tuple[list, np.ndarray]]]:
    """Per-point axis arrays for the full Cartesian product of ``axes``.

    Point ids are decoded with mixed-radix index arithmetic (see
    :class:`repro.core.stream.GridEnumerator`) rather than ``np.meshgrid``,
    so this shares its enumeration — point ``i`` here is point ``i`` of the
    streaming path — while materializing only integer code arrays:
    ``points`` carries the numeric columns, the categorical axes live in
    ``cats`` as ``(table, codes)`` only (consumers that need per-point
    objects, like the scalar backend, gather them from ``cats``).
    """
    from repro.core.stream import GridEnumerator

    enum = GridEnumerator(_normalize_axes(axes))
    codes = enum.codes(np.arange(enum.n, dtype=np.int64))
    points: dict[str, np.ndarray] = {}
    cats: dict[str, tuple[list, np.ndarray]] = {}
    for name, vals in enum.lists.items():
        idx = codes[name]
        if name in _CATEGORICAL:
            cats[name] = (vals, idx)
        else:
            points[name] = np.asarray(vals)[idx]
    return points, enum.n, cats


def _is_numeric_range(v) -> bool:
    """True for a 2-tuple that means an inclusive integer range (lo, hi).

    *Both* elements must be plain numbers: a pair of categorical values —
    e.g. two :class:`LsuType` members, or booleans — is a 2-element value
    list to sample from, not a range, regardless of which element is which
    (checking only ``v[0]`` misclassified mixed pairs).
    """
    return (isinstance(v, tuple) and len(v) == 2
            and all(isinstance(x, numbers.Real)
                    and not isinstance(x, bool)
                    and not isinstance(x, LsuType) for x in v))


def _random_points(n: int, seed: int, axes: Mapping[str, Any],
                   constraints: tuple = (),
                   ) -> tuple[dict[str, np.ndarray], int,
                              dict[str, tuple[list, np.ndarray]]]:
    """Per-point axis arrays for ``n`` uniformly sampled design points.

    Numeric axes given as a 2-tuple ``(lo, hi)`` of numbers are sampled as
    integers in the inclusive range; any axis given as a list (or a tuple
    that is not a numeric pair — e.g. two ``LsuType`` values) is sampled
    uniformly from it; scalars are held fixed.  Each ``n_elems`` sample is
    rounded down to a multiple of *that point's own* ``simd`` (floored at
    ``simd``), so the sampled values stay inside the requested range
    whenever it contains any multiple of the point's simd — rounding to the
    global LCM of all sampled simd values could leave the range entirely.

    With ``constraints``, sampling is seeded rejection: draw a batch, keep
    the feasible rows (uniform over the feasible region, since rejection
    preserves the base distribution), repeat until ``n`` points or a
    bounded attempt budget runs out — then fail loudly instead of emitting
    infeasible points or spinning forever on an empty feasible region.
    """
    rng = np.random.default_rng(seed)
    tuples = {k: v for k, v in axes.items()
              if k not in _CATEGORICAL and _is_numeric_range(v)}
    lists = _normalize_axes({k: v for k, v in axes.items() if k not in tuples})

    def draw(m: int) -> tuple[dict[str, np.ndarray],
                              dict[str, tuple[list, np.ndarray]]]:
        points: dict[str, np.ndarray] = {}
        cats: dict[str, tuple[list, np.ndarray]] = {}
        for name in AXES:
            if name in tuples:
                lo, hi = tuples[name]
                points[name] = rng.integers(int(lo), int(hi) + 1, size=m)
            else:
                vals = lists[name]
                idx = rng.integers(0, len(vals), size=m)
                if name in _CATEGORICAL:
                    cats[name] = (vals, idx)
                else:
                    points[name] = np.asarray(vals)[idx]
        simd = np.asarray(points["simd"], dtype=np.int64)
        n_elems = np.asarray(points["n_elems"], dtype=np.int64)
        points["n_elems"] = np.maximum((n_elems // simd) * simd, simd)
        return points, cats

    if not constraints or n <= 0:
        points, cats = draw(n)
        return points, n, cats

    from repro.search.constraints import (
        columns_from_parts,
        feasibility_mask,
        normalize_constraints,
    )

    constraints = normalize_constraints(constraints)
    batch = max(int(n), 1024)
    budget = 256 * int(n) + 10_000          # total draws before giving up
    drawn = found = 0
    kept_points: list[dict[str, np.ndarray]] = []
    kept_codes: list[dict[str, np.ndarray]] = []
    tables: dict[str, list] = {}
    while found < n and drawn < budget:
        m = min(batch, budget - drawn)
        points, cats = draw(m)
        drawn += m
        mask = feasibility_mask(
            constraints, columns_from_parts(points, cats, m))
        if not mask.any():
            continue
        kept_points.append({k: v[mask] for k, v in points.items()})
        kept_codes.append({k: idx[mask] for k, (_, idx) in cats.items()})
        tables = {k: vals for k, (vals, _) in cats.items()}
        found += int(mask.sum())
    if found < n:
        region = ("appears empty" if found == 0
                  else f"yielded only {found} of {n} requested points")
        raise ValueError(
            f"constrained random sampling: the feasible region {region} "
            f"after {drawn} seeded draws; relax the constraints or widen "
            f"the axis ranges")
    points = {k: np.concatenate([p[k] for p in kept_points])[:n]
              for k in kept_points[0]}
    cats = {k: (tables[k], np.concatenate([c[k] for c in kept_codes])[:n])
            for k in kept_codes[0]}
    return points, n, cats
