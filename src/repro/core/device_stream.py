"""Device-resident streaming sweep: the jax-jit fast path.

The host streaming loop (:func:`repro.core.stream.run_stream`) enumerates
every chunk on the host, ships the decoded axis arrays to the device,
scores them, ships *every* estimate column back, and folds reducers in
NumPy — four host<->device boundary crossings per chunk.  This module
fuses the whole chunk step into one jit-compiled function so only a
``(start)`` scalar crosses per chunk and one reducer state crosses at the
very end:

* **In-jit enumeration** — the mixed-radix point-id -> axis decode
  (``(ids // stride) % mod``) runs on device from a chunk-start scalar;
  axis *value tables* (a few hundred numbers) live on device for the whole
  sweep.  The padded-tail rule reproduces :func:`stream._chunk_ids`
  exactly: ``ids = min(start + iota, n - 1)``.
* **In-jit scoring** — the same two-group expansion as
  :func:`repro.core.sweep._score` (hardware-axis resolution, inert-axis
  normalization, Eqs. 1-10 via :func:`model_batch.estimate_batch` with
  ``xp=jnp``), producing the identical chunk-column dict the host
  evaluator would, on device.
* **On-device reducer folds** — lax-based, fixed-shape carries for
  :class:`stream.StatsReducer` (Shewchuk exact-sum partials + Chan
  moments, replicated operation for operation), :class:`stream.TopKReducer`
  and the 2-objective :class:`stream.ParetoReducer`.  Chunk sums go
  through the shared position-deterministic tree sum
  (:func:`stream._tree_sum`), which is what makes the fixed-shape
  zero-masked device fold *bit-equal* to the host fold under any chunk
  partition.  Selection reducers never comparator-sort the full chunk:
  every sort key becomes an order-isomorphic int64 (:func:`_f64_key` for
  floats, the value itself for ints), candidate lanes are picked with
  single-operand integer sorts — a threshold cut for top-k, an exact
  in-chunk dominance prefilter (rank / scatter-min / prefix-min) for the
  Pareto front — and only those few lanes are re-scored (elementwise, so
  bit-equal) and merged with the carry by a tiny exact sort.  On XLA:CPU
  a single-operand int64 sort is ~16x faster than the multi-operand
  float comparator sort it replaces.
* **Overlapped dispatch** — the chunk loop enqueues step N+1 while N
  computes (jax async dispatch; the carry is donated off-CPU so state
  ping-pongs between two buffers), and the step executable is keyed only
  on (chunk size, reducer config, table bucket shapes) with every grid
  quantity passed as traced data — a warm-up sweep over a 1-point grid
  compiles the very executable the million-point sweep runs, and
  :func:`repro.compat.enable_compilation_cache` persists it across
  processes.

Fixed-shape carries mean two *capacity* limits the host fold does not
have: the Pareto front cap (:data:`FRONT_CAP`) and the exact-sum partial
count (:data:`N_PARTIALS`).  Both are tracked with on-device overflow
flags checked before any reducer is touched; an overflow raises
:class:`DeviceFoldOverflow` and the caller refolds the same range on the
host path — never a silently truncated result.

Everything jax lives inside functions: importing this module is
numpy-only, and :meth:`DeviceSweep.build` returns ``None`` (host path)
whenever jax is missing, the plan is constrained, several local devices
are visible (the host path shards chunks across them), or the plan's axis
values fall outside the integer/bool domain the device tables mirror
bit-exactly.
"""
from __future__ import annotations

import numpy as np

from repro.core import model_batch as _mb
from repro.core import stream as _stream
from repro.core import sweep as _sweep

#: Pareto front capacity of the fixed-shape device carry.  A front larger
#: than this overflows to the host path (flagged, never truncated).
FRONT_CAP = 4096

#: Shewchuk partial slots of the on-device exact sum.  Real sweeps use 2-4;
#: adversarial magnitude spreads overflow to the host path.
N_PARTIALS = 16

#: Axis value tables are padded (edge-replicated) to multiples of this, so
#: every grid whose axes fit one bucket shares a single compiled step.
_TABLE_BUCKET = 128

_NUM_AXES = tuple(a for a in _sweep.AXES if a not in _sweep._CATEGORICAL)
_DRAM_FIELDS = ("dq", "bl", "f_mem", "t_rcd", "t_rp", "t_wr")
_BSP_FIELDS = ("burst_cnt", "max_th")

#: Chunk-column order (must cover everything the host evaluator emits).
COLUMNS = ("id",) + _sweep.AXES + _stream.ESTIMATE_COLUMNS + ("resource",)

_SENT_ID = np.int64(1) << 62          # sorts after every real point id

_I64MAX = np.int64(np.iinfo(np.int64).max)

#: ``_f64_key(+inf)`` — the masked-lane / empty-slot sentinel for
#: float-keyed selection, so dead lanes behave exactly like the host
#: fold's ``+inf`` padding.
_INFKEY = np.int64(0x7FF0000000000000)

_STEP_CACHE: dict = {}


class DeviceFoldOverflow(RuntimeError):
    """A fixed-shape device carry ran out of capacity; refold on the host."""


# ---------------------------------------------------------------------------
# traced helpers (called at trace time only; jax imported lazily)
# ---------------------------------------------------------------------------

def _tree_sum_dev(x, chunk: int):
    """Traced twin of :func:`stream._tree_sum` over a zero-masked chunk."""
    import jax.numpy as jnp

    size = 1 << (chunk - 1).bit_length()
    if size != chunk:
        x = jnp.concatenate([x, jnp.zeros(size - chunk, dtype=x.dtype)])
    while size > 1:
        x = x[0::2] + x[1::2]
        size //= 2
    return x[0]


def _exact_add(parts, cnt, x):
    """Traced twin of :meth:`stream._ExactSum.add` (grow-expansion).

    ``parts`` holds ``cnt`` non-overlapping partials in slots ``[0, cnt)``;
    the unrolled loop reads the *original* slots (like the host iterating
    the list it mutates behind the read cursor) and compacts surviving
    ``lo`` terms left, appending the final ``hi``.  Returns the new
    ``(parts, cnt, overflowed)``.
    """
    import jax.numpy as jnp

    n_slots = parts.shape[0]
    idx = jnp.arange(n_slots, dtype=jnp.int32)
    new_parts = jnp.zeros_like(parts)
    i = jnp.int32(0)
    for j in range(n_slots):
        active = j < cnt
        y = parts[j]
        swap = jnp.abs(x) < jnp.abs(y)
        big = jnp.where(swap, y, x)
        small = jnp.where(swap, x, y)
        hi = big + small
        lo = small - (hi - big)
        keep = active & (lo != 0.0)
        new_parts = jnp.where((idx == i) & keep, lo, new_parts)
        i = jnp.where(keep, i + jnp.int32(1), i)
        x = jnp.where(active, hi, x)
    overflow = i >= n_slots
    new_parts = jnp.where(idx == i, x, new_parts)
    return new_parts, jnp.minimum(i + jnp.int32(1), n_slots), overflow


def _f64_key(x):
    """Order-isomorphic int64 key of a float64 array.

    ``x + 0.0`` collapses ``-0.0`` into ``+0.0`` (bit-distinct but
    numerically equal), then the sign-aware flip makes the raw IEEE-754
    pattern totally ordered as a signed int64: ``key(a) < key(b)`` iff
    ``a < b`` and ``key(a) == key(b)`` iff ``a == b`` for every non-NaN
    pair — so sorting keys is sorting values, with identical ties.
    """
    import jax
    import jax.numpy as jnp

    b = jax.lax.bitcast_convert_type(x + 0.0, jnp.int64)
    return b ^ ((b >> 63) & jnp.int64(0x7FFFFFFFFFFFFFFF))


def _col_key(v, mask):
    """``(monotonic int64 sort key, sentinel)`` for one column.

    Float columns map through :func:`_f64_key` (sentinel ``_INFKEY``,
    the +inf key); integer/bool columns are exact as int64 (sentinel
    ``_I64MAX``).  Key order and key ties match the host's native-dtype
    comparisons — tighter than a float64 cast, which would round int64
    columns above 2**53.  Masked lanes get the sentinel.
    """
    import jax.numpy as jnp

    if jnp.issubdtype(v.dtype, jnp.floating):
        key, sent = _f64_key(v.astype(jnp.float64)), _INFKEY
    else:
        key, sent = v.astype(jnp.int64), _I64MAX
    return jnp.where(mask, key, jnp.int64(sent)), jnp.int64(sent)


def _score_ids(tables, ids):
    """The in-jit twin of ``plan.evaluator()``'s ``score_ids``.

    Gathers axis values from the device tables for an arbitrary id
    vector, replicates :func:`sweep._score`'s two-group construction and
    hardware resolution, and runs :func:`model_batch.estimate_batch` with
    ``xp=jnp`` (``paired_kernel`` replaces each scatter-based segment sum
    with its bit-equal two-term split add) — so every column is bit-equal
    to the host evaluator's for the same ids.  Unused columns cost
    nothing: callers consume what they need and XLA dead-code-eliminates
    the rest, which is what lets the selection folds re-score only their
    few candidate lanes.
    """
    import jax.numpy as jnp

    chunk = ids.shape[0]
    iota = jnp.arange(chunk, dtype=jnp.int64)
    strides, mods = tables["strides"], tables["mods"]
    code = {name: (ids // strides[i]) % mods[i]
            for i, name in enumerate(_sweep.AXES)}
    num = {k: tables["num_" + k][code[k]] for k in _NUM_AXES}

    type_codes = tables["lsu_code"][code["lsu_type"]]
    own = tables["hw_own"][code["hardware"]]
    hw_scale = jnp.where(own, 1.0, tables["hw_hf"][code["hardware"]])
    d_code = jnp.where(own, code["dram"], tables["len_d"] + code["hardware"])
    b_code = jnp.where(own, code["bsp"], tables["len_b"] + code["hardware"])

    n_ga, simd = num["n_ga"], num["simd"]
    n_elems, elem_bytes = num["n_elems"], num["elem_bytes"]
    is_atomic = type_codes == _mb.ATOMIC
    is_ack = type_codes == _mb.WRITE_ACK

    # _normalize_inert_axes, traced
    delta = jnp.where(is_atomic | is_ack, 1, num["delta"])
    val_constant = num["val_constant"] & is_atomic
    include_write = num["include_write"] & ~is_atomic

    g1_type = jnp.where(is_ack, _mb.ALIGNED, type_codes)
    g1_count = jnp.where(is_atomic | is_ack, n_ga, n_ga + include_write)
    g1_width = jnp.where(is_atomic, elem_bytes, simd * elem_bytes)
    g1_acc = jnp.where(is_atomic, n_elems, n_elems // simd)
    g2_count = jnp.where(is_ack & include_write, simd, 0)

    vec = lambda a, b: jnp.concatenate([a, b])  # noqa: E731
    dram_f = {k: tables["dram_" + k][d_code] for k in _DRAM_FIELDS}
    bsp_f = {k: tables["bsp_" + k][b_code] for k in _BSP_FIELDS}
    batch = _mb.GroupBatch(
        kernel=vec(iota, iota),
        n_kernels=chunk,
        count=vec(g1_count, g2_count),
        lsu_type=vec(g1_type, jnp.full(chunk, _mb.WRITE_ACK,
                                       dtype=jnp.int64)),
        ls_width=vec(g1_width, elem_bytes),
        ls_acc=vec(g1_acc, n_elems // simd),
        ls_bytes=vec(g1_width, elem_bytes),
        delta=vec(delta, jnp.ones(chunk, dtype=jnp.int64)),
        val_constant=vec(val_constant, jnp.zeros(chunk, dtype=bool)),
        f=vec(simd, simd),
        **{k: vec(v, v) for k, v in {**dram_f, **bsp_f}.items()},
    )
    est = _mb.estimate_batch(batch, xp=jnp, paired_kernel=True)

    # hardware host_factor then session calibration — the same two
    # multiplies, in the same order, as _score + evaluator() (a 1.0 scale
    # is an exact multiplicative identity, so applying them
    # unconditionally matches the host's conditional skips bit-for-bit).
    cal = jnp.where(own, tables["calib"], 1.0)
    w = (batch.count * batch.ls_width).astype(jnp.float64)

    cols = {
        "id": ids,
        "lsu_type": code["lsu_type"],
        "n_ga": n_ga, "simd": simd, "n_elems": n_elems, "delta": delta,
        "elem_bytes": elem_bytes,
        "include_write": include_write, "val_constant": val_constant,
        "dram": d_code, "bsp": b_code, "hardware": code["hardware"],
    }
    for name in _stream.ESTIMATE_COLUMNS:
        v = getattr(est, name)
        if name in ("t_exe", "t_ideal", "t_ovh"):
            v = (v * hw_scale) * cal
        if name in ("total_bytes", "n_lsu"):
            # the host's np.bincount segment sum promotes these to float64;
            # the paired split add keeps int64 — cast to match the host
            # column dtype exactly (values are small integers, lossless)
            v = v.astype(jnp.float64)
        cols[name] = v
    # np.bincount folds (0 + w1) + w2 per point; 0 + w1 == w1 exactly.
    cols["resource"] = w[:chunk] + w[chunk:]
    return cols


def _score_chunk(tables, start, chunk: int):
    """Chunk-shaped :func:`_score_ids`: decode ids from a start scalar.

    The padded-tail rule reproduces :func:`stream._chunk_ids` exactly:
    ``ids = min(start + iota, n - 1)``.  Returns ``(cols, valid, mask)``.
    """
    import jax.numpy as jnp

    n = tables["n"]
    iota = jnp.arange(chunk, dtype=jnp.int64)
    ids = jnp.minimum(start + iota, n - 1)
    valid = jnp.minimum(jnp.int64(chunk), n - start)
    mask = iota < valid
    return _score_ids(tables, ids), valid, mask


def _fold_stats(st, cols, valid, mask, chunk: int):
    """Traced twin of :meth:`stream.StatsReducer.update` for one chunk."""
    import jax.numpy as jnp

    t = cols["t_exe"]                                   # already float64
    tz = jnp.where(mask, t, 0.0)
    s = _tree_sum_dev(tz, chunk)
    tb = jnp.where(mask, cols["total_bytes"].astype(jnp.float64), 0.0)
    mb = jnp.sum(jnp.where(mask, cols["memory_bound"],
                           False).astype(jnp.int64))

    te_parts, te_cnt, ovf1 = _exact_add(st["te_parts"], st["te_cnt"], s)
    tb_parts, tb_cnt, ovf2 = _exact_add(st["tb_parts"], st["tb_cnt"],
                                        _tree_sum_dev(tb, chunk))

    mf = valid.astype(jnp.float64)
    cmean = s / mf
    cm2 = _tree_sum_dev(jnp.where(mask, (t - cmean) ** 2, 0.0), chunk)
    # _chan_merge(n_points, mean, m2, valid, cmean, cm2), same op order
    n_new = st["n"] + valid
    nf = n_new.astype(jnp.float64)
    d = cmean - st["mean"]
    mean = st["mean"] + d * (mf / nf)
    m2 = st["m2"] + cm2 + d * d * (st["n"].astype(jnp.float64) / nf * mf)

    vals = jnp.where(mask, t, jnp.inf)
    i = jnp.argmin(vals)                     # first occurrence, like numpy
    v = vals[i]
    pid = cols["id"][i]
    better = (v < st["vmin"]) | ((v == st["vmin"]) & (pid < st["vid"]))
    return {
        "n": n_new, "mb": st["mb"] + mb,
        "vmin": jnp.where(better, v, st["vmin"]),
        "vid": jnp.where(better, pid, st["vid"]),
        "te_parts": te_parts, "te_cnt": te_cnt,
        "tb_parts": tb_parts, "tb_cnt": tb_cnt,
        "mean": mean, "m2": m2,
        "ovf": st["ovf"] | ovf1 | ovf2,
    }


def _fold_topk(st, cols, valid, mask, k: int, key: str, chunk: int, tables):
    """Traced twin of :meth:`stream.TopKReducer.update` for one chunk.

    Selection is by (value, id) — exactly the host's stable lexsort
    tie-breaking — but never comparator-sorts the chunk.  Three cheap
    passes instead:

    1. a single-operand sort of the int64 keys yields the k-th smallest
       key ``thr``;
    2. a second single-operand sort over ``where(key < thr, lane - chunk,
       where(key == thr, lane, big))`` packs every lane strictly below
       the threshold (at most k-1 by the order-statistic definition)
       ahead of the tied lanes in ascending lane (= ascending id) order,
       so the first ``2k`` entries always contain the exact top-k —
       arbitrary ties need no capacity flag;
    3. the 2k candidates are re-scored (every column is an elementwise
       function of a lane's own axis values, so re-scoring is bit-equal)
       and merged with the carry by a tiny exact (key, id) sort.

    Empty carry slots and masked lanes carry (sentinel-key, sentinel-id)
    pairs that sort after every real row.
    """
    import jax
    import jax.numpy as jnp

    kkey, sent = _col_key(cols[key], mask)
    ids = cols["id"]
    if k >= chunk:
        b = chunk
        lanes = jnp.arange(chunk, dtype=jnp.int64)
        real = mask
    else:
        b = 2 * k
        iota = jnp.arange(chunk, dtype=jnp.int64)
        (skey,) = jax.lax.sort((kkey,), num_keys=1)
        thr = skey[k - 1]
        big = jnp.int64(2 * chunk)
        ckey = jnp.where(kkey < thr, iota - chunk,
                         jnp.where(kkey == thr, iota, big))
        (sc,) = jax.lax.sort((ckey,), num_keys=1)
        ent = sc[:b]
        lanes = jnp.where(ent < 0, ent + chunk,
                          jnp.minimum(ent, chunk - 1))
        real = (ent < big) & mask[lanes]
    ckk = jnp.where(real, kkey[lanes], sent)
    cid = jnp.where(real, ids[lanes], _SENT_ID)
    cols2 = _score_ids(tables, ids[lanes])
    mk = jnp.concatenate([st["sortkey"], ckk])
    mi = jnp.concatenate([st["sortid"], cid])
    pos = jnp.arange(k + b, dtype=jnp.int64)
    sk, si, sp = jax.lax.sort((mk, mi, pos), num_keys=2)
    perm = sp[:k]
    new_cols = {c: jnp.concatenate([st["cols"][c], cols2[c]])[perm]
                for c in COLUMNS}
    return {"cols": new_cols, "sortkey": sk[:k], "sortid": si[:k],
            "n_seen": st["n_seen"] + valid}


def _fold_pareto(st, cols, valid, mask, cap: int, objectives, chunk: int,
                 tables):
    """Traced twin of :meth:`stream.ParetoReducer.update` (2 objectives).

    An exact in-chunk dominance prefilter replaces the old 3-operand
    comparator sort over (cap + chunk) lanes: rank the v0 keys with a
    single-operand sort + ``searchsorted``, scatter-min the v1 keys per
    v0 group, prefix-min across groups, and drop every lane those minima
    dominate.  The predicate is :func:`sweep._pareto_2d`'s mask
    restricted to the chunk, and chunk-dominated implies union-dominated
    (adding carry rows can only lower the group minima), so dropped
    lanes can never reach the merged front; conversely every dropped
    lane's dominator chain ends in a surviving lane (dominance is a
    strict partial order), so the merge still flags exactly the rows the
    host fold flags.  Survivors are compacted in ascending lane
    (= ascending id) order, re-scored at width S (elementwise, so
    bit-equal), and merged with the carry by `_pareto_2d` in key space
    over (cap + S) lanes — carry rows first, which preserves the host's
    ascending-id held order.  Empty carry slots and masked lanes hold
    (sentinel, sentinel) keys: the host's ``+inf`` padding role.  More
    than S chunk survivors or more than ``cap`` merged survivors sets
    the overflow flag.
    """
    import jax
    import jax.numpy as jnp

    o0, o1 = objectives
    k0, sent0 = _col_key(cols[o0], mask)
    k1, sent1 = _col_key(cols[o1], mask)
    iota = jnp.arange(chunk, dtype=jnp.int64)

    (s0,) = jax.lax.sort((k0,), num_keys=1)
    g = jnp.searchsorted(s0, k0, side="left")
    gm = jnp.full(chunk, _I64MAX, dtype=jnp.int64).at[g].min(k1)
    cm = jax.lax.cummin(gm)
    m_strict = jnp.where(g > 0, cm[jnp.maximum(g - 1, 0)], sent1)
    keep = mask & ~((m_strict <= k1) | (gm[g] < k1))
    s_count = jnp.sum(keep.astype(jnp.int64))

    s_cap = min(cap, chunk)
    big = jnp.int64(2 * chunk)
    ckey = jnp.where(keep, iota, big)
    (sc,) = jax.lax.sort((ckey,), num_keys=1)
    ent = sc[:s_cap]
    lanes = jnp.minimum(ent, chunk - 1)
    cand = ent < big
    cv0 = jnp.where(cand, k0[lanes], sent0)
    cv1 = jnp.where(cand, k1[lanes], sent1)
    cols2 = _score_ids(tables, cols["id"][lanes])

    m = cap + s_cap
    v0 = jnp.concatenate([st["v0k"], cv0])
    v1 = jnp.concatenate([st["v1k"], cv1])
    midx = jnp.arange(m, dtype=jnp.int64)
    sm0, sm1, sidx = jax.lax.sort((v0, v1, midx), num_keys=3)

    new_group = jnp.concatenate(
        [jnp.ones(1, dtype=bool), sm0[1:] != sm0[:-1]])
    group_start = jax.lax.cummax(jnp.where(new_group, midx, 0))
    gmin = sm1[group_start]
    cmm = jax.lax.cummin(sm1)
    prev_end = group_start - 1
    m_str = jnp.where(prev_end >= 0, cmm[jnp.maximum(prev_end, 0)], sent1)
    dominated = (m_str <= sm1) | (gmin < sm1)

    survives = ~dominated
    count = jnp.sum(survives.astype(jnp.int64))
    keep_key = jnp.where(survives, sidx, _SENT_ID)
    (ordered,) = jax.lax.sort((keep_key,), num_keys=1)
    perm = jnp.minimum(ordered[:cap], m - 1)      # clamp sentinels: gather-safe
    live = jnp.arange(cap, dtype=jnp.int64) < jnp.minimum(count, cap)
    new_cols = {c: jnp.concatenate([st["cols"][c], cols2[c]])[perm]
                for c in COLUMNS}
    return {
        "cols": new_cols,
        "v0k": jnp.where(live, v0[perm], sent0),
        "v1k": jnp.where(live, v1[perm], sent1),
        "count": jnp.minimum(count, cap),
        "ovf": st["ovf"] | (count > cap) | (s_count > s_cap),
    }


def _get_step(chunk: int, sig: tuple):
    """The jit-compiled fused chunk step for (chunk size, reducer config).

    ``step(carry, tables, start) -> carry`` — everything else (grid
    geometry, axis tables, calibration) is traced data, so one executable
    serves every grid whose tables fit the same padded buckets.  The carry
    is donated off-CPU (CPU donation is a no-op that warns).
    """
    import jax

    key = (chunk, sig, jax.default_backend())
    step = _STEP_CACHE.get(key)
    if step is not None:
        return step

    def _step(carry, tables, start):
        cols, valid, mask = _score_chunk(tables, start, chunk)
        out = []
        for spec, st in zip(sig, carry):
            if spec[0] == "stats":
                out.append(_fold_stats(st, cols, valid, mask, chunk))
            elif spec[0] == "topk":
                out.append(_fold_topk(st, cols, valid, mask,
                                      spec[1], spec[2], chunk, tables))
            else:
                out.append(_fold_pareto(st, cols, valid, mask,
                                        spec[1], spec[2], chunk, tables))
        return tuple(out)

    donate = (0,) if jax.default_backend() != "cpu" else ()
    step = jax.jit(_step, donate_argnums=donate)
    _STEP_CACHE[key] = step
    return step


# ---------------------------------------------------------------------------
# DeviceSweep: host-side driver
# ---------------------------------------------------------------------------

def _pad_table(arr: np.ndarray) -> np.ndarray:
    """Edge-replicate to the next :data:`_TABLE_BUCKET` multiple (padding
    is never gathered — codes only index the true prefix)."""
    size = -(-len(arr) // _TABLE_BUCKET) * _TABLE_BUCKET
    if size == len(arr):
        return arr
    return np.concatenate([arr, np.repeat(arr[-1:], size - len(arr),
                                          axis=0)])


_COL_DTYPES = {
    **{a: np.int64 for a in ("id", "lsu_type", "n_ga", "simd", "n_elems",
                             "delta", "elem_bytes", "dram", "bsp",
                             "hardware")},
    # total_bytes / n_lsu are float64 on the host too: its np.bincount
    # segment sum promotes the integer inputs
    **{a: np.float64 for a in ("t_exe", "t_ideal", "t_ovh", "bound_ratio",
                               "resource", "total_bytes", "n_lsu")},
    **{a: np.bool_ for a in ("include_write", "val_constant",
                             "memory_bound")},
}


class DeviceSweep:
    """One plan's device-resident fold driver (build via :meth:`build`)."""

    def __init__(self, plan: "_stream.SweepPlan", tables: dict):
        self.plan = plan
        self.n = plan.enumerator().n
        self.chunk = plan.chunk_size
        self.front_cap = FRONT_CAP
        self._tables_host = tables
        self._tables_dev = None

    # -- eligibility --------------------------------------------------------

    @classmethod
    def build(cls, plan: "_stream.SweepPlan") -> "DeviceSweep | None":
        """A driver for ``plan``, or ``None`` when the host path must run.

        Ineligible: jax missing, non-jax backend, constrained plan,
        several visible devices (the host path shards chunks across them),
        an empty grid, non-integer/bool numeric axis values (the device
        tables mirror the host's gathered dtypes exactly), or axis values
        the host evaluator itself would reject.
        """
        try:
            import jax  # noqa: F401
        except ImportError:  # pragma: no cover - jax-less install
            return None
        from repro import compat as _compat

        if plan.backend != "jax-jit" or plan.constraints:
            return None
        if _compat.local_device_count() > 1:
            return None
        lists = {k: list(v) for k, v in plan.lists.items()}
        enum = _stream.GridEnumerator(lists)
        if enum.n == 0:
            return None

        tables: dict = {
            "strides": enum.strides.copy(),
            "mods": enum._mod.copy(),
            "n": np.int64(enum.n),
            "calib": np.float64(plan.calibration_factor),
        }
        int_axes = ("n_ga", "simd", "n_elems", "delta", "elem_bytes")
        for k in _NUM_AXES:
            arr = np.asarray(lists[k])
            want = np.bool_ if k in ("include_write",
                                     "val_constant") else np.int64
            if arr.dtype == object or not (
                    np.issubdtype(arr.dtype, np.integer)
                    or np.issubdtype(arr.dtype, np.bool_)):
                return None
            tables["num_" + k] = _pad_table(arr.astype(want))
        for k in int_axes[:4]:      # host _score raises on these; let it
            pass
        if (tables["num_n_ga"][:len(lists["n_ga"])].min(initial=1) < 1
                or tables["num_simd"][:len(lists["simd"])].min(
                    initial=1) < 1
                or tables["num_delta"][:len(lists["delta"])].min(
                    initial=1) < 1):
            return None
        ne = np.asarray(lists["n_elems"], dtype=np.int64)
        sd = np.asarray(lists["simd"], dtype=np.int64)
        if np.any(ne[:, None] % sd[None, :]):
            return None

        try:
            lsu_codes = np.asarray([_mb.TYPE_CODE[t]
                                    for t in lists["lsu_type"]],
                                   dtype=np.int64)
        except (KeyError, TypeError):
            return None
        tables["lsu_code"] = _pad_table(lsu_codes)

        hw_table = lists["hardware"]
        try:
            drams_v, bsps_v, hf, is_none = _sweep._hardware_views(hw_table)
            all_own = bool(is_none.all())
            # Mirror _resolve_hardware_codes: the dram/bsp tables are
            # extended with the per-hardware views only when any spec is
            # set; all-None leaves them (and the codes) untouched.
            d_table = lists["dram"] + ([] if all_own else drams_v)
            b_table = lists["bsp"] + ([] if all_own else bsps_v)
            for k in _DRAM_FIELDS:
                tables["dram_" + k] = _pad_table(np.asarray(
                    [getattr(d, k) if d is not None else 0
                     for d in d_table]))
            for k in _BSP_FIELDS:
                tables["bsp_" + k] = _pad_table(np.asarray(
                    [getattr(b, k) if b is not None else 0
                     for b in b_table]))
        except (AttributeError, TypeError):
            return None
        tables["hw_own"] = _pad_table(np.asarray(is_none, dtype=bool))
        tables["hw_hf"] = _pad_table(np.asarray(hf, dtype=np.float64))
        tables["len_d"] = np.int64(len(lists["dram"]))
        tables["len_b"] = np.int64(len(lists["bsp"]))

        _compat.enable_compilation_cache()
        return cls(plan, tables)

    def supports(self, reducers) -> bool:
        return self._sig(reducers) is not None

    def _sig(self, reducers) -> tuple | None:
        sig = []
        for r in reducers:
            if type(r) is _stream.StatsReducer:
                sig.append(("stats",))
            elif type(r) is _stream.TopKReducer and r.key in COLUMNS:
                sig.append(("topk", r.k, r.key))
            elif (type(r) is _stream.ParetoReducer
                    and len(r.objectives) == 2
                    and all(o in COLUMNS for o in r.objectives)):
                sig.append(("pareto", self.front_cap, tuple(r.objectives)))
            else:
                return None
        return tuple(sig)

    # -- carries ------------------------------------------------------------

    def _init_carry(self, sig: tuple):
        import jax.numpy as jnp

        carry = []
        for spec in sig:
            if spec[0] == "stats":
                carry.append({
                    "n": jnp.int64(0), "mb": jnp.int64(0),
                    "vmin": jnp.float64(np.inf), "vid": jnp.int64(-1),
                    "te_parts": jnp.zeros(N_PARTIALS, dtype=jnp.float64),
                    "te_cnt": jnp.int32(0),
                    "tb_parts": jnp.zeros(N_PARTIALS, dtype=jnp.float64),
                    "tb_cnt": jnp.int32(0),
                    "mean": jnp.float64(0.0), "m2": jnp.float64(0.0),
                    "ovf": jnp.bool_(False),
                })
            elif spec[0] == "topk":
                k = spec[1]
                sent = (_INFKEY if _COL_DTYPES[spec[2]] is np.float64
                        else _I64MAX)
                carry.append({
                    "cols": {c: jnp.zeros(k, dtype=_COL_DTYPES[c])
                             for c in COLUMNS},
                    "sortkey": jnp.full(k, sent, dtype=jnp.int64),
                    "sortid": jnp.full(k, _SENT_ID, dtype=jnp.int64),
                    "n_seen": jnp.int64(0),
                })
            else:
                cap = spec[1]
                s0, s1 = (_INFKEY if _COL_DTYPES[o] is np.float64
                          else _I64MAX for o in spec[2])
                carry.append({
                    "cols": {c: jnp.zeros(cap, dtype=_COL_DTYPES[c])
                             for c in COLUMNS},
                    "v0k": jnp.full(cap, s0, dtype=jnp.int64),
                    "v1k": jnp.full(cap, s1, dtype=jnp.int64),
                    "count": jnp.int64(0),
                    "ovf": jnp.bool_(False),
                })
        return tuple(carry)

    # -- the fold -----------------------------------------------------------

    def fold_range(self, lo: int, hi: int, reducers,
                   profile: dict | None = None) -> None:
        """Fold chunk-aligned ``[lo, hi)`` into ``reducers`` on device.

        Same alignment contract as :meth:`SweepPlan.run_range`.  The loop
        enqueues every chunk step without a host sync (jax async
        dispatch); reducer state is pulled to the host exactly once.
        Overflow flags are validated *before* any reducer is touched, so
        on :class:`DeviceFoldOverflow` the reducers are untouched and the
        caller can refold the identical range on the host path.

        With ``profile``, each step is synchronized for honest attribution
        (``compile_s`` first step, ``score_s`` the rest, ``transfer_s``
        table upload + final state pull) — profiling serializes the
        overlap on purpose.
        """
        import time

        import jax
        from jax.experimental import enable_x64

        n, chunk = self.n, self.chunk
        lo, hi = int(lo), min(int(hi), n)
        if lo % chunk:
            raise ValueError(f"range start {lo} is not chunk-aligned "
                             f"(chunk_size={chunk})")
        if hi % chunk and hi != n:
            raise ValueError(f"range stop {hi} is not chunk-aligned "
                             f"(chunk_size={chunk}) and is not the grid "
                             f"end {n}")
        if hi <= lo:
            return
        reducers = tuple(reducers)
        sig = self._sig(reducers)
        if sig is None:
            raise ValueError("unsupported reducer set for the device fold; "
                             "check supports() first")
        step = _get_step(chunk, sig)

        with enable_x64():
            t0 = time.perf_counter()
            if self._tables_dev is None:
                self._tables_dev = jax.device_put(self._tables_host)
            tables = self._tables_dev
            carry = self._init_carry(sig)
            if profile is not None:
                profile.setdefault("path", "device-fused")
                profile["transfer_s"] = (profile.get("transfer_s", 0.0)
                                         + time.perf_counter() - t0)
                first = True
                for s in range(lo, hi, chunk):
                    t0 = time.perf_counter()
                    carry = step(carry, tables, np.int64(s))
                    jax.block_until_ready(carry)
                    stage = "compile_s" if first else "score_s"
                    profile[stage] = (profile.get(stage, 0.0)
                                      + time.perf_counter() - t0)
                    first = False
                profile.setdefault("enumerate_s", 0.0)   # fused in-jit
                profile.setdefault("reduce_s", 0.0)      # fused in-jit
                t0 = time.perf_counter()
            else:
                for s in range(lo, hi, chunk):
                    carry = step(carry, tables, np.int64(s))
            state = jax.tree_util.tree_map(np.asarray, carry)
            if profile is not None:
                profile["transfer_s"] += time.perf_counter() - t0

        # Validate every capacity flag before touching any reducer — a
        # partial merge would double-count when the host refolds the range.
        for spec, st in zip(sig, state):
            if spec[0] == "stats" and bool(st["ovf"]):
                raise DeviceFoldOverflow(
                    f"exact-sum partial count exceeded {N_PARTIALS}")
            if spec[0] == "pareto" and bool(st["ovf"]):
                raise DeviceFoldOverflow(
                    f"pareto front exceeded the device cap {spec[1]}")

        for r, spec, st in zip(reducers, sig, state):
            if spec[0] == "stats":
                r.merge(_stream.StatsReducer.from_state({
                    "n_points": int(st["n"]),
                    "memory_bound": int(st["mb"]),
                    "t_exe_min": float(st["vmin"]),
                    "t_exe_min_id": int(st["vid"]),
                    "t_exe_sum":
                        [float(p) for p in
                         st["te_parts"][:int(st["te_cnt"])]],
                    "total_bytes_sum":
                        [float(p) for p in
                         st["tb_parts"][:int(st["tb_cnt"])]],
                    "mean": float(st["mean"]),
                    "m2": float(st["m2"]),
                }))
            elif spec[0] == "topk":
                held = min(int(st["n_seen"]), spec[1])
                tmp = _stream.TopKReducer(spec[1], spec[2])
                tmp.cols = {c: np.asarray(st["cols"][c][:held])
                            for c in COLUMNS}
                r.merge(tmp)
            else:
                cnt = int(st["count"])
                tmp = _stream.ParetoReducer(spec[2])
                tmp.cols = {c: np.asarray(st["cols"][c][:cnt])
                            for c in COLUMNS}
                r.merge(tmp)


def try_outcome(plan: "_stream.SweepPlan", reducers,
                profile: dict | None = None) -> "_stream.StreamOutcome | None":
    """Run the whole grid device-resident, or ``None`` for the host path.

    Folds ``[0, n)`` into ``reducers`` (which are only touched on success
    — a capacity overflow returns ``None`` with the reducers pristine) and
    returns the same :class:`stream.StreamOutcome` ``run_stream`` would.
    """
    dev = DeviceSweep.build(plan)
    if dev is None:
        return None
    reducers = tuple(reducers)
    if not dev.supports(reducers):
        return None
    n = dev.n
    try:
        dev.fold_range(0, n, reducers, profile=profile)
    except DeviceFoldOverflow:
        return None
    return _stream.StreamOutcome(
        reducers=reducers, n_points=n,
        n_chunks=-(-n // plan.chunk_size), chunk_size=plan.chunk_size)
