"""Core: the paper's analytical memory model (implementation layer).

The *public* surface is :mod:`repro.api` (``Design`` / ``Session`` /
``Space`` and the shared ``Estimate``/``Report`` family); the modules below
implement it.  The pre-PR-3 module-level entry points (``model.estimate``,
``sweep.sweep_grid``/``sweep_random``, ``predictor.predict``,
``autotune.autotune``, ``validate.validate``) were deprecation shims for
one release and are now removed — route everything through ``Session``.

Hardware values live in the registry-backed spec layer (:mod:`repro.hw`);
the constants re-exported below are its legacy parameter views.

Faithful FPGA/HLS layer (paper Eqs. 1-10):
    fpga        -- DRAM/BSP parameter *classes* (Table III values: repro.hw)
    lsu         -- LSU taxonomy (Table I) and descriptors (Table II)
    model       -- T_exe estimation + memory-bound criterion (scalar core)
    model_batch -- array-based core of the same equations (vectorized)
    sweep       -- design-space sweeps: grid/random scoring + Pareto fronts
    stream      -- bounded-memory streaming sweeps: lazy grid enumeration,
                   chunked evaluation, online Pareto/top-k/stats reducers
    dramsim     -- event-driven DRAM oracle (board substitute)
    baselines   -- Wang [6] / HLScope+ [7] comparison models
    apps        -- Table IV applications + SIV microbenchmarks
    cache       -- on-disk cache of compiled-HLO analyses (autotune)
    validate    -- measured-vs-predicted loop (Session.validate)

TPU/XLA adaptation layer (DESIGN.md S2):
    hbm       -- access-class taxonomy + HBM/ICI parameters
    hlo       -- compiled-HLO traffic extraction (memory + collectives)
    predictor -- lowered step -> classified traffic -> time prediction
    roofline  -- three-term roofline report
    autotune  -- model-guided configuration search (Session.autotune)
"""

from repro.core.fpga import BspParams, DramParams
from repro.core.lsu import Lsu, LsuType, make_global_access
from repro.core.model import KernelEstimate, memory_bound_ratio
from repro.core.model_batch import BatchEstimate, GroupBatch, estimate_batch
from repro.core.sweep import SweepResult, pareto_front
from repro.hw import get as _hw_get

# Registry-backed convenience re-exports of the former module constants
# (canonical values now live in repro.hw.presets; reading them here does not
# warn — the deprecated homes are repro.core.fpga / repro.core.hbm).
DDR4_1866 = _hw_get("stratix10_ddr4_1866").dram_params()
DDR4_2666 = _hw_get("stratix10_ddr4_2666").dram_params()
DRAM_CONFIGS = {d.name: d for d in (DDR4_1866, DDR4_2666)}
STRATIX10_BSP = _hw_get("stratix10_ddr4_1866").bsp_params()
