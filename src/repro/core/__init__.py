"""Core: the paper's analytical memory model (implementation layer).

The *public* surface is :mod:`repro.api` (``Design`` / ``Session`` /
``Space`` and the shared ``Estimate``/``Report`` family); the modules below
implement it.  The pre-PR-3 entry points re-exported here (``estimate``,
``sweep_grid``, ``sweep_random``) are deprecated shims kept for one release.

Faithful FPGA/HLS layer (paper Eqs. 1-10):
    fpga        -- DRAM/BSP parameter sets (Table III)
    lsu         -- LSU taxonomy (Table I) and descriptors (Table II)
    model       -- T_exe estimation + memory-bound criterion (scalar core)
    model_batch -- array-based core of the same equations (vectorized)
    sweep       -- design-space sweeps: grid/random scoring + Pareto fronts
    dramsim     -- event-driven DRAM oracle (board substitute)
    baselines   -- Wang [6] / HLScope+ [7] comparison models
    apps        -- Table IV applications + SIV microbenchmarks
    cache       -- on-disk cache of compiled-HLO analyses (autotune)
    validate    -- measured-vs-predicted loop (Session.validate)

TPU/XLA adaptation layer (DESIGN.md S2):
    hbm       -- access-class taxonomy + HBM/ICI parameters
    hlo       -- compiled-HLO traffic extraction (memory + collectives)
    predictor -- lowered step -> classified traffic -> time prediction
    roofline  -- three-term roofline report
    autotune  -- model-guided configuration search (Session.autotune)
"""

from repro.core.fpga import DDR4_1866, DDR4_2666, BspParams, DramParams, STRATIX10_BSP
from repro.core.lsu import Lsu, LsuType, make_global_access
from repro.core.model import KernelEstimate, estimate, memory_bound_ratio
from repro.core.model_batch import BatchEstimate, GroupBatch, estimate_batch
from repro.core.sweep import SweepResult, pareto_front, sweep_grid, sweep_random
