"""Core: the paper's analytical memory model.

Faithful FPGA/HLS layer (paper Eqs. 1-10):
    fpga        -- DRAM/BSP parameter sets (Table III)
    lsu         -- LSU taxonomy (Table I) and descriptors (Table II)
    model       -- T_exe estimation + memory-bound criterion (scalar API)
    model_batch -- array-based core of the same equations (vectorized)
    sweep       -- design-space sweeps: grid/random scoring + Pareto fronts
    dramsim     -- event-driven DRAM oracle (board substitute)
    baselines   -- Wang [6] / HLScope+ [7] comparison models
    apps        -- Table IV applications + SIV microbenchmarks
    cache       -- on-disk cache of compiled-HLO analyses (autotune)

TPU/XLA adaptation layer (DESIGN.md S2):
    hbm       -- access-class taxonomy + HBM/ICI parameters
    hlo       -- compiled-HLO traffic extraction (memory + collectives)
    predictor -- lowered step -> classified traffic -> time prediction
    roofline  -- three-term roofline report
    autotune  -- model-guided configuration search
"""

from repro.core.fpga import DDR4_1866, DDR4_2666, BspParams, DramParams, STRATIX10_BSP
from repro.core.lsu import Lsu, LsuType, make_global_access
from repro.core.model import KernelEstimate, estimate, memory_bound_ratio
from repro.core.model_batch import BatchEstimate, GroupBatch, estimate_batch
from repro.core.sweep import SweepResult, pareto_front, sweep_grid, sweep_random
