"""Trip-count-aware static cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts every ``while`` body **once**, so any
scan-over-layers program under-reports FLOPs/bytes by the trip count (we
measured 10x for a 10-step scan).  This module re-derives the counts from the
HLO text itself — the exact analogue of the paper reading the early RTL
report instead of waiting for the bitstream:

* parses every computation and instruction (name, shape, opcode, operands);
* recovers ``while`` trip counts from the loop-condition comparison constant;
* multiplies body costs by trips through the call graph (while bodies,
  fusion computations, called computations);
* counts FLOPs precisely for ``dot`` (operand shapes x contracting dims) and
  approximately (1 FLOP/element) for elementwise/reduce ops;
* counts HBM bytes per executed instruction (operands + result), with
  slice-aware special cases: ``dynamic-slice``/``gather`` read only what they
  produce, ``dynamic-update-slice``/``scatter`` touch only the update region,
  and fusion operands feeding an internal gather/slice are charged the
  consumer's result bytes rather than the whole operand (otherwise a scan
  that slices its layer's weights out of the stacked array would be charged
  the full stack every iteration);
* classifies bytes into the access classes of DESIGN.md S2 (stream /
  strided / gather) and collects collectives with trip multipliers.

Validated against ``cost_analysis()`` on scan-free modules (tests).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

from repro.core.hlo import shape_bytes, COLLECTIVE_KINDS, _collective_from, _group_size

#: Bump whenever the analysis semantics change (opcode coverage, class
#: mapping, trip-count recovery, ...) so on-disk caches of analyze() output
#: (core.cache / core.autotune) are invalidated automatically.
ANALYZER_VERSION = 2

_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*.+\{\s*$")
# NOTE: tuple types may contain /*index=N*/ comments, so the tuple branch
# must tolerate '=' inside the parens (non-greedy up to ') opcode(').
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)"
    r"\s*([a-z][a-z0-9\-]*)\((.*)$")
_SHAPE_ELEMS_RE = re.compile(r"[a-z0-9]+\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_SPLIT_RE = re.compile(r"\),?\s*")

_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "negate", "abs", "rsqrt", "sqrt", "sine",
    "cosine", "logistic", "expm1", "log1p", "select", "compare", "and", "or",
    "xor", "not", "clamp", "floor", "ceil", "round-nearest-afz", "sign",
    "atan2", "remainder", "erf", "cbrt",
}
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "partition-id", "replica-id",
               "rng-bit-generator", "rng-get-and-update-state", "domain",
               "opt-barrier", "custom-call"}
# NOTE: dynamic-slice / dynamic-update-slice are *contiguous block* accesses
# (scan-counter offsets) — the paper's burst-coalesced-aligned class — so they
# stay in "stream".  Only data-dependent gather/scatter carry the per-row
# transaction overhead (the Write-ACK analogue).
_CLASS_GATHER = {"gather", "scatter", "scatter-add"}
_CLASS_STRIDED = {"transpose", "reverse", "pad", "slice", "concatenate",
                  "copy", "sort", "reshape"}


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str                     # operand list + attributes (raw)
    operands: tuple[str, ...]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr]
    shapes: dict[str, str]        # instr name -> result shape string
    consumers: dict[str, int] = dataclasses.field(default_factory=dict)
    root: str = ""
    by_name: dict[str, "Instr"] = dataclasses.field(default_factory=dict)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        h = _COMP_HEADER_RE.match(line.strip()) if "{" in line and "->" in line else None
        if h:
            cur = Computation(name=h.group(2), is_entry=bool(h.group(1)),
                              instrs=[], shapes={})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        # operand names: %refs before the first attribute keyword
        args = rest.split("), ")[0]
        operands = tuple(_OPERAND_RE.findall(args))
        ins = Instr(name=name, shape=shape, opcode=opcode, rest=rest,
                    operands=operands)
        cur.instrs.append(ins)
        cur.shapes[name] = shape
        cur.by_name[name] = ins
        for op_name in operands:
            cur.consumers[op_name] = cur.consumers.get(op_name, 0) + 1
        if line.lstrip().startswith("ROOT"):
            cur.root = name
    return comps


def _shape_elems(shape: str) -> float:
    total = 0.0
    for dims in _SHAPE_ELEMS_RE.findall(shape):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _attr(rest: str, key: str) -> str | None:
    m = re.search(re.escape(key) + r"=\{([^}]*)\}", rest)
    return m.group(1) if m else None


def _dims_of(shape: str) -> list[int]:
    m = _SHAPE_ELEMS_RE.search(shape)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.shape)
    k = 1.0
    lhs_shape = comp.shapes.get(ins.operands[0]) if ins.operands else None
    contract = _attr(ins.rest, "lhs_contracting_dims")
    if lhs_shape and contract is not None:
        dims = _dims_of(lhs_shape)
        for idx in contract.split(","):
            idx = idx.strip()
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _while_trips(cond: Computation) -> int:
    """Trip count from the loop condition's comparison constant."""
    consts: dict[str, int] = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                consts[ins.name] = int(m.group(1))
    best = 0
    for ins in cond.instrs:
        if ins.opcode == "compare":
            for op in ins.operands:
                if op in consts:
                    best = max(best, abs(consts[op]))
    if best == 0 and consts:
        best = max(abs(v) for v in consts.values())
    return max(1, best)


def _called(rest: str, key: str) -> str | None:
    m = re.search(re.escape(key) + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_by_class: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    n_collectives: float = 0.0
    transcendentals: float = 0.0
    warnings: list = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_class.values())

    def scaled(self, mult: float) -> "HloCost":
        out = HloCost()
        out.flops = self.flops * mult
        # mult == 0 must not leave stale zero-valued classes behind: a
        # downstream consumer keys LSU groups off the class *names*, so a
        # {"gather": 0.0} entry would still instantiate a gather group.
        if mult:
            out.bytes_by_class = defaultdict(
                float, {k: v * mult for k, v in self.bytes_by_class.items()})
            out.collective_by_kind = defaultdict(
                float,
                {k: v * mult for k, v in self.collective_by_kind.items()})
        out.collective_operand_bytes = self.collective_operand_bytes * mult
        out.collective_wire_bytes = self.collective_wire_bytes * mult
        out.n_collectives = self.n_collectives * mult
        out.transcendentals = self.transcendentals * mult
        out.warnings = list(self.warnings)
        return out

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        for k, v in other.bytes_by_class.items():
            self.bytes_by_class[k] += v
        self.collective_operand_bytes += other.collective_operand_bytes
        self.collective_wire_bytes += other.collective_wire_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] += v
        self.n_collectives += other.n_collectives
        self.transcendentals += other.transcendentals
        self.warnings.extend(other.warnings)


_HEAVY_OPS = {"dot", "convolution", "reduce", "reduce-window", "gather",
              "scatter", "dynamic-slice", "dynamic-update-slice", "sort",
              "transpose", "copy", "concatenate", "pad", "slice", "reverse",
              "fft", "cholesky", "triangular-solve"}


class Analyzer:
    """``fused=True`` (default) applies a TPU-fusion-aware traffic model:
    only *materialization boundaries* touch HBM — heavy ops (dot / reduce /
    gather / layout changes), values with more than one consumer, and
    computation roots (loop carries).  Pure single-consumer elementwise
    chains are fusion-internal (VMEM/registers), as the TPU backend would
    emit them.  ``fused=False`` charges every instruction operands+result —
    the XLA HloCostAnalysis convention, used for validation against
    ``cost_analysis()`` on scan-free modules."""

    def __init__(self, text: str, fused: bool = True):
        self.comps = parse_module(text)
        self.fused = fused
        self._fusion_flops_cache: dict[str, tuple[float, float]] = {}
        self._comp_cost_cache: dict[str, HloCost] = {}
        self._fusion_heavy_cache: dict[str, bool] = {}

    def _materialized(self, ins: Instr, comp: Computation) -> bool:
        if not self.fused:
            return True
        if ins.opcode in _HEAVY_OPS:
            return True
        if ins.opcode == "fusion" and self._fusion_heavy(
                _called(ins.rest, "calls") or ""):
            return True
        if comp.consumers.get(ins.name, 0) > 1:
            return True
        return ins.name == comp.root

    def _fusion_heavy(self, comp_name: str) -> bool:
        if comp_name in self._fusion_heavy_cache:
            return self._fusion_heavy_cache[comp_name]
        comp = self.comps.get(comp_name)
        heavy = False
        if comp:
            for i in comp.instrs:
                if i.opcode in _HEAVY_OPS:
                    heavy = True
                    break
                if i.opcode == "fusion" and self._fusion_heavy(
                        _called(i.rest, "calls") or ""):
                    heavy = True
                    break
        self._fusion_heavy_cache[comp_name] = heavy
        return heavy

    # ---- fusion internals: flops only (their bytes stay in VMEM) ----
    def _fusion_internal_flops(self, comp_name: str) -> tuple[float, float]:
        if comp_name in self._fusion_flops_cache:
            return self._fusion_flops_cache[comp_name]
        comp = self.comps.get(comp_name)
        flops = trans = 0.0
        if comp:
            for ins in comp.instrs:
                if ins.opcode == "dot":
                    flops += _dot_flops(ins, comp)
                elif ins.opcode == "fusion":
                    callee = _called(ins.rest, "calls")
                    if callee:
                        f, t = self._fusion_internal_flops(callee)
                        flops += f
                        trans += t
                elif ins.opcode in ("exponential", "log", "tanh", "power",
                                    "logistic", "expm1", "log1p", "erf"):
                    n = _shape_elems(ins.shape)
                    flops += n
                    trans += n
                elif ins.opcode in _ELEMENTWISE_FLOPS:
                    flops += _shape_elems(ins.shape)
                elif ins.opcode in ("reduce", "reduce-window"):
                    flops += _shape_elems(ins.shape) * 2  # approx
        self._fusion_flops_cache[comp_name] = (flops, trans)
        return flops, trans

    def _fusion_class(self, comp_name: str) -> str:
        comp = self.comps.get(comp_name)
        if not comp:
            return "stream"
        ops = {i.opcode for i in comp.instrs}
        if ops & _CLASS_GATHER:
            return "gather"
        if ops & (_CLASS_STRIDED - {"reshape"}):
            return "strided"
        return "stream"

    def _fusion_param_consumers(self, comp_name: str) -> dict[int, float]:
        """param index -> bytes actually touched, for params feeding a
        slicing/updating op: ds/gather/slice read only their result;
        dynamic-update-slice touches only its update region (the rest of the
        buffer is aliased in place)."""
        comp = self.comps.get(comp_name)
        if not comp:
            return {}
        param_idx: dict[str, int] = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)", "parameter(" + ins.rest)
                if m:
                    param_idx[ins.name] = int(m.group(1))

        def trace_param(name: str) -> int | None:
            for _ in range(8):  # walk light wrappers back to the param
                if name in param_idx:
                    return param_idx[name]
                prod = comp.by_name.get(name)
                if prod is None or prod.opcode not in (
                        "bitcast", "copy", "convert", "reshape")                         or not prod.operands:
                    return None
                name = prod.operands[0]
            return None

        out: dict[int, float] = {}
        for ins in comp.instrs:
            if ins.opcode in ("dynamic-slice", "gather", "slice"):
                if ins.operands:
                    idx = trace_param(ins.operands[0])
                    if idx is not None:
                        out[idx] = out.get(idx, 0.0) + shape_bytes(ins.shape)
            elif ins.opcode == "dynamic-update-slice":
                if ins.operands:
                    idx = trace_param(ins.operands[0])
                    if idx is not None:
                        upd = (shape_bytes(comp.shapes.get(ins.operands[1], ""))
                               if len(ins.operands) > 1 else 0.0)
                        out[idx] = out.get(idx, 0.0) + upd
        return out

    def _fusion_result_bytes(self, comp_name: str, default: float) -> float:
        """Result write size: a dus-rooted fusion writes only the update."""
        comp = self.comps.get(comp_name)
        if not comp:
            return default
        name = comp.root
        for _ in range(8):  # walk light wrappers
            ins = comp.by_name.get(name)
            if ins is None:
                return default
            if ins.opcode == "dynamic-update-slice":
                if len(ins.operands) > 1:
                    return shape_bytes(comp.shapes.get(ins.operands[1], ""))
                return default
            if ins.opcode in ("bitcast", "copy", "convert", "reshape",
                              "tuple") and ins.operands:
                name = ins.operands[0]
                continue
            return default
        return default

    def _region_input_bytes(self, ins: Instr, comp: Computation,
                            caps: dict[str, float] | None = None) -> float:
        """HBM bytes read by the fused region rooted at ``ins``: walk back
        through light (fusion-internal) producers to materialized values /
        parameters; get-tuple-element reads charge their own element size
        (loop carries), not the whole tuple.  ``caps`` bounds specific
        operand reads (the fusion-internal-slice case)."""
        seen: set[str] = set()
        total = 0.0
        stack = list(ins.operands)
        for name in list(stack):
            if caps and name in caps:
                total += caps[name]
                seen.add(name)
        stack = [n for n in stack if n not in seen]
        while stack:
            name = stack.pop()
            if name in seen:
                continue
            seen.add(name)
            prod = comp.by_name.get(name)
            if prod is None:
                continue
            if prod.opcode == "constant":
                continue
            if prod.opcode == "get-tuple-element":
                total += shape_bytes(prod.shape)
                continue
            if prod.opcode == "parameter" or self._materialized(prod, comp):
                total += shape_bytes(prod.shape)
                continue
            stack.extend(prod.operands)
        return total

    # ---- per-instruction traffic/flops ----
    def _instr_cost(self, ins: Instr, comp: Computation) -> HloCost:
        c = HloCost()
        op = ins.opcode
        base = op[:-6] if op.endswith("-start") else op
        if op in _NO_TRAFFIC or op.endswith("-done"):
            if op == "custom-call":
                c.warnings.append(f"custom-call {ins.name} uncounted")
            return c

        result_b = shape_bytes(ins.shape)
        operand_b = sum(shape_bytes(comp.shapes.get(o, "")) for o in ins.operands)
        reads = (self._region_input_bytes(ins, comp) if self.fused
                 else operand_b)

        if base in COLLECTIVE_KINDS:
            g = _group_size(ins.rest)
            operand, wire = _collective_from(base, result_b, g)
            c.collective_operand_bytes = operand
            c.collective_wire_bytes = wire
            c.collective_by_kind[base] = operand
            c.n_collectives = 1
            return c

        if op == "while":
            body = self.comps.get(_called(ins.rest, "body") or "")
            cond = self.comps.get(_called(ins.rest, "condition") or "")
            trips = _while_trips(cond) if cond else 1
            inner = HloCost()
            if body:
                inner.add(self.comp_cost(body.name))
            if cond:
                inner.add(self.comp_cost(cond.name))
            c.add(inner.scaled(trips))
            return c

        if op in ("call", "conditional"):
            for key in ("to_apply", "true_computation", "false_computation",
                        "branch_computations"):
                callee = _called(ins.rest, key)
                if callee and callee in self.comps:
                    c.add(self.comp_cost(callee))
            return c

        if op == "fusion":
            callee = _called(ins.rest, "calls") or ""
            flops, trans = self._fusion_internal_flops(callee)
            c.flops = flops
            c.transcendentals = trans
            if not self._materialized(ins, comp):
                return c  # light elementwise wrapper — fuses away on TPU
            sliced = self._fusion_param_consumers(callee)
            caps = {}
            for i, o in enumerate(ins.operands):
                if i in sliced:
                    caps[o] = min(shape_bytes(comp.shapes.get(o, "")),
                                  sliced[i])
            if self.fused:
                b = (self._fusion_result_bytes(callee, result_b)
                     + self._region_input_bytes(ins, comp, caps))
            else:
                b = result_b
                for i, o in enumerate(ins.operands):
                    ob = shape_bytes(comp.shapes.get(o, ""))
                    b += min(ob, sliced[i]) if i in sliced else ob
            c.bytes_by_class[self._fusion_class(callee)] = b
            return c

        # plain instructions
        if op == "dot":
            c.flops = _dot_flops(ins, comp)
            c.bytes_by_class["stream"] = reads + result_b
            return c
        if op == "gather":
            c.bytes_by_class["gather"] = 2.0 * result_b
            return c
        if op == "dynamic-slice":
            c.bytes_by_class["stream"] = 2.0 * result_b
            return c
        if op == "dynamic-update-slice":
            upd = (shape_bytes(comp.shapes.get(ins.operands[1], ""))
                   if len(ins.operands) > 1 else result_b)
            c.bytes_by_class["stream"] = 2.0 * upd
            return c
        if op == "scatter":
            upd = (shape_bytes(comp.shapes.get(ins.operands[2], ""))
                   if len(ins.operands) > 2 else result_b)
            c.bytes_by_class["gather"] = 3.0 * upd
            return c
        if op in ("reduce", "reduce-window"):
            c.flops = operand_b and _shape_elems(
                comp.shapes.get(ins.operands[0], ins.shape))
            c.bytes_by_class["stream"] = reads + result_b
            return c
        if op == "sort":
            n = _shape_elems(ins.shape)
            c.flops = n * max(1.0, math.log2(max(n, 2)))
            c.bytes_by_class["strided"] = reads + result_b
            return c
        cls = ("gather" if op in _CLASS_GATHER
               else "strided" if op in _CLASS_STRIDED and op != "reshape"
               else "stream")
        if op in _ELEMENTWISE_FLOPS:
            c.flops = _shape_elems(ins.shape)
            if op in ("exponential", "log", "tanh", "power", "logistic",
                      "expm1", "log1p", "erf"):
                c.transcendentals = c.flops
        if op == "reshape":
            return c  # layout-preserving reshapes are free at HLO level
        if not self._materialized(ins, comp):
            return c  # fusion-internal (VMEM) — no HBM traffic
        c.bytes_by_class[cls] += reads + result_b
        return c

    def comp_cost(self, comp_name: str) -> HloCost:
        if comp_name in self._comp_cost_cache:
            return self._comp_cost_cache[comp_name]
        comp = self.comps[comp_name]
        total = HloCost()
        # guard against recursion
        self._comp_cost_cache[comp_name] = total
        for ins in comp.instrs:
            total.add(self._instr_cost(ins, comp))
        self._comp_cost_cache[comp_name] = total
        return total

    def entry_comp(self) -> Computation | None:
        """The module's ENTRY computation, or None for degenerate modules
        (constant-folded steps can compile to a body the line parser sees
        no computations in at all)."""
        for comp in self.comps.values():
            if comp.is_entry:
                return comp
        return None

    def entry_cost(self) -> HloCost:
        entry = self.entry_comp()
        if entry is None:
            # A fully constant-folded module is a valid, zero-traffic
            # workload — report it as such rather than failing the whole
            # model walk.
            c = HloCost()
            c.warnings.append("no ENTRY computation found; empty cost")
            return c
        return self.comp_cost(entry.name)


def analyze(hlo_text: str, fused: bool = True) -> HloCost:
    """Full-module trip-aware cost (FLOPs, per-class bytes, collectives)."""
    return Analyzer(hlo_text, fused=fused).entry_cost()
