"""TPU memory-system parameters and the access-class taxonomy.

This is the hardware-adaptation of the paper's Table I + Table III to the
TPU target (DESIGN.md S2).  The LSU types become *access classes* of
HLO-level memory traffic; the DRAM datasheet becomes the TPU v5e datasheet
constants plus HBM transaction parameters.

Class mapping (paper -> TPU):

    BC_ALIGNED        -> STREAM      contiguous tile-aligned HBM traffic
    BC_NON_ALIGNED    -> STRIDED     layout-changing / sub-transaction rows
    BC_WRITE_ACK      -> GATHER      data-dependent row gather/scatter
    ATOMIC_PIPELINED  -> SERIALIZED  collision-prone scatter-accumulate
    PIPELINED (local) -> VMEM        on-chip, no HBM traffic

Each class has the same two-term structure as the paper's model: a bandwidth
term at class efficiency ``K`` (the `K_lsu` analogue) and a per-transaction
latency term ``T_row`` amortized by the memory-level parallelism the access
pattern allows (the bank-interleaving analogue of Eq. 4).
"""
from __future__ import annotations

import dataclasses
import enum


class AccessClass(enum.Enum):
    STREAM = "stream"
    STRIDED = "strided"
    GATHER = "gather"
    SERIALIZED = "serialized"
    VMEM = "vmem"


@dataclasses.dataclass(frozen=True)
class TpuParams:
    """TPU chip + interconnect constants (v5e datasheet values as given)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # HBM bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per ICI link (~50 GB/s/link)
    ici_links: int = 4                  # links per chip on a 2D torus
    hbm_bytes: float = 16e9             # HBM capacity per chip
    vmem_bytes: float = 128e6           # VMEM per chip (order of magnitude)
    # HBM transaction model (the burst/`dq*bl` analogue):
    txn_bytes: int = 512                # HBM transaction granularity
    t_row: float = 28e-9                # row-miss latency (tRCD+tRP class)
    mlp: int = 64                       # outstanding-transaction parallelism
    ici_hop_latency: float = 1e-6       # per-hop collective launch latency
    # Class efficiency factors K (the K_lsu analogue; fraction of peak HBM
    # bandwidth a pure stream of this class sustains):
    k_stream: float = 0.92              # refresh + arbitration losses
    k_strided: float = 0.92             # before the sub-row penalty below
    k_gather: float = 0.92              # before the per-row transaction waste

    @property
    def ridge_flops_per_byte(self) -> float:
        """Roofline ridge point: FLOP/byte where compute == memory time."""
        return self.peak_flops / self.hbm_bw


def _as_tpu_params(hw) -> TpuParams:
    """Normalize ``hw`` to a :class:`TpuParams` view.

    Accepts ``None`` (the registry's ``tpu_v5e`` preset), a ``TpuParams``,
    or anything with a ``tpu_params()`` view (a ``repro.hw.Hardware`` spec)
    — the hook that threads the unified spec through every model path.
    """
    if hw is None:
        from repro.hw import DEFAULT_CHIP, get as _get

        return _get(DEFAULT_CHIP).tpu_params()
    view = getattr(hw, "tpu_params", None)
    if callable(view):
        return view()
    return hw


# TPU_V5E moved to the registry-backed spec layer (repro.hw.presets,
# "tpu_v5e") in 0.4, warned as a PEP-562 alias through 0.5, and is gone as
# of 0.6 — use repro.hw.get("tpu_v5e").tpu_params() (or repro.TPU_V5E).


@dataclasses.dataclass(frozen=True)
class Traffic:
    """One classified traffic component of a compiled step (the Lsu analogue).

    ``bytes`` counts *useful* bytes; ``row_bytes`` is the contiguous run
    length of the access pattern (minor-dim extent for strided ops, the
    gathered row size for gathers) — the paper's ``ls_width``/``delta``
    information collapsed to what HLO exposes.
    """

    access_class: AccessClass
    nbytes: float
    row_bytes: float = 512.0
    name: str = ""


def traffic_time(t: Traffic, hw=None) -> tuple[float, float]:
    """(T_ideal, T_ovh) for one traffic component — Eqs. 2 and 4 transplanted.

    ``hw`` may be a :class:`TpuParams`, a ``repro.hw.Hardware`` spec, or
    ``None`` (the registry's ``tpu_v5e`` preset).

    * T_ideal = useful bytes / peak HBM bandwidth (identical for all classes,
      exactly like Eq. 2).
    * T_ovh   = wasted-transaction transfer time + per-transaction row
      latency amortized over the class's memory-level parallelism.
    """
    hw = _as_tpu_params(hw)
    t_ideal = t.nbytes / hw.hbm_bw
    if t.access_class is AccessClass.VMEM or t.nbytes <= 0:
        return t_ideal, 0.0

    if t.access_class is AccessClass.STREAM:
        # only the stream-efficiency loss (the 14.93 -> 14.2 GB/s analogue)
        t_ovh = t.nbytes / (hw.hbm_bw * hw.k_stream) - t_ideal
        return t_ideal, max(0.0, t_ovh)

    row = max(1.0, t.row_bytes)
    txns_per_row = max(1.0, -(-row // hw.txn_bytes))        # ceil
    fetched_per_row = txns_per_row * hw.txn_bytes
    waste = max(0.0, fetched_per_row / row - 1.0)           # Eq. 8 analogue
    n_rows = t.nbytes / row
    n_txn = n_rows * txns_per_row

    if t.access_class is AccessClass.STRIDED:
        t_ovh = (t.nbytes * waste) / (hw.hbm_bw * hw.k_strided)
        t_ovh += t.nbytes / (hw.hbm_bw * hw.k_strided) - t_ideal
        return t_ideal, max(0.0, t_ovh)

    if t.access_class is AccessClass.GATHER:
        # wasted transfer + one T_row per transaction, amortized over the
        # outstanding-transaction parallelism (bank interleaving analogue).
        t_ovh = (t.nbytes * waste) / (hw.hbm_bw * hw.k_gather)
        t_ovh += n_txn * hw.t_row / hw.mlp
        return t_ideal, t_ovh

    # SERIALIZED: Eq. 10 — a full read+write row cycle per transaction, no
    # amortization (collisions serialize).
    t_ovh = n_txn * (2.0 * hw.t_row)
    return t_ideal, t_ovh


def memory_time(components: list[Traffic], hw=None) -> float:
    """Eq. 1 transplanted: sum of per-class (T_ideal + T_ovh)."""
    hw = _as_tpu_params(hw)
    return sum(sum(traffic_time(c, hw)) for c in components)


def memory_time_batch(bytes_by_class, hw=None, *,
                      row_bytes: float = 512.0):
    """Vectorized ``memory_time`` over a batch of compiled steps.

    ``bytes_by_class`` maps an :class:`AccessClass` (or its value string) to
    an array of useful-byte totals, one entry per step; returns the per-step
    memory time array.  Matches the scalar ``traffic_time`` sum exactly for
    the same ``row_bytes`` (the autotune batched ranker relies on this;
    cross-checked in tests).
    """
    import numpy as np

    hw = _as_tpu_params(hw)
    total = None
    for cls, nbytes in bytes_by_class.items():
        if isinstance(cls, str):
            cls = AccessClass(cls)
        b = np.asarray(nbytes, dtype=np.float64)
        t_ideal = b / hw.hbm_bw
        if cls is AccessClass.VMEM:
            t_ovh = np.zeros_like(b)
        elif cls is AccessClass.STREAM:
            t_ovh = np.maximum(0.0, b / (hw.hbm_bw * hw.k_stream) - t_ideal)
        else:
            row = max(1.0, row_bytes)
            txns_per_row = max(1.0, -(-row // hw.txn_bytes))      # ceil
            fetched_per_row = txns_per_row * hw.txn_bytes
            waste = max(0.0, fetched_per_row / row - 1.0)
            n_txn = (b / row) * txns_per_row
            if cls is AccessClass.STRIDED:
                t_ovh = np.maximum(
                    0.0, (b * waste) / (hw.hbm_bw * hw.k_strided)
                    + b / (hw.hbm_bw * hw.k_strided) - t_ideal)
            elif cls is AccessClass.GATHER:
                t_ovh = ((b * waste) / (hw.hbm_bw * hw.k_gather)
                         + n_txn * hw.t_row / hw.mlp)
            else:                                                 # SERIALIZED
                t_ovh = n_txn * (2.0 * hw.t_row)
        contrib = t_ideal + np.where(b > 0, t_ovh, 0.0)
        total = contrib if total is None else total + contrib
    if total is None:
        return np.zeros(0, dtype=np.float64)
    return total
