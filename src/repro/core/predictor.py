"""Step-time prediction from compiled (never executed) artifacts.

`predict_step()` is the paper's Eq. 1 pipeline transplanted (DESIGN.md S2);
the public surface is ``repro.Session.predict`` (the old module-level
``predict`` name is a deprecated alias):

  1. statically analyze the compiled module with the trip-count-aware HLO
     counter (`hlo_counter.analyze` -- the LSU-type report reader; XLA's own
     ``cost_analysis`` under-counts scan bodies by the trip count);
  2. apply the two-term access-class model (`hbm.traffic_time` -- the
     Eq. 2 / Eq. 4-10 transplant) to the per-class byte totals;
  3. add the collective family (`wire bytes / ICI bw + hop latency`) -- the
     beyond-paper extension for the pod interconnect;
  4. the memory-bound criterion (Eq. 3 analogue) compares the resulting
     resource times (arithmetic intensity vs. the chip's ridge point).

All times are per-device seconds for one step.  ``cost`` (from
``hlo.cost_analysis_stats``) is optional and only recorded for cross-checks.
"""
from __future__ import annotations

import dataclasses

from repro.core import hbm as _hbm
from repro.core import hlo_counter as _hc
from repro.core.hbm import AccessClass, TpuParams, Traffic, _as_tpu_params

_CLASS_BY_NAME = {
    "stream": AccessClass.STREAM,
    "strided": AccessClass.STRIDED,
    "gather": AccessClass.GATHER,
    "serialized": AccessClass.SERIALIZED,
}


@dataclasses.dataclass(frozen=True)
class StepPrediction:
    t_compute: float
    t_memory: float
    t_collective: float
    memory_components: tuple[Traffic, ...]
    flops: float
    hbm_bytes: float
    collective_wire_bytes: float
    collective_operand_bytes: float
    n_collectives: float
    collective_by_kind: dict
    xla_cost: dict

    @property
    def t_step_serial(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def t_step_overlapped(self) -> float:
        """Perfect overlap: the slowest resource wins (roofline assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def memory_bound(self) -> bool:
        """Eq. 3 analogue."""
        return self.bottleneck != "compute"

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.hbm_bytes if self.hbm_bytes else float("inf")


def components_from_cost(hc: _hc.HloCost, *,
                         gather_row_bytes: float = 512.0) -> list[Traffic]:
    out = []
    for name, b in sorted(hc.bytes_by_class.items()):
        cls = _CLASS_BY_NAME.get(name, AccessClass.STREAM)
        row = gather_row_bytes if cls is not AccessClass.STREAM else 512.0
        out.append(Traffic(cls, b, row_bytes=row, name=name))
    return out


def predict_step(
    hlo_text: str,
    cost: dict | None = None,
    hw: TpuParams | None = None,
    *,
    gather_row_bytes: float = 512.0,
) -> StepPrediction:
    """Predict per-device step time from ``compiled.as_text()``.

    ``hw`` may be a :class:`TpuParams`, a ``repro.hw.Hardware`` spec, or
    ``None`` (the registry's ``tpu_v5e`` preset).
    """
    hw = _as_tpu_params(hw)
    hc = _hc.analyze(hlo_text)
    comps = components_from_cost(hc, gather_row_bytes=gather_row_bytes)
    t_mem = _hbm.memory_time(comps, hw)
    t_coll = (hc.collective_wire_bytes / (hw.ici_bw * hw.ici_links)
              + hc.n_collectives * hw.ici_hop_latency)
    return StepPrediction(
        t_compute=hc.flops / hw.peak_flops,
        t_memory=t_mem,
        t_collective=t_coll,
        memory_components=tuple(comps),
        flops=hc.flops,
        hbm_bytes=hc.total_bytes,
        collective_wire_bytes=hc.collective_wire_bytes,
        collective_operand_bytes=hc.collective_operand_bytes,
        n_collectives=hc.n_collectives,
        collective_by_kind=dict(hc.collective_by_kind),
        xla_cost=dict(cost or {}),
    )


