"""Bounded-memory streaming evaluation of huge design spaces.

The sweep engine's materialized path (:func:`repro.core.sweep._build`) holds
every point, estimate and resource value in memory before any selection
runs, so a 10M-point sweep is memory-prohibitive by construction.  This
module supplies the streaming counterpart:

* :class:`GridEnumerator` — a lazy Cartesian-product enumerator.  A design
  point is a single integer id in ``[0, n)``; per-axis indices come out of
  mixed-radix arithmetic (``(ids // stride) % size``), bit-identical to the
  order ``np.meshgrid(..., indexing="ij")`` used to materialize, with no
  O(n) allocation anywhere.
* **Online reducers** — :class:`ParetoReducer`, :class:`TopKReducer` and
  :class:`StatsReducer` fold one scored chunk at a time into a running
  Pareto front, a bounded best-``k`` selection and exact summary stats, so
  peak memory is O(chunk + front + k) regardless of sweep size (times the
  worker count when the thread-pool path holds several chunks in flight).
* :func:`run_stream` — the chunk loop: fixed-shape chunks (the last one
  padded so a jit-compiled estimator compiles exactly once per chunk
  shape), masked before folding, optionally pipelined through a thread
  pool for the numpy backend.

A *chunk-column* dict is the currency between the evaluator and the
reducers: ``id`` (global point ids), the normalized numeric axis values,
integer codes for the categorical axes, the per-point estimate fields
(``t_exe``, ``t_ideal``, ``t_ovh``, ``bound_ratio``, ``memory_bound``,
``total_bytes``, ``n_lsu``) and ``resource``.  Every column is a plain
1-D array of the chunk length — no object dtype on the hot path.

The folded result is order- and chunk-size-invariant for the Pareto front
and bit-equal to the materialized path for front membership, top-k rows
and summary stats (tests/test_stream.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

#: Estimate columns every evaluator must provide per chunk.
ESTIMATE_COLUMNS = ("t_exe", "t_ideal", "t_ovh", "bound_ratio",
                    "memory_bound", "total_bytes", "n_lsu")


class GridEnumerator:
    """Lazy mixed-radix view of the Cartesian product of normalized axes.

    ``lists`` maps axis name -> list of values (the output of
    ``sweep._normalize_axes``).  Point ids count through the product in C
    order (first axis slowest), exactly matching the materialized
    ``_grid_points`` layout, so point ``i`` here is point ``i`` there.
    """

    def __init__(self, lists: Mapping[str, Sequence]):
        self.lists = {k: list(v) for k, v in lists.items()}
        self.names = list(self.lists)
        self.sizes = np.asarray([len(v) for v in self.lists.values()],
                                dtype=np.int64)
        if np.any(self.sizes == 0):
            raise ValueError("empty sweep: every axis needs at least one value")
        # stride of axis i = product of the sizes of all later axes
        strides = np.ones(len(self.sizes), dtype=np.int64)
        for i in range(len(self.sizes) - 2, -1, -1):
            strides[i] = strides[i + 1] * self.sizes[i + 1]
        self.strides = strides
        self.n = int(self.sizes.prod()) if len(self.sizes) else 0

    def codes(self, ids: np.ndarray) -> dict[str, np.ndarray]:
        """Per-axis index arrays for the given point ids (no materialization)."""
        ids = np.asarray(ids, dtype=np.int64)
        return {name: (ids // self.strides[i]) % self.sizes[i]
                for i, name in enumerate(self.names)}


def _concat(held: dict[str, np.ndarray] | None,
            cols: Mapping[str, np.ndarray]) -> dict[str, np.ndarray]:
    if held is None:
        return {k: np.asarray(v) for k, v in cols.items()}
    return {k: np.concatenate([held[k], np.asarray(cols[k])]) for k in held}


def _take(cols: Mapping[str, np.ndarray], idx) -> dict[str, np.ndarray]:
    return {k: np.asarray(v)[idx] for k, v in cols.items()}


class Reducer:
    """Protocol of an online reducer: fold chunk columns, read state back."""

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        raise NotImplementedError


class StatsReducer(Reducer):
    """Exact running summary: counts, min (earliest id on ties), sums.

    ``n_points``, ``memory_bound`` and ``t_exe_min`` are bit-equal to their
    materialized counterparts under any chunking; the sums accumulate one
    float64 partial per chunk (agreement ~1e-12 relative).
    """

    def __init__(self):
        self.n_points = 0
        self.memory_bound = 0
        self.t_exe_min = math.inf
        self.t_exe_min_id = -1
        self.t_exe_sum = 0.0
        self.total_bytes_sum = 0.0

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        t = np.asarray(cols["t_exe"])
        if not len(t):
            return
        self.n_points += len(t)
        self.memory_bound += int(np.asarray(cols["memory_bound"]).sum())
        self.t_exe_sum += float(t.sum())
        self.total_bytes_sum += float(np.asarray(cols["total_bytes"]).sum())
        i = int(np.argmin(t))                  # first occurrence on ties
        if float(t[i]) < self.t_exe_min:       # strict: keep the earliest id
            self.t_exe_min = float(t[i])
            self.t_exe_min_id = int(np.asarray(cols["id"])[i])

    def summary(self) -> dict:
        return {
            "n_points": self.n_points,
            "memory_bound_points": self.memory_bound,
            "t_exe_min": self.t_exe_min,
            "t_exe_min_id": self.t_exe_min_id,
            "t_exe_sum": self.t_exe_sum,
            "total_bytes_sum": self.total_bytes_sum,
        }


class TopKReducer(Reducer):
    """Bounded best-``k`` selection by one column (ascending).

    Each fold concatenates the held rows with the chunk, cuts to the ``k``
    smallest with ``np.argpartition`` and breaks value ties by point id, so
    the surviving rows are exactly the first ``k`` of a stable argsort over
    the whole space — bit-equal to the materialized ``top_k``.
    """

    def __init__(self, k: int = 10, key: str = "t_exe"):
        if k < 1:
            raise ValueError("top-k needs k >= 1")
        self.k = int(k)
        self.key = key
        self.cols: dict[str, np.ndarray] | None = None

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        merged = _concat(self.cols, cols)
        vals = np.asarray(merged[self.key], dtype=np.float64)
        if len(vals) > self.k:
            # argpartition bounds the exact-order work to the candidate set:
            # everything at or below the k-th value competes, then value
            # ties are broken by id (== original position, since ids only
            # grow across folds) to match a stable full argsort.
            part = np.argpartition(vals, self.k - 1)[:self.k]
            cutoff = float(vals[part].max())
            cand = np.flatnonzero(vals <= cutoff)
            order = cand[np.lexsort((merged["id"][cand], vals[cand]))][:self.k]
        else:
            order = np.lexsort((merged["id"], vals))
        self.cols = _take(merged, order)       # kept in rank order

    @property
    def ids(self) -> np.ndarray:
        """Selected point ids, best first."""
        return (np.empty(0, dtype=np.int64) if self.cols is None
                else np.asarray(self.cols["id"], dtype=np.int64))


class ParetoReducer(Reducer):
    """Running Pareto front over the given objective columns (minimized).

    Folding is just ``pareto_front`` over (held front + chunk); because
    every globally non-dominated point survives any partial fold and every
    dominated point is dominated by some front member, the final front is
    invariant to chunk size and chunk order (tests/test_stream.py property).
    Memory is O(front).
    """

    def __init__(self, objectives: Sequence[str] = ("t_exe", "resource")):
        if not objectives:
            raise ValueError("pareto needs at least one objective column")
        self.objectives = tuple(objectives)
        self.cols: dict[str, np.ndarray] | None = None

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        from repro.core.sweep import pareto_front

        merged = _concat(self.cols, cols)
        vals = np.stack([np.asarray(merged[o], dtype=np.float64)
                         for o in self.objectives], axis=1)
        self.cols = _take(merged, pareto_front(vals))

    @property
    def ids(self) -> np.ndarray:
        """Front point ids, ascending."""
        if self.cols is None:
            return np.empty(0, dtype=np.int64)
        return np.sort(np.asarray(self.cols["id"], dtype=np.int64))


def default_reducers(k: int = 10) -> tuple[Reducer, ...]:
    """The reducer set ``Session.sweep`` streams into unless told otherwise."""
    return (ParetoReducer(), TopKReducer(k), StatsReducer())


@dataclasses.dataclass(frozen=True)
class StreamOutcome:
    """What ``run_stream`` hands back: the folded reducers + loop telemetry."""

    reducers: tuple[Reducer, ...]
    n_points: int
    n_chunks: int
    chunk_size: int


def run_stream(
    n: int,
    chunk_size: int,
    eval_chunk: Callable[[np.ndarray], Mapping[str, np.ndarray]],
    reducers: Iterable[Reducer],
    *,
    workers: int | None = None,
    chunk_order: Sequence[int] | None = None,
) -> StreamOutcome:
    """Drive ``eval_chunk`` over ``n`` points in fixed-shape chunks.

    ``eval_chunk(ids)`` always receives exactly ``chunk_size`` ids — the
    last chunk is padded by repeating its final valid id, so a jit-compiled
    evaluator sees one shape only and compiles exactly once.  The padded
    tail is sliced off every returned column before the reducers fold it.

    ``workers > 1`` evaluates chunks through a thread pool while folding
    strictly in submission order, so results are identical to the serial
    loop (the reducers themselves are order-invariant for the Pareto front,
    but top-k tie-breaking and stats argmins rely on ascending ids).

    ``chunk_order`` permutes which chunk is evaluated when (testing hook
    for the order-invariance property); folding follows that order.
    """
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    reducers = tuple(reducers)
    starts = list(range(0, n, chunk_size))
    if chunk_order is not None:
        starts = [starts[i] for i in chunk_order]

    def ids_for(start: int) -> tuple[np.ndarray, int]:
        stop = min(start + chunk_size, n)
        ids = np.arange(start, stop, dtype=np.int64)
        if len(ids) < chunk_size:
            ids = np.concatenate(
                [ids, np.full(chunk_size - len(ids), ids[-1], dtype=np.int64)])
        return ids, stop - start

    def fold(cols: Mapping[str, np.ndarray], valid: int) -> None:
        if valid != chunk_size:
            cols = {k: np.asarray(v)[:valid] for k, v in cols.items()}
        for r in reducers:
            r.update(cols)

    if workers and workers > 1 and len(starts) > 1:
        from collections import deque
        from concurrent.futures import ThreadPoolExecutor

        w = int(workers)
        with ThreadPoolExecutor(max_workers=w) as ex:
            # At most w+1 chunks exist at once (in flight or awaiting their
            # in-order fold), so the threaded path's peak memory is
            # O(workers * chunk + front + k), not unbounded.
            pending: deque = deque()
            for s in starts:
                ids, valid = ids_for(s)
                pending.append((ex.submit(eval_chunk, ids), valid))
                if len(pending) > w:          # fold in submission order
                    fut, v = pending.popleft()
                    fold(fut.result(), v)
            while pending:
                fut, v = pending.popleft()
                fold(fut.result(), v)
    else:
        for s in starts:
            ids, valid = ids_for(s)
            fold(eval_chunk(ids), valid)

    return StreamOutcome(reducers=reducers, n_points=n,
                         n_chunks=len(starts), chunk_size=chunk_size)
