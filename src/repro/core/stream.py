"""Bounded-memory streaming evaluation of huge design spaces.

The sweep engine's materialized path (:func:`repro.core.sweep._build`) holds
every point, estimate and resource value in memory before any selection
runs, so a 10M-point sweep is memory-prohibitive by construction.  This
module supplies the streaming counterpart:

* :class:`GridEnumerator` — a lazy Cartesian-product enumerator.  A design
  point is a single integer id in ``[0, n)``; per-axis indices come out of
  mixed-radix arithmetic (``(ids // stride) % size``), bit-identical to the
  order ``np.meshgrid(..., indexing="ij")`` used to materialize, with no
  O(n) allocation anywhere.  An empty axis makes an empty (``n == 0``)
  grid, not an error — the sweep then folds nothing and reports empty.
* **Online mergeable reducers** — :class:`ParetoReducer`,
  :class:`TopKReducer` and :class:`StatsReducer` fold one scored chunk at
  a time into a running Pareto front, a bounded best-``k`` selection and
  exact summary stats, so peak memory is O(chunk + front + k) regardless
  of sweep size.  Every reducer also implements the **merge protocol**
  (``merge`` / ``state_dict`` / ``from_state`` / ``fresh``): fold any
  partition of ``[0, n)`` into independent reducers, merge the states, and
  the result is bit-equal to the serial single-pass fold (variance, which
  combines through the parallel/Chan formula, agrees to ~1e-12 under
  re-grouping).  That invariance is what the coordinator/worker executor
  (:mod:`repro.core.distributed`) is built on.
* :class:`SweepPlan` — a frozen, picklable, data-only description of one
  streaming sweep (normalized axis lists + backend + calibration + chunk
  size).  ``plan.evaluator()`` reconstructs the chunk-scoring closure from
  that data alone, so a fresh worker process can rebuild the exact same
  evaluation from a pickled (or JSON round-tripped) plan.
* :func:`run_stream` — the chunk loop: fixed-shape chunks (the last one
  padded so a jit-compiled estimator compiles exactly once per chunk
  shape), masked before folding, optionally pipelined through a thread
  pool for the numpy backend.

A *chunk-column* dict is the currency between the evaluator and the
reducers: ``id`` (global point ids), the normalized numeric axis values,
integer codes for the categorical axes, the per-point estimate fields
(``t_exe``, ``t_ideal``, ``t_ovh``, ``bound_ratio``, ``memory_bound``,
``total_bytes``, ``n_lsu``) and ``resource``.  Every column is a plain
1-D array of the chunk length — no object dtype on the hot path.

The folded result is order- and chunk-size-invariant for the Pareto front
and bit-equal to the materialized path for front membership, top-k rows
and summary stats (tests/test_stream.py, tests/test_distributed.py).
"""
from __future__ import annotations

import dataclasses
import json
import math
from time import perf_counter as _perf_counter
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

#: Estimate columns every evaluator must provide per chunk.
ESTIMATE_COLUMNS = ("t_exe", "t_ideal", "t_ovh", "bound_ratio",
                    "memory_bound", "total_bytes", "n_lsu")


class GridEnumerator:
    """Lazy mixed-radix view of the Cartesian product of normalized axes.

    ``lists`` maps axis name -> list of values (the output of
    ``sweep._normalize_axes``).  Point ids count through the product in C
    order (first axis slowest), exactly matching the materialized
    ``_grid_points`` layout, so point ``i`` here is point ``i`` there.

    An axis with no values makes the whole grid empty (``n == 0``): no
    point id exists, ``codes`` only ever sees empty id arrays, and the
    streaming loop builds no chunks.
    """

    def __init__(self, lists: Mapping[str, Sequence]):
        self.lists = {k: list(v) for k, v in lists.items()}
        self.names = list(self.lists)
        self.sizes = np.asarray([len(v) for v in self.lists.values()],
                                dtype=np.int64)
        # Strides/modulos are clamped to 1 so an empty axis (size 0) never
        # divides by zero; with n == 0 no id is ever decoded through them.
        sizes_c = np.maximum(self.sizes, 1)
        strides = np.ones(len(sizes_c), dtype=np.int64)
        for i in range(len(sizes_c) - 2, -1, -1):
            strides[i] = strides[i + 1] * sizes_c[i + 1]
        self.strides = strides
        self._mod = sizes_c
        self.n = int(self.sizes.prod()) if len(self.sizes) else 0

    def codes(self, ids: np.ndarray) -> dict[str, np.ndarray]:
        """Per-axis index arrays for the given point ids (no materialization)."""
        ids = np.asarray(ids, dtype=np.int64)
        return {name: (ids // self.strides[i]) % self._mod[i]
                for i, name in enumerate(self.names)}

    def encode(self, codes: Mapping[str, np.ndarray]) -> np.ndarray:
        """Point ids from per-axis index arrays (the inverse of ``codes``).

        This is how the discrete refinement of ``Session.optimize`` maps a
        neighborhood of axis indices back onto global point ids for the
        streaming evaluator.
        """
        out = None
        for i, name in enumerate(self.names):
            term = np.asarray(codes[name], dtype=np.int64) * self.strides[i]
            out = term if out is None else out + term
        return out if out is not None else np.empty(0, dtype=np.int64)


def _concat(held: dict[str, np.ndarray] | None,
            cols: Mapping[str, np.ndarray]) -> dict[str, np.ndarray]:
    if held is None:
        return {k: np.asarray(v) for k, v in cols.items()}
    return {k: np.concatenate([held[k], np.asarray(cols[k])]) for k in held}


def _take(cols: Mapping[str, np.ndarray], idx) -> dict[str, np.ndarray]:
    return {k: np.asarray(v)[idx] for k, v in cols.items()}


def _cols_to_state(cols: dict[str, np.ndarray] | None):
    """Held chunk columns as (dtype, nested-list) pairs — plain picklable
    primitives, lossless for float64/int64/bool round-trips."""
    if cols is None:
        return None
    return {k: [np.asarray(v).dtype.str, np.asarray(v).tolist()]
            for k, v in cols.items()}


def _cols_from_state(state) -> dict[str, np.ndarray] | None:
    if state is None:
        return None
    return {k: np.asarray(data, dtype=np.dtype(dt))
            for k, (dt, data) in state.items()}


class _ExactSum:
    """Exact, mergeable float accumulator (Shewchuk partials, the
    ``math.fsum`` algorithm).

    ``partials`` is a list of non-overlapping doubles whose mathematical
    sum *is* the running total — every ``add`` is exact, so accumulation
    is associative and commutative with no rounding anywhere, and
    ``value`` rounds the total exactly once.  Any grouping of the same
    addends therefore yields the bit-identical ``value``, which is what
    makes distributed stats merges bit-equal to the serial fold no matter
    how ``[0, n)`` was partitioned.
    """

    __slots__ = ("partials",)

    def __init__(self, partials: Iterable[float] = ()):
        self.partials = [float(p) for p in partials]

    def add(self, x: float) -> None:
        x = float(x)
        ps = self.partials
        i = 0
        for y in ps:
            if abs(x) < abs(y):
                x, y = y, x
            hi = x + y
            lo = y - (hi - x)
            if lo:
                ps[i] = lo
                i += 1
            x = hi
        ps[i:] = [x]

    def merge(self, other: "_ExactSum") -> None:
        for p in other.partials:
            self.add(p)

    @property
    def value(self) -> float:
        return math.fsum(self.partials)


def _tree_sum(x: np.ndarray) -> float:
    """Deterministic binary-tree sum of a 1-D float64 array.

    Zero-pads to the next power of two and repeatedly folds ``x[0::2] +
    x[1::2]``.  The pairing is a pure function of element *positions*, and
    zero-extension is exact for the non-negative summands the stats fold
    feeds it (``x + 0.0 == x``), so the result is independent of how much
    the array was padded — an array of ``m`` values zero-extended to any
    power of two >= ``m`` sums to the same bits.  That is the contract that
    lets the fixed-shape on-device fold (:mod:`repro.core.device_stream`),
    which always sums a full zero-masked chunk, reproduce the host fold's
    per-chunk sums bit-for-bit.
    """
    m = len(x)
    if m == 0:
        return 0.0
    buf = np.zeros(1 << (m - 1).bit_length(), dtype=np.float64)
    buf[:m] = x
    while len(buf) > 1:
        buf = buf[0::2] + buf[1::2]
    return float(buf[0])


def _chan_merge(n_a: int, mean_a: float, m2_a: float,
                n_b: int, mean_b: float, m2_b: float,
                ) -> tuple[int, float, float]:
    """Parallel (Chan et al.) combine of two (count, mean, M2) moment sets.

    Exact in exact arithmetic; in float64 the combined M2 agrees with the
    serial single-pass fold to ~1e-12 relative under any re-grouping.
    Combining with an empty side (n == 0, mean == 0, M2 == 0) is the
    identity bit-for-bit.
    """
    n = n_a + n_b
    if n == 0:
        return 0, 0.0, 0.0
    d = mean_b - mean_a
    mean = mean_a + d * (n_b / n)
    m2 = m2_a + m2_b + d * d * (n_a / n * n_b)
    return n, mean, m2


class Reducer:
    """Protocol of a mergeable online reducer.

    ``update(cols)`` folds one scored chunk.  The merge protocol lets
    independent reducers cover disjoint id ranges and be unioned:

    * ``fresh()`` — an empty reducer with this one's configuration;
    * ``state_dict()`` — accumulated state as picklable primitives;
    * ``from_state(state)`` — rebuild a reducer from ``state_dict()``;
    * ``merge(other)`` — fold another reducer's accumulation into this
      one; merging any partition of the id space must equal the serial
      fold (the distributed executor's correctness contract).

    Custom reducers passed to ``Session.sweep(..., executor="processes")``
    must implement all five and be picklable.
    """

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        raise NotImplementedError

    def merge(self, other: "Reducer") -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the merge protocol "
            f"(merge/state_dict/from_state/fresh) required for distributed "
            f"sweeps")

    def state_dict(self) -> dict:
        raise NotImplementedError(
            f"{type(self).__name__} does not implement state_dict()")

    @classmethod
    def from_state(cls, state: dict) -> "Reducer":
        raise NotImplementedError(
            f"{cls.__name__} does not implement from_state()")

    def fresh(self) -> "Reducer":
        raise NotImplementedError(
            f"{type(self).__name__} does not implement fresh()")


class StatsReducer(Reducer):
    """Exact running summary: counts, min (smallest id on ties), sums,
    mean and variance.

    ``n_points``, ``memory_bound``, ``t_exe_min``/``t_exe_min_id`` and the
    sums are bit-equal to the serial fold under *any* partition of the id
    space: the min tie-breaks lexicographically by (value, id) and the
    sums accumulate one float64 partial per chunk through an exact
    (Shewchuk) accumulator, so neither fold order nor merge grouping can
    perturb a bit.  The mean reported by ``summary()`` derives from the
    exact sum.  Variance combines through the parallel/Chan formula
    (:func:`_chan_merge`) — exact in exact arithmetic, ~1e-12 relative in
    float64 under re-grouping.
    """

    def __init__(self):
        self.n_points = 0
        self.memory_bound = 0
        self.t_exe_min = math.inf
        self.t_exe_min_id = -1
        self._t_exe_sum = _ExactSum()
        self._total_bytes_sum = _ExactSum()
        self._mean = 0.0        # Chan running mean of t_exe
        self._m2 = 0.0          # Chan running sum of squared deviations

    # Exact-sum reads (the public names predate the mergeable protocol).
    @property
    def t_exe_sum(self) -> float:
        return self._t_exe_sum.value

    @property
    def total_bytes_sum(self) -> float:
        return self._total_bytes_sum.value

    @property
    def t_exe_mean(self) -> float:
        return self._t_exe_sum.value / self.n_points if self.n_points else 0.0

    @property
    def t_exe_var(self) -> float:
        return self._m2 / self.n_points if self.n_points else 0.0

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        t = np.asarray(cols["t_exe"], dtype=np.float64)
        m = len(t)
        if not m:
            return
        self.memory_bound += int(np.asarray(cols["memory_bound"]).sum())
        # All chunk-level reductions go through the position-deterministic
        # _tree_sum so the fused on-device fold (device_stream), which sums
        # zero-masked fixed-shape chunks, produces bit-identical chunk
        # contributions to this host fold.
        s = _tree_sum(t)
        self._t_exe_sum.add(s)
        self._total_bytes_sum.add(
            _tree_sum(np.asarray(cols["total_bytes"], dtype=np.float64)))
        cmean = s / m
        cm2 = _tree_sum((t - cmean) ** 2)
        self.n_points, self._mean, self._m2 = _chan_merge(
            self.n_points, self._mean, self._m2, m, cmean, cm2)
        i = int(np.argmin(t))                  # first occurrence on ties
        v, pid = float(t[i]), int(np.asarray(cols["id"])[i])
        if v < self.t_exe_min or (v == self.t_exe_min
                                  and pid < self.t_exe_min_id):
            self.t_exe_min, self.t_exe_min_id = v, pid

    def merge(self, other: "Reducer") -> None:
        if not isinstance(other, StatsReducer):
            raise TypeError(f"cannot merge {type(other).__name__} into "
                            f"StatsReducer")
        if (other.t_exe_min < self.t_exe_min
                or (other.t_exe_min == self.t_exe_min
                    and other.t_exe_min_id < self.t_exe_min_id)):
            self.t_exe_min = other.t_exe_min
            self.t_exe_min_id = other.t_exe_min_id
        self.memory_bound += other.memory_bound
        self._t_exe_sum.merge(other._t_exe_sum)
        self._total_bytes_sum.merge(other._total_bytes_sum)
        self.n_points, self._mean, self._m2 = _chan_merge(
            self.n_points, self._mean, self._m2,
            other.n_points, other._mean, other._m2)

    def state_dict(self) -> dict:
        return {
            "n_points": self.n_points,
            "memory_bound": self.memory_bound,
            "t_exe_min": self.t_exe_min,
            "t_exe_min_id": self.t_exe_min_id,
            "t_exe_sum": list(self._t_exe_sum.partials),
            "total_bytes_sum": list(self._total_bytes_sum.partials),
            "mean": self._mean,
            "m2": self._m2,
        }

    @classmethod
    def from_state(cls, state: dict) -> "StatsReducer":
        r = cls()
        r.n_points = int(state["n_points"])
        r.memory_bound = int(state["memory_bound"])
        r.t_exe_min = float(state["t_exe_min"])
        r.t_exe_min_id = int(state["t_exe_min_id"])
        r._t_exe_sum = _ExactSum(state["t_exe_sum"])
        r._total_bytes_sum = _ExactSum(state["total_bytes_sum"])
        r._mean = float(state["mean"])
        r._m2 = float(state["m2"])
        return r

    def fresh(self) -> "StatsReducer":
        return StatsReducer()

    def summary(self) -> dict:
        return {
            "n_points": self.n_points,
            "memory_bound_points": self.memory_bound,
            "t_exe_min": self.t_exe_min,
            "t_exe_min_id": self.t_exe_min_id,
            "t_exe_sum": self.t_exe_sum,
            "total_bytes_sum": self.total_bytes_sum,
            "t_exe_mean": self.t_exe_mean,
            "t_exe_var": self.t_exe_var,
        }


class TopKReducer(Reducer):
    """Bounded best-``k`` selection by one column (ascending).

    Each fold concatenates the held rows with the chunk, cuts to the ``k``
    smallest with ``np.argpartition`` and breaks value ties by point id, so
    the surviving rows are exactly the first ``k`` of a stable argsort over
    the whole space — bit-equal to the materialized ``top_k``.  Because
    selection depends only on the (value, id) pairs, merging per-range
    top-k states (each of which contains every global-top-k candidate of
    its range) reproduces the global selection bit-for-bit under any
    partition.
    """

    def __init__(self, k: int = 10, key: str = "t_exe"):
        if k < 1:
            raise ValueError("top-k needs k >= 1")
        self.k = int(k)
        self.key = key
        self.cols: dict[str, np.ndarray] | None = None

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        merged = _concat(self.cols, cols)
        vals = np.asarray(merged[self.key], dtype=np.float64)
        if len(vals) > self.k:
            # argpartition bounds the exact-order work to the candidate set:
            # everything at or below the k-th value competes, then value
            # ties are broken by id (== original position, since ids only
            # grow across folds) to match a stable full argsort.
            part = np.argpartition(vals, self.k - 1)[:self.k]
            cutoff = float(vals[part].max())
            cand = np.flatnonzero(vals <= cutoff)
            order = cand[np.lexsort((merged["id"][cand], vals[cand]))][:self.k]
        else:
            order = np.lexsort((merged["id"], vals))
        self.cols = _take(merged, order)       # kept in rank order

    def merge(self, other: "Reducer") -> None:
        if not isinstance(other, TopKReducer) \
                or (other.k, other.key) != (self.k, self.key):
            raise ValueError(
                f"cannot merge top-k reducers with different configs: "
                f"k={self.k}/key={self.key!r} vs "
                f"k={getattr(other, 'k', None)}/"
                f"key={getattr(other, 'key', None)!r}")
        if other.cols is not None:
            self.update(other.cols)

    def state_dict(self) -> dict:
        return {"k": self.k, "key": self.key,
                "cols": _cols_to_state(self.cols)}

    @classmethod
    def from_state(cls, state: dict) -> "TopKReducer":
        r = cls(int(state["k"]), str(state["key"]))
        r.cols = _cols_from_state(state["cols"])
        return r

    def fresh(self) -> "TopKReducer":
        return TopKReducer(self.k, self.key)

    @property
    def ids(self) -> np.ndarray:
        """Selected point ids, best first."""
        return (np.empty(0, dtype=np.int64) if self.cols is None
                else np.asarray(self.cols["id"], dtype=np.int64))


class ParetoReducer(Reducer):
    """Running Pareto front over the given objective columns (minimized).

    Folding is just ``pareto_front`` over (held front + chunk); because
    every globally non-dominated point survives any partial fold and every
    dominated point is dominated by some front member, the final front is
    invariant to chunk size, chunk order and partition/merge grouping
    (tests/test_stream.py, tests/test_distributed.py).  Memory is O(front).
    """

    def __init__(self, objectives: Sequence[str] = ("t_exe", "resource")):
        if not objectives:
            raise ValueError("pareto needs at least one objective column")
        self.objectives = tuple(objectives)
        self.cols: dict[str, np.ndarray] | None = None

    def update(self, cols: Mapping[str, np.ndarray]) -> None:
        from repro.core.sweep import pareto_front

        merged = _concat(self.cols, cols)
        vals = np.stack([np.asarray(merged[o], dtype=np.float64)
                         for o in self.objectives], axis=1)
        self.cols = _take(merged, pareto_front(vals))

    def merge(self, other: "Reducer") -> None:
        if not isinstance(other, ParetoReducer) \
                or other.objectives != self.objectives:
            raise ValueError(
                f"cannot merge pareto reducers with different objectives: "
                f"{self.objectives} vs {getattr(other, 'objectives', None)}")
        if other.cols is not None:
            self.update(other.cols)

    def state_dict(self) -> dict:
        return {"objectives": list(self.objectives),
                "cols": _cols_to_state(self.cols)}

    @classmethod
    def from_state(cls, state: dict) -> "ParetoReducer":
        r = cls(tuple(state["objectives"]))
        r.cols = _cols_from_state(state["cols"])
        return r

    def fresh(self) -> "ParetoReducer":
        return ParetoReducer(self.objectives)

    @property
    def ids(self) -> np.ndarray:
        """Front point ids, ascending."""
        if self.cols is None:
            return np.empty(0, dtype=np.int64)
        return np.sort(np.asarray(self.cols["id"], dtype=np.int64))


def default_reducers(k: int = 10) -> tuple[Reducer, ...]:
    """The reducer set ``Session.sweep`` streams into unless told otherwise."""
    return (ParetoReducer(), TopKReducer(k), StatsReducer())


@dataclasses.dataclass(frozen=True)
class StreamOutcome:
    """What ``run_stream`` hands back: the folded reducers + loop telemetry."""

    reducers: tuple[Reducer, ...]
    n_points: int
    n_chunks: int
    chunk_size: int


def _chunk_ids(start: int, n: int, chunk_size: int) -> tuple[np.ndarray, int]:
    """The fixed-shape id block of the chunk at ``start`` and its valid
    length.  Only the final chunk of the *global* grid is ever padded (by
    repeating its last valid id), so a chunk's contents depend on nothing
    but (start, n, chunk_size) — the property that makes range-partitioned
    evaluation bit-identical to the serial pass."""
    stop = min(start + chunk_size, n)
    ids = np.arange(start, stop, dtype=np.int64)
    if len(ids) < chunk_size:
        ids = np.concatenate(
            [ids, np.full(chunk_size - len(ids), ids[-1], dtype=np.int64)])
    return ids, stop - start


def run_stream(
    n: int,
    chunk_size: int,
    eval_chunk: Callable[[np.ndarray], Mapping[str, np.ndarray]],
    reducers: Iterable[Reducer],
    *,
    workers: int | None = None,
    chunk_order: Sequence[int] | None = None,
    stage_times: dict | None = None,
) -> StreamOutcome:
    """Drive ``eval_chunk`` over ``n`` points in fixed-shape chunks.

    ``eval_chunk(ids)`` always receives exactly ``chunk_size`` ids — the
    last chunk is padded by repeating its final valid id, so a jit-compiled
    evaluator sees one shape only and compiles exactly once.  The padded
    tail is sliced off every returned column before the reducers fold it.
    ``n == 0`` builds no chunks at all and returns the reducers untouched.

    ``workers > 1`` evaluates chunks through a thread pool while folding
    strictly in submission order, so results are identical to the serial
    loop (the reducers themselves are order-invariant for the Pareto front,
    but top-k tie-breaking and stats argmins rely on ascending ids).

    ``chunk_order`` permutes which chunk is evaluated when (testing hook
    for the order-invariance property); folding follows that order.

    ``stage_times`` (a mutable dict) accumulates the per-stage wall-time
    breakdown ``Session.sweep(profile=True)`` reports: ``score_s`` (chunk
    evaluation, which on jax includes the host<->device ``transfer_s`` the
    evaluator itself accounts) and ``reduce_s`` (reducer folds).  Only the
    serial loop is instrumented — the threaded path overlaps stages, so
    per-stage attribution would be meaningless there.
    """
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    reducers = tuple(reducers)
    starts = list(range(0, n, chunk_size))
    if chunk_order is not None:
        starts = [starts[i] for i in chunk_order]

    def fold(cols: Mapping[str, np.ndarray], valid: int) -> None:
        # A constrained evaluator returns pre-compacted columns (feasible
        # rows only) — it can only come back full-length when every point
        # of a full chunk was feasible, so slicing off the padded tail is
        # needed exactly when the columns still have the fixed shape.
        if valid != chunk_size and len(cols["id"]) == chunk_size:
            cols = {k: np.asarray(v)[:valid] for k, v in cols.items()}
        if len(cols["id"]) == 0:
            return
        for r in reducers:
            r.update(cols)

    if workers and workers > 1 and len(starts) > 1:
        from collections import deque
        from concurrent.futures import ThreadPoolExecutor

        w = int(workers)
        with ThreadPoolExecutor(max_workers=w) as ex:
            # At most w+1 chunks exist at once (in flight or awaiting their
            # in-order fold), so the threaded path's peak memory is
            # O(workers * chunk + front + k), not unbounded.
            pending: deque = deque()
            for s in starts:
                ids, valid = _chunk_ids(s, n, chunk_size)
                pending.append((ex.submit(eval_chunk, ids), valid))
                if len(pending) > w:          # fold in submission order
                    fut, v = pending.popleft()
                    fold(fut.result(), v)
            while pending:
                fut, v = pending.popleft()
                fold(fut.result(), v)
    elif stage_times is not None:
        import time as _time

        stage_times.setdefault("score_s", 0.0)
        stage_times.setdefault("reduce_s", 0.0)
        for s in starts:
            ids, valid = _chunk_ids(s, n, chunk_size)
            t0 = _time.perf_counter()
            cols = eval_chunk(ids)
            t1 = _time.perf_counter()
            fold(cols, valid)
            t2 = _time.perf_counter()
            stage_times["score_s"] += t1 - t0
            stage_times["reduce_s"] += t2 - t1
    else:
        for s in starts:
            ids, valid = _chunk_ids(s, n, chunk_size)
            fold(eval_chunk(ids), valid)

    return StreamOutcome(reducers=reducers, n_points=n,
                         n_chunks=len(starts), chunk_size=chunk_size)


# ---------------------------------------------------------------------------
# SweepPlan: the picklable, data-only sweep description
# ---------------------------------------------------------------------------

_PLAN_BACKENDS = ("scalar", "numpy-batch", "jax-jit")


def _axis_value_to_json(v):
    """One normalized axis value as a JSON-able primitive or tagged dict."""
    from repro.core.fpga import BspParams, DramParams
    from repro.core.lsu import LsuType

    if isinstance(v, LsuType):
        return {"$kind": "lsu_type", "value": v.value}
    if isinstance(v, DramParams):
        return {"$kind": "dram", **dataclasses.asdict(v)}
    if isinstance(v, BspParams):
        return {"$kind": "bsp", **dataclasses.asdict(v)}
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    to_json = getattr(v, "to_json", None)      # repro.hw.Hardware
    if callable(to_json):
        return {"$kind": "hardware", "spec": json.loads(to_json())}
    raise TypeError(f"axis value {v!r} has no JSON encoding")


def _axis_value_from_json(v):
    if not isinstance(v, dict):
        return v
    kind = v.get("$kind")
    fields = {k: x for k, x in v.items() if k != "$kind"}
    if kind == "lsu_type":
        from repro.core.lsu import LsuType

        return LsuType(fields["value"])
    if kind == "dram":
        from repro.core.fpga import DramParams

        return DramParams(**fields)
    if kind == "bsp":
        from repro.core.fpga import BspParams

        return BspParams(**fields)
    if kind == "hardware":
        from repro.hw import Hardware

        return Hardware.from_json(json.dumps(fields["spec"]))
    raise TypeError(f"unknown encoded axis value {v!r}")


# Public names for the typed axis-value codecs: repro.workload's
# ModelSweepPlan serializes its hardware axis (and base dram/bsp) through
# the same tagged-dict encoding, so one codec owns every axis value that
# crosses a JSON boundary.
axis_value_to_json = _axis_value_to_json
axis_value_from_json = _axis_value_from_json


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """A frozen, picklable description of one streaming sweep.

    This is everything ``Session.sweep`` knows when it streams — the
    normalized per-axis value lists (``Space.lists`` output, hardware axes
    defaulted), the compute backend, the session calibration factor and the
    chunk size — as *data only*.  ``evaluator()`` rebuilds the
    chunk-scoring function from that data in any process, so the same plan
    drives the in-process thread pipeline, the coordinator/worker process
    pool (:mod:`repro.core.distributed`) and the serving front door
    identically; ``to_json()``/``from_json()`` round-trip the plan through
    text for transports that cannot carry pickles.

    Build one with ``Session.plan(...)`` rather than by hand — that applies
    the same axis normalization and chunk rounding ``Session.sweep`` uses.
    """

    lists: Mapping[str, Sequence]
    backend: str = "numpy-batch"
    calibration_factor: float = 1.0
    chunk_size: int = 1 << 16
    constraints: tuple = ()

    def __post_init__(self):
        from repro.core import sweep as _sweep

        if self.backend not in _PLAN_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}: pick one "
                             f"of {_PLAN_BACKENDS}")
        if self.chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        missing = [a for a in _sweep.AXES if a not in self.lists]
        if missing:
            raise ValueError(f"plan lists must cover every sweep axis; "
                             f"missing {missing}")
        object.__setattr__(
            self, "lists", {k: tuple(self.lists[k]) for k in _sweep.AXES})
        if self.constraints:
            from repro.search.constraints import normalize_constraints

            object.__setattr__(
                self, "constraints", normalize_constraints(self.constraints))
        else:
            object.__setattr__(self, "constraints", ())

    # -- geometry -----------------------------------------------------------

    def enumerator(self) -> GridEnumerator:
        return GridEnumerator(self.lists)

    @property
    def n(self) -> int:
        """Total points of the grid (0 when any axis is empty)."""
        return self.enumerator().n

    @property
    def n_chunks(self) -> int:
        return -(-self.n // self.chunk_size)

    def feasible_mask(self, ids: np.ndarray) -> np.ndarray:
        """Boolean keep-mask of the plan's constraints over point ids.

        A pure function of each point's own configuration — no scoring —
        which is why masking a chunk *before* evaluation is bit-equal to
        post-filtering the unconstrained sweep.  All-True when the plan
        carries no constraints.
        """
        ids = np.asarray(ids, dtype=np.int64)
        if not self.constraints:
            return np.ones(len(ids), dtype=bool)
        from repro.search.constraints import (
            columns_from_lists,
            feasibility_mask,
        )

        enum = self.enumerator()
        cols = columns_from_lists(self.lists, enum.codes(ids))
        return feasibility_mask(self.constraints, cols)

    # -- evaluation ---------------------------------------------------------

    def evaluator(self, stage_times: dict | None = None,
                  ) -> Callable[[np.ndarray], dict[str, np.ndarray]]:
        """The chunk-scoring function, rebuilt from plan data alone.

        Maps a fixed-shape id block to the chunk-column dict the reducers
        fold.  Call once per process and reuse — the jax-jit backend
        compiles on first use, and on multi-device hosts shards each chunk
        across local devices whenever ``chunk_size`` tiles the device
        count.

        When the plan carries constraints, each chunk is feasibility-masked
        *before* scoring: the returned columns hold only the feasible rows
        (possibly zero), already unpadded.  The jax-jit backend still sees
        exactly one array shape — feasible ids are re-padded to the chunk
        shape for scoring and sliced back down after — so constraints never
        trigger recompilation.

        ``stage_times`` (see :func:`run_stream`) accumulates ``enumerate_s``
        (mixed-radix decode + axis gathers) here and, on the jax-jit
        backend, ``transfer_s`` inside the estimator.
        """
        from repro.core import sweep as _sweep

        lists = {k: list(v) for k, v in self.lists.items()}
        enum = GridEnumerator(lists)
        backend = self.backend
        cat_names = [a for a in _sweep.AXES if a in _sweep._CATEGORICAL]
        num_names = [a for a in _sweep.AXES if a not in _sweep._CATEGORICAL]
        c = self.calibration_factor

        estimator = None
        if backend == "jax-jit":
            from repro import api as _api
            from repro import compat as _compat

            ndev = _compat.local_device_count()
            sharding = (_compat.data_sharding(ndev)
                        if ndev > 1 and self.chunk_size % ndev == 0 else None)
            estimator = (lambda b: _api._jax_estimate_batch(
                b, sharding=sharding, stage_times=stage_times))
        elif backend == "numpy-batch":
            from repro.core import model_batch as _mb

            estimator = _mb.estimate_batch

        def score_ids(ids: np.ndarray) -> dict[str, np.ndarray]:
            m = len(ids)
            t0 = _perf_counter() if stage_times is not None else 0.0
            codes = enum.codes(ids)
            numeric = {k: np.asarray(lists[k])[codes[k]] for k in num_names}
            cats = {k: (lists[k], codes[k]) for k in cat_names}
            if stage_times is not None:
                stage_times["enumerate_s"] = (
                    stage_times.get("enumerate_s", 0.0)
                    + _perf_counter() - t0)
            if backend == "scalar":
                result = _sweep._score_scalar(dict(numeric), m, cats)
                est, resource = result.estimate, result.resource
                numeric = {k: result.points[k] for k in num_names}
                cats, _, own = _sweep._resolve_hardware_codes(cats, m)
            else:
                est, resource, cats, numeric, own = _sweep._score(
                    numeric, cats, m, estimator)
            cols: dict[str, np.ndarray] = {
                "id": np.asarray(ids, dtype=np.int64)}
            for k in num_names:
                cols[k] = np.asarray(numeric[k])
            for k in cat_names:
                cols[k] = np.asarray(cats[k][1], dtype=np.int64)
            scale = np.where(own, c, 1.0) if c != 1.0 else None
            for name in ESTIMATE_COLUMNS:
                v = np.asarray(getattr(est, name))
                if scale is not None and name in ("t_exe", "t_ideal",
                                                  "t_ovh"):
                    v = v * scale       # session calibration, like sweep()
                cols[name] = v
            cols["resource"] = np.asarray(resource)
            return cols

        if not self.constraints:
            return score_ids

        from repro.search.constraints import (
            columns_from_lists,
            feasibility_mask,
        )

        constraints = self.constraints
        fixed_shape = backend == "jax-jit"

        def eval_chunk(ids: np.ndarray) -> dict[str, np.ndarray]:
            ids = np.asarray(ids, dtype=np.int64)
            # Chunk ids are strictly increasing until the padded tail
            # repeats the last valid id, so the first occurrence of the
            # final id marks the valid length.
            valid = int(np.searchsorted(ids, ids[-1])) + 1 if len(ids) else 0
            live = ids[:valid]
            mask = feasibility_mask(
                constraints, columns_from_lists(lists, enum.codes(live)))
            feas = live[mask]
            f = len(feas)
            if f == len(ids):
                return score_ids(ids)
            if fixed_shape:
                # Re-pad to the compiled chunk shape (repeat an arbitrary
                # in-range id when nothing is feasible), score, slice.
                filler = feas[-1] if f else ids[0]
                padded = np.concatenate(
                    [feas, np.full(len(ids) - f, filler, dtype=np.int64)])
                cols = score_ids(padded)
            else:
                # Variable shapes are free off-jit; score one throwaway row
                # when empty so every column keeps its dtype.
                cols = score_ids(feas if f else ids[:1])
            return {k: np.asarray(v)[:f] for k, v in cols.items()}

        return eval_chunk

    def tables(self) -> dict[str, list]:
        """Resolved categorical tables (dram/bsp extended with the
        hardware-axis views) — what survivor-row codes index into."""
        from repro.core import sweep as _sweep

        cat_names = [a for a in _sweep.AXES if a in _sweep._CATEGORICAL]
        probe = {k: (list(self.lists[k]), np.zeros(1, dtype=np.int64))
                 for k in cat_names}
        return {k: v[0] for k, v in
                _sweep._resolve_hardware_codes(probe, 1)[0].items()}

    def run_range(self, lo: int, hi: int, reducers: Iterable[Reducer], *,
                  eval_chunk: Callable | None = None) -> tuple[Reducer, ...]:
        """Fold the chunks covering point ids ``[lo, hi)`` into ``reducers``.

        ``lo`` (and ``hi``, unless it is ``n``) must sit on chunk
        boundaries: work units are unions of whole chunks of the *global*
        chunk grid, so every chunk a worker evaluates is bit-identical to
        the chunk the serial pass would have evaluated — the foundation of
        the distributed executor's bit-equality contract.
        """
        n = self.n
        lo, hi = int(lo), min(int(hi), n)
        if lo % self.chunk_size:
            raise ValueError(f"range start {lo} is not chunk-aligned "
                             f"(chunk_size={self.chunk_size})")
        if hi % self.chunk_size and hi != n:
            raise ValueError(f"range stop {hi} is not chunk-aligned "
                             f"(chunk_size={self.chunk_size}) and is not "
                             f"the grid end {n}")
        if eval_chunk is None:
            eval_chunk = self.evaluator()
        reducers = tuple(reducers)
        for start in range(lo, hi, self.chunk_size):
            ids, valid = _chunk_ids(start, n, self.chunk_size)
            cols = eval_chunk(ids)
            # Same rule as run_stream's fold: a constrained evaluator has
            # already compacted to the feasible rows.
            if valid != self.chunk_size \
                    and len(cols["id"]) == self.chunk_size:
                cols = {k: np.asarray(v)[:valid] for k, v in cols.items()}
            if len(cols["id"]) == 0:
                continue
            for r in reducers:
                r.update(cols)
        return reducers

    def run(self, reducers: Iterable[Reducer], *,
            workers: int | None = None) -> StreamOutcome:
        """Serial/threaded whole-grid fold (``run_stream`` over this plan)."""
        return run_stream(self.n, self.chunk_size, self.evaluator(),
                          reducers, workers=workers)

    # -- serialization ------------------------------------------------------

    def to_json(self) -> str:
        """The plan as canonical JSON (axis values via typed codecs).

        Constraints ride along as tagged dicts; a plan carrying a custom
        callable constraint raises here (pickle still carries it).
        """
        out = {
            "version": 1,
            "backend": self.backend,
            "calibration_factor": self.calibration_factor,
            "chunk_size": self.chunk_size,
            "lists": {k: [_axis_value_to_json(v) for v in vs]
                      for k, vs in self.lists.items()},
        }
        if self.constraints:
            from repro.search.constraints import constraint_to_json

            out["constraints"] = [constraint_to_json(c)
                                  for c in self.constraints]
        return json.dumps(out, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SweepPlan":
        d = json.loads(text)
        encoded = d.get("constraints", [])
        constraints: tuple = ()
        if encoded:
            from repro.search.constraints import constraint_from_json

            constraints = tuple(constraint_from_json(o) for o in encoded)
        return cls(
            lists={k: [_axis_value_from_json(v) for v in vs]
                   for k, vs in d["lists"].items()},
            backend=d["backend"],
            calibration_factor=float(d["calibration_factor"]),
            chunk_size=int(d["chunk_size"]),
            constraints=constraints)


def make_range_folder(plan: SweepPlan) -> Callable:
    """``fold(lo, hi, reducers)`` for chunk-aligned ranges of ``plan``.

    The fastest eligible implementation is chosen once per folder: on the
    unconstrained single-device jax-jit backend that is the fused
    device-resident step (:mod:`repro.core.device_stream` — in-jit
    enumeration + scoring + reducer folds, one host pull per range), with a
    transparent fall-through to the host ``plan.run_range`` loop for
    unsupported reducer sets or a device-side capacity overflow.  Both
    paths are bit-equal by the reducer merge contract, so callers (the
    distributed worker loop) never see which one ran.  The host evaluator
    is built lazily — a worker whose every unit folds on-device never pays
    for it.
    """
    device = None
    if plan.backend == "jax-jit" and not plan.constraints:
        try:
            from repro.core import device_stream as _dev
        except ImportError:  # pragma: no cover - jax-less install
            _dev = None
        if _dev is not None:
            device = _dev.DeviceSweep.build(plan)

    evaluator = None

    def fold_range(lo: int, hi: int, reducers: Iterable[Reducer]) -> None:
        nonlocal evaluator
        reducers = tuple(reducers)
        if device is not None and device.supports(reducers):
            from repro.core.device_stream import DeviceFoldOverflow
            try:
                device.fold_range(lo, hi, reducers)
                return
            except DeviceFoldOverflow:
                pass        # reducers untouched; refold on the host path
        if evaluator is None:
            evaluator = plan.evaluator()
        plan.run_range(lo, hi, reducers, eval_chunk=evaluator)

    return fold_range
