"""Coordinator/worker process pool for distributed streaming sweeps.

The streaming engine (``core.stream``) already made the per-chunk point-id
interval the natural work unit and every reducer mergeable, so distributing
a sweep needs no new math: the coordinator partitions ``[0, n)`` into
chunk-aligned *work units*, a spawn-based process pool folds each unit into
fresh reducers rebuilt from the picklable :class:`~repro.core.stream.SweepPlan`,
and the coordinator merges the returned reducer states.  Because work units
are whole chunks aligned to the global chunk grid, every worker sees exactly
the chunk contents the single-process fold would (including the one padded
final chunk), and the merged result is bit-equal to the serial run.

Fault tolerance is re-issue based: a unit whose workers all died, or that
outlived ``straggler_timeout_s``, is handed to another worker; the first
returned state per unit wins and duplicates are dropped, so re-issue never
double-counts.  This is the process-pool phase of the multi-host roadmap
item — the ``jax.distributed`` phase can reuse the same plan/merge protocol
with a network transport.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import queue
import time
import traceback

from repro.core import stream as _stream

__all__ = ["run_distributed"]

_FAULT_ENV = "REPRO_DIST_FAULT"


def _maybe_fault(uid: int) -> None:
    """Test hook: inject a one-shot worker fault for work unit ``uid``.

    ``REPRO_DIST_FAULT="<uid>:<kind>:<marker-path>"`` makes the *first*
    worker to start that unit fail — ``kind="kill"`` hard-exits the
    process, ``kind="hang"`` sleeps past any sane straggler timeout.  The
    marker file records that the fault already fired so the re-issued
    attempt succeeds.  No-op unless the variable is set.
    """
    spec = os.environ.get(_FAULT_ENV)
    if not spec:
        return
    fuid, kind, marker = spec.split(":", 2)
    if int(fuid) != uid or os.path.exists(marker):
        return
    with open(marker, "w") as fh:
        fh.write(f"{kind} fired in pid {os.getpid()}\n")
    if kind == "kill":
        time.sleep(0.2)     # let the queue feeder flush the "start" message
        os._exit(17)
    if kind == "hang":
        time.sleep(60.0)


def _worker_main(plan, task_q, result_q) -> None:
    """Worker loop: build the range folder once, fold units until sentinel.

    The folder (:func:`repro.core.stream.make_range_folder`) takes the
    device-resident fused path on the jax-jit backend when the plan and
    reducers qualify, and the host ``plan.run_range`` pipeline otherwise —
    the same bit-equal dispatch ``Session.sweep`` makes in-process, so
    work units reuse one compiled fused step per worker.

    Messages out: ``("start", uid, pid)`` when a unit begins (feeds the
    coordinator's straggler/death bookkeeping), ``("ok", uid, states)``
    with one ``state_dict()`` per reducer on success, ``("err", uid, tb)``
    on failure (``uid == -1`` if the evaluator itself failed to build).
    """
    try:
        fold_range = _stream.make_range_folder(plan)
    except BaseException:
        result_q.put(("err", -1, traceback.format_exc()))
        return
    while True:
        task = task_q.get()
        if task is None:
            return
        uid, lo, hi, reducer_states = task
        try:
            result_q.put(("start", uid, os.getpid()))
            _maybe_fault(uid)
            reducers = [cls.from_state(s) for cls, s in reducer_states]
            fold_range(lo, hi, reducers)
            result_q.put(("ok", uid, [r.state_dict() for r in reducers]))
        except BaseException:
            result_q.put(("err", uid, traceback.format_exc()))


def _units(n_chunks: int, chunk_size: int, n: int,
           unit_chunks: int) -> list[tuple[int, int, int]]:
    """Partition the chunk grid into ``(uid, lo, hi)`` work units."""
    units = []
    for uid, c0 in enumerate(range(0, n_chunks, unit_chunks)):
        lo = c0 * chunk_size
        hi = min((c0 + unit_chunks) * chunk_size, n)
        units.append((uid, lo, hi))
    return units


def run_distributed(plan, reducers, *, workers: int | None = None,
                    unit_chunks: int | None = None,
                    straggler_timeout_s: float = 30.0,
                    max_issues: int = 4,
                    poll_s: float = 0.05) -> "_stream.StreamOutcome":
    """Fold ``plan`` into ``reducers`` across a spawn-based process pool.

    The caller's ``reducers`` receive the merged result in place (mirroring
    ``run_stream``) and come back inside the returned
    :class:`~repro.core.stream.StreamOutcome`.  ``unit_chunks`` sets the
    work-unit size in chunks (default: ~4 units per worker so stragglers
    cost a fraction of the sweep, never a full worker share).  A unit is
    re-issued when every worker that started it died, or after
    ``straggler_timeout_s`` without completing; each unit is issued at most
    ``max_issues`` times before the sweep fails.
    """
    n, chunk = plan.n, plan.chunk_size
    n_chunks = plan.n_chunks
    reducers = tuple(reducers)
    if n_chunks == 0:       # empty grid: nothing to distribute
        return _stream.StreamOutcome(reducers=reducers, n_points=n,
                                     n_chunks=0, chunk_size=chunk)
    if workers is None:
        workers = min(4, os.cpu_count() or 1)
    if workers < 1:
        raise ValueError("workers must be >= 1")
    if unit_chunks is None:
        unit_chunks = max(1, -(-n_chunks // (4 * workers)))
    units = _units(n_chunks, chunk, n, unit_chunks)
    workers = min(workers, len(units))

    # Workers rebuild each unit's reducers from these states so custom
    # Reducer subclasses keep their configuration (k, key, objectives)
    # without the coordinator knowing their constructor signatures.
    protos = [(type(r), r.fresh().state_dict()) for r in reducers]

    ctx = mp.get_context("spawn")
    task_q = ctx.Queue()
    result_q = ctx.Queue()

    def spawn() -> "mp.Process":
        p = ctx.Process(target=_worker_main, args=(plan, task_q, result_q),
                        daemon=True)
        p.start()
        return p

    pool = [spawn() for _ in range(workers)]
    done: dict[int, list] = {}              # uid -> reducer states (first wins)
    issues = {uid: 0 for uid, _, _ in units}
    starters: dict[int, set[int]] = {uid: set() for uid, _, _ in units}
    last_event = {uid: time.monotonic() for uid, _, _ in units}
    by_uid = {uid: (lo, hi) for uid, lo, hi in units}
    respawns_left = max_issues * workers
    all_dead: set[int] = set()              # every worker pid that ever died

    def issue(uid: int) -> None:
        lo, hi = by_uid[uid]
        issues[uid] += 1
        last_event[uid] = time.monotonic()
        # Forget prior starters: the unit is only "dead" again once a *new*
        # attempt starts and that worker dies too (prevents re-issuing every
        # poll tick against the same dead pids).
        starters[uid].clear()
        task_q.put((uid, lo, hi, protos))

    def shutdown() -> None:
        for _ in pool:
            task_q.put(None)
        for p in pool:
            p.join(timeout=2.0)
        for p in pool:
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        task_q.cancel_join_thread()
        result_q.cancel_join_thread()

    try:
        for uid, _, _ in units:
            issue(uid)
        while len(done) < len(units):
            try:
                msg = result_q.get(timeout=poll_s)
            except queue.Empty:
                msg = None
            if msg is not None:
                kind, uid, payload = msg
                if kind == "start":
                    starters[uid].add(payload)
                    last_event[uid] = time.monotonic()
                elif kind == "ok":
                    done.setdefault(uid, payload)   # first result wins
                elif kind == "err":
                    raise RuntimeError(
                        f"distributed sweep worker failed on unit {uid}:\n"
                        f"{payload}")
                continue
            # No result this tick: sweep the pool for deaths and stragglers.
            dead = {p.pid for p in pool if not p.is_alive()}
            if dead:
                all_dead |= dead
                alive = [p for p in pool if p.is_alive()]
                for p in pool:
                    if not p.is_alive():
                        p.join()
                        if respawns_left > 0:
                            respawns_left -= 1
                            alive.append(spawn())
                pool = alive
                if not pool:
                    raise RuntimeError(
                        "distributed sweep: every worker died and the "
                        "respawn budget is exhausted")
            now = time.monotonic()
            for uid, _, _ in units:
                if uid in done:
                    continue
                died = bool(starters[uid]) and starters[uid] <= all_dead
                stale = now - last_event[uid] > straggler_timeout_s
                if died or stale:
                    if issues[uid] >= max_issues:
                        raise RuntimeError(
                            f"distributed sweep: work unit {uid} "
                            f"(ids [{by_uid[uid][0]}, {by_uid[uid][1]})) "
                            f"failed after {issues[uid]} issues")
                    issue(uid)
    finally:
        shutdown()

    for uid in sorted(done):
        for base, state in zip(reducers, done[uid]):
            base.merge(type(base).from_state(state))
    return _stream.StreamOutcome(reducers=reducers, n_points=n,
                                 n_chunks=n_chunks, chunk_size=chunk)
