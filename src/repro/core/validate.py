"""Measured-vs-predicted kernel validation harness.

The paper's headline result is an analytical model whose predictions stay
within ~9% of *measured* execution time.  PR 1 built the prediction side
(vectorized Eqs. 1-10, sweep engine); this module closes the loop with the
measurement side, mirroring the paper's SIV methodology:

1. **Characterize** — run one known-streaming kernel and derive the host's
   effective memory bandwidth (the paper's microbenchmark step that anchors
   Table II/III parameters to the real board).  ``calibrate_dram`` rescales
   ``f_mem`` of a DDR4 parameter set so Eq. 2's ideal time matches the
   measured stream bandwidth of whatever backend is running (CPU interpret
   mode in CI, a real accelerator elsewhere).
2. **Read the early report** — lower + compile each kernel and extract
   bytes-moved per access class from the trip-count-aware HLO counter
   (`hlo_counter.analyze`), the transplant of reading the HLS RTL report
   instead of waiting for the bitstream.
3. **Predict** — map the classed bytes onto LSU groups (stream -> burst-
   coalesced aligned, strided -> non-aligned, gather -> write-ACK) and score
   Eqs. 1-10 for all kernels in one ``model_batch.estimate_batch`` pass.
4. **Measure** — time the kernel for real (interpret mode on CPU, compiled
   on accelerators) and report per-kernel |measured - predicted| errors,
   the shape of the paper's Table IV/V error tables
   (`benchmarks.paper_tables.table6_kernel_validation`).

On CPU the absolute errors are dominated by interpreter overhead, so the
harness reports them honestly rather than asserting a bound — the contract
(and the regression test) is that the loop *runs end to end* and produces
finite errors, which is the prerequisite for calibrating against real TPU
timings later.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.fpga import DramParams
from repro.core.lsu import Lsu, LsuType
from repro.core.model_batch import GroupBatch, estimate_batch


def _default_dram() -> DramParams:
    """The registry default board's DRAM view (was the DDR4_1866 const)."""
    from repro.hw import DEFAULT_BOARD, get as _get

    return _get(DEFAULT_BOARD).dram_params()

#: Modeled bytes of one LSU access when mapping HLO traffic onto LSU groups.
#: 64 B = the DDR4 minimum burst (dq * bl = 8 * 8) of the paper's Table III
#: parts, and the cache-line granularity of the CPU backend.
ACCESS_BYTES = 64


# ---------------------------------------------------------------------------
# cases
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ValidationCase:
    """One kernel to validate: ``build()`` returns (jitted fn, args)."""

    name: str
    build: Callable[[], tuple]
    calibration: bool = False    # stream anchor used to fit the bandwidth


def default_cases(*, small: bool = True) -> list[ValidationCase]:
    """The five Pallas kernels + the three membench access classes.

    ``small=True`` keeps interpret-mode wall time in seconds (CI); pass
    False on a real accelerator for measurement-grade shapes.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention.ops import gqa_decode
    from repro.kernels.flash_attention.ops import mha
    from repro.kernels.membench import ops as MB
    from repro.kernels.mlstm_chunk.ops import chunked_mlstm
    from repro.kernels.rglru.ops import scan as rglru_scan

    n = 1 << (15 if small else 22)
    S = 128 if small else 2048

    def aligned():
        xs = tuple(jax.random.normal(jax.random.PRNGKey(i), (n,), jnp.float32)
                   for i in range(3))
        return jax.jit(functools.partial(MB.aligned_sum, block=2048)), (xs,)

    def strided():
        xs = tuple(jax.random.normal(jax.random.PRNGKey(i), (n,), jnp.float32)
                   for i in range(2))
        return (jax.jit(functools.partial(MB.strided_sum, delta=4, block=512)),
                (xs,))

    def gather():
        xs = tuple(jax.random.normal(jax.random.PRNGKey(i), (n,), jnp.float32)
                   for i in range(2))
        idx = jax.random.randint(jax.random.PRNGKey(9), (16,), 0, n // 512)
        return (jax.jit(functools.partial(MB.gather_sum, block=512)),
                (xs, idx))

    def flash():
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, S, 4, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, S, 2, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, S, 2, 32), jnp.float32)
        return (jax.jit(functools.partial(mha, block_q=64, block_kv=64)),
                (q, k, v))

    def decode():
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (2, 1, 8, 32), jnp.float32)
        kc = jax.random.normal(ks[1], (2, S, 2, 32), jnp.float32)
        vc = jax.random.normal(ks[2], (2, S, 2, 32), jnp.float32)
        ln = jnp.asarray(S, jnp.int32)
        return (jax.jit(functools.partial(gqa_decode, block_s=64)),
                (q, kc, vc, ln))

    def rglru():
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        a = jax.random.uniform(ks[0], (2, S, 256), jnp.float32, 0.6, 0.999)
        b = jax.random.normal(ks[1], (2, S, 256), jnp.float32)
        return (jax.jit(functools.partial(rglru_scan, block_s=64,
                                          block_w=128)), (a, b))

    def mlstm():
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        q = jax.random.normal(ks[0], (1, S, 2, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, S, 2, 32), jnp.float32) / 32 ** 0.5
        v = jax.random.normal(ks[2], (1, S, 2, 32), jnp.float32)
        li = jax.nn.log_sigmoid(jax.random.normal(ks[3], (1, S, 2)))
        lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (1, S, 2)) + 2.0)
        return (jax.jit(functools.partial(chunked_mlstm, chunk=64)),
                (q, k, v, li, lf))

    return [
        ValidationCase("membench_aligned", aligned, calibration=True),
        ValidationCase("membench_strided", strided),
        ValidationCase("membench_gather", gather),
        ValidationCase("flash_attention", flash),
        ValidationCase("decode_attention", decode),
        ValidationCase("rglru_scan", rglru),
        ValidationCase("mlstm_chunk", mlstm),
    ]


# ---------------------------------------------------------------------------
# measure / analyze / predict
# ---------------------------------------------------------------------------

def time_callable(fn, args, *, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds per call, device-synchronized."""
    import jax

    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def traffic_from_compiled(compiled) -> dict:
    """Classed bytes/FLOPs of a compiled executable from its HLO text."""
    from repro.core import hlo_counter as _hc

    hc = _hc.analyze(compiled.as_text())
    return {"flops": hc.flops, "total_bytes": hc.total_bytes,
            "bytes_by_class": dict(hc.bytes_by_class)}


def analyze_traffic(fn, args) -> dict:
    """Lower + compile ``fn(*args)`` and read classed bytes/FLOPs from HLO."""
    return traffic_from_compiled(fn.lower(*args).compile())


_CLASS_LSU = {"stream": LsuType.BC_ALIGNED,
              "strided": LsuType.BC_NON_ALIGNED,
              "gather": LsuType.BC_WRITE_ACK,
              "serialized": LsuType.BC_WRITE_ACK}


def lsus_from_classes(bytes_by_class: dict, *,
                      access_bytes: int = ACCESS_BYTES) -> list[Lsu]:
    """Map the HLO counter's access-class byte totals onto LSU groups.

    Each class becomes one LSU of the matching paper type issuing
    ``access_bytes``-wide accesses; total traffic is preserved (the byte
    count already reflects what the compiled program touches, so strides are
    expressed through the LSU *type* overheads, not through delta-inflation,
    which would double-count).
    """
    lsus = []
    for name, b in sorted(bytes_by_class.items()):
        if b <= 0:
            continue
        lsus.append(Lsu(_CLASS_LSU.get(name, LsuType.BC_ALIGNED),
                        ls_width=access_bytes,
                        ls_acc=max(1, int(round(b / access_bytes))),
                        ls_bytes=access_bytes, name=name))
    return lsus


def calibrate_dram(measured_bw: float, base: DramParams | None = None,
                   name: str = "host-calibrated") -> DramParams:
    """DRAM parameter set whose Eq. 2 peak bandwidth equals ``measured_bw``.

    ``bw_mem = dq * 2 * f_mem``, so only the I/O clock is rescaled; the
    timing overheads (t_rcd/t_rp/t_wr) keep their datasheet values — the
    same split the paper uses between datasheet rows and measured rows.
    """
    base = base if base is not None else _default_dram()
    return dataclasses.replace(base, name=name,
                               f_mem=measured_bw / (2.0 * base.dq))


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelValidation:
    """One row of the measured-vs-predicted error table."""

    name: str
    backend: str
    interpret: bool
    measured_s: float
    predicted_s: float
    bytes_moved: float
    flops: float
    err_pct: float               # |predicted - measured| / measured * 100
    memory_bound: bool

    def row(self) -> dict:
        return {
            "kernel": self.name, "backend": self.backend,
            "interpret": self.interpret,
            "measured_ms": round(self.measured_s * 1e3, 4),
            "predicted_ms": round(self.predicted_s * 1e3, 4),
            "bytes_mb": round(self.bytes_moved / 1e6, 3),
            "flops_m": round(self.flops / 1e6, 3),
            "memory_bound": bool(self.memory_bound),
            "err_pct": round(self.err_pct, 1),
        }


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    results: list[KernelValidation]
    failures: list[dict]         # {"kernel": name, "error": msg}
    dram: DramParams             # the calibrated parameter set
    measured_bw: float           # stream bandwidth anchor [B/s]
    calibration_factor: float = 1.0   # measured/modeled on the stream anchor

    @property
    def max_err_pct(self) -> float:
        return max((r.err_pct for r in self.results), default=float("nan"))

    def rows(self) -> list[dict]:
        return [r.row() for r in self.results]


def _validate(cases: Sequence[ValidationCase] | None = None, *,
              iters: int = 3, warmup: int = 1,
              dram: DramParams | None = None,
              base: DramParams | None = None,
              fit_host_factor: bool = True) -> ValidationReport:
    """Run the measured-vs-predicted loop over ``cases``.

    Pass ``dram`` to skip bandwidth calibration (reproducible tests);
    otherwise the first ``calibration=True`` case (or the first case)
    anchors the effective bandwidth.  On top of the bandwidth fit, a single
    host factor — measured/modeled time on the same stream anchor — absorbs
    backend-global costs the DRAM-scale model cannot see (interpret-mode
    interpreter overhead, CPU caches hiding row misses), so per-kernel
    errors measure the model's *relative* fidelity across kernels: the
    paper's normalized-figure methodology.  Pass ``fit_host_factor=False``
    to report the model's raw predictions instead (no wall-clock enters the
    prediction side, so repeated runs predict identically).  A case that
    fails to build/compile/run becomes a failure record, never an
    exception — partial tables are still tables.
    """
    import jax

    from repro import compat

    base = base if base is not None else _default_dram()
    backend = jax.default_backend()
    interpret = compat.default_interpret()
    cases = list(cases) if cases is not None else default_cases()

    measured: list[tuple[ValidationCase, float, dict]] = []
    failures: list[dict] = []
    for case in cases:
        try:
            fn, args = case.build()
            # Compile once: the AOT executable is both analyzed and timed.
            compiled = fn.lower(*args).compile()
            traffic = traffic_from_compiled(compiled)
            t = time_callable(compiled, args, iters=iters, warmup=warmup)
            if not (np.isfinite(t) and t > 0):
                raise ValueError(f"non-finite measurement {t!r}")
            measured.append((case, t, traffic))
        except Exception as e:  # noqa: BLE001 — a failed kernel is a row
            failures.append({"kernel": case.name,
                             "error": f"{type(e).__name__}: {e}"})

    if not measured:
        return ValidationReport([], failures,
                                dram or base, float("nan"))

    anchor = next((m for m in measured if m[0].calibration), measured[0])
    measured_bw = anchor[2]["total_bytes"] / anchor[1]
    if dram is None:
        dram = calibrate_dram(measured_bw, base)

    kernels = [lsus_from_classes(tr["bytes_by_class"])
               for _, _, tr in measured]
    est = estimate_batch(GroupBatch.from_kernels(kernels, dram))
    t_raw = np.asarray(est.t_exe, dtype=float)

    anchor_idx = measured.index(anchor)
    factor = (anchor[1] / t_raw[anchor_idx]
              if fit_host_factor and np.isfinite(t_raw[anchor_idx])
              and t_raw[anchor_idx] > 0
              else 1.0)

    results = []
    for i, (case, t, tr) in enumerate(measured):
        pred = float(t_raw[i] * factor)
        results.append(KernelValidation(
            name=case.name, backend=backend, interpret=interpret,
            measured_s=t, predicted_s=pred,
            bytes_moved=float(tr["total_bytes"]), flops=float(tr["flops"]),
            err_pct=abs(pred - t) / t * 100.0,
            memory_bound=bool(np.asarray(est.memory_bound)[i]),
        ))
    return ValidationReport(results, failures, dram, measured_bw,
                            calibration_factor=float(factor))
