"""On-disk cache of compiled-HLO cost analyses, keyed by config hash.

Lower+compile is the expensive step of model-guided search (seconds per
candidate); the analytical scoring is microseconds.  Caching the *analysis*
(the `HloCost` numbers, not the HLO text) makes re-ranking a design space
under different hardware parameters, or resuming an interrupted sweep, free.

Records are plain JSON dicts, one file per key, written atomically so
concurrent autotune runs can share a cache directory.  The key is a SHA-256
over a canonical JSON encoding of the configuration (plus a cache schema
version and the jax version, since recompiling under a different compiler
can change the counts).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import tempfile
from typing import Any, Mapping

CACHE_VERSION = 1

#: Default cache root; override with the REPRO_CACHE_DIR environment variable.
DEFAULT_ROOT = os.environ.get(
    "REPRO_CACHE_DIR", os.path.join("~", ".cache", "repro"))


def config_hash(obj: Any, *, salt: str = "") -> str:
    """Stable hex digest of an arbitrary JSON-encodable configuration.

    Non-JSON values fall back to ``repr`` — good enough for dataclasses,
    enums and mesh shapes, and stable within a process generation.
    """
    blob = json.dumps({"v": CACHE_VERSION, "salt": salt, "obj": obj},
                      sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


class HloAnalysisCache:
    """Directory of ``<key>.json`` analysis records."""

    def __init__(self, root: str | os.PathLike | None = None,
                 namespace: str = "hlo"):
        base = pathlib.Path(root if root is not None else DEFAULT_ROOT)
        self.root = base.expanduser() / namespace

    def _path(self, key: str) -> pathlib.Path:
        return self.root / f"{key}.json"

    def get(self, key: str) -> dict | None:
        try:
            with open(self._path(key)) as fh:
                return json.load(fh)
        except (OSError, ValueError):
            return None      # missing or corrupt — recompute

    def put(self, key: str, record: Mapping[str, Any]) -> None:
        self.root.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(dict(record), fh, sort_keys=True, default=repr)
            os.replace(tmp, self._path(key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __contains__(self, key: str) -> bool:
        return self._path(key).is_file()

    def __len__(self) -> int:
        if not self.root.is_dir():
            return 0
        return sum(1 for _ in self.root.glob("*.json"))

    def clear(self) -> int:
        """Delete every record; returns the number removed."""
        n = 0
        if self.root.is_dir():
            for p in self.root.glob("*.json"):
                try:
                    p.unlink()
                    n += 1
                except OSError:
                    pass
        return n
