"""Caches keyed by content hash: on-disk HLO analyses + in-memory LRU.

Lower+compile is the expensive step of model-guided search (seconds per
candidate); the analytical scoring is microseconds.  Caching the *analysis*
(the `HloCost` numbers, not the HLO text) makes re-ranking a design space
under different hardware parameters, or resuming an interrupted sweep, free.

Records are plain JSON dicts, one file per key, written atomically so
concurrent autotune runs can share a cache directory.  The key is a SHA-256
over a canonical JSON encoding of the configuration (plus a cache schema
version and the jax version, since recompiling under a different compiler
can change the counts).

:class:`LruCache` is the in-memory layer above that disk cache: a bounded,
thread-safe, recency-evicting map with hit/miss counters.  The serving
layer (:mod:`repro.core.serving`) keys it with the same
:func:`config_hash` to memoize whole estimate results per canonical
``Design`` + hardware context.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import tempfile
import threading
from collections import OrderedDict
from typing import Any, Mapping

CACHE_VERSION = 1

#: Default cache root; override with the REPRO_CACHE_DIR environment variable.
DEFAULT_ROOT = os.environ.get(
    "REPRO_CACHE_DIR", os.path.join("~", ".cache", "repro"))


def config_hash(obj: Any, *, salt: str = "") -> str:
    """Stable hex digest of an arbitrary JSON-encodable configuration.

    Non-JSON values fall back to ``repr`` — good enough for dataclasses,
    enums and mesh shapes, and stable within a process generation.
    """
    blob = json.dumps({"v": CACHE_VERSION, "salt": salt, "obj": obj},
                      sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


class LruCache:
    """Bounded, thread-safe, least-recently-used map with hit/miss counters.

    ``get`` refreshes recency; ``put`` evicts the coldest entry past
    ``capacity``.  Values are returned as stored (no copying) — callers
    cache immutable records (frozen dataclasses, result tuples).  A
    ``capacity`` of 0 disables storage but keeps counting misses, so a
    cache-off server still reports honest stats.
    """

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = int(capacity)
        self._data: OrderedDict[str, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str, default: Any = None) -> Any:
        with self._lock:
            try:
                self._data.move_to_end(key)
            except KeyError:
                self.misses += 1
                return default
            self.hits += 1
            return self._data[key]

    def put(self, key: str, value: Any) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def __contains__(self, key: str) -> bool:
        with self._lock:          # membership does not refresh recency
            return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def stats(self) -> dict:
        return {"size": len(self._data), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses}


class HloAnalysisCache:
    """Directory of ``<key>.json`` analysis records."""

    def __init__(self, root: str | os.PathLike | None = None,
                 namespace: str = "hlo"):
        base = pathlib.Path(root if root is not None else DEFAULT_ROOT)
        self.root = base.expanduser() / namespace

    def _path(self, key: str) -> pathlib.Path:
        return self.root / f"{key}.json"

    def get(self, key: str) -> dict | None:
        try:
            with open(self._path(key)) as fh:
                return json.load(fh)
        except (OSError, ValueError):
            return None      # missing or corrupt — recompute

    def put(self, key: str, record: Mapping[str, Any]) -> None:
        self.root.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(dict(record), fh, sort_keys=True, default=repr)
            os.replace(tmp, self._path(key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __contains__(self, key: str) -> bool:
        return self._path(key).is_file()

    def __len__(self) -> int:
        if not self.root.is_dir():
            return 0
        return sum(1 for _ in self.root.glob("*.json"))

    def clear(self) -> int:
        """Delete every record; returns the number removed."""
        n = 0
        if self.root.is_dir():
            for p in self.root.glob("*.json"):
                try:
                    p.unlink()
                    n += 1
                except OSError:
                    pass
        return n
