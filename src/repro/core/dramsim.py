"""Event-driven DRAM timing simulator — the validation oracle.

The paper validates its closed-form model against a physical Stratix 10
board.  We have no board, so this module provides an *independent*
implementation of the memory system described in SII-B / Fig. 2: per-bank row
buffers, PRE/ACT row-miss latency, a shared data bus at ``bw_mem``, bank
interleaving at the controller granularity, and round-robin arbitration
between LSU streams.  The closed-form model (``core.model``) is cross-checked
against this simulator by property-based tests; agreement within the paper's
own error envelope (<~15 % for coalesced, <~28 % for ACK) is required.

Simplifications (shared with the paper's model): no refresh (~3.5 % effect,
SV-A1), fixed inter-command timing, single rank/channel (the devkit has one
DIMM), closed-page policy approximated by row-buffer state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Sequence

import numpy as np

from repro.core.fpga import BspParams, DramParams
from repro.core.lsu import Lsu, LsuType
from repro.core import model as _model
from repro.core.model import _default_bsp


@dataclasses.dataclass
class Transaction:
    addr: int          # byte address
    nbytes: float      # transaction size
    is_write: bool
    serialized: bool = False    # atomic: next txn waits for this completion
    extra_latency: float = 0.0  # e.g. write-recovery round trip
    force_miss: bool = False    # closed-page semantics (atomics)


def _transactions_for_lsu(
    lsu: Lsu, dram: DramParams, bsp: BspParams, base_addr: int, rng: np.random.Generator
) -> Iterator[Transaction]:
    """Expand an LSU into the DRAM transaction stream its coalescer emits.

    * BC aligned: maximal ``2**burst_cnt * dq * bl`` transactions streaming a
      physical extent of ``useful * delta`` bytes (the coalescer always
      fetches whole bursts; a stride makes 1/delta of each useful).
    * BC non-aligned: the ``max_th`` / page triggers cap each assembled
      request at ``burst_size`` *useful* bytes, i.e. a physical window of
      ``burst_size * delta`` bytes per request.
    * Write-ACK: one min-burst per access at a data-dependent address inside
      the array footprint (``span_bytes``); writes pay the recovery time.
    * Atomic: strictly serialized read-modify-write with closed-page
      semantics (each command re-opens the row — the Eq. 10 behaviour).
    """
    if lsu.lsu_type is LsuType.ATOMIC_PIPELINED:
        for _ in range(lsu.ls_acc):
            yield Transaction(base_addr, dram.min_burst_bytes, False,
                              serialized=True, force_miss=True)
            # write recovery is charged at the forced row re-open in run()
            yield Transaction(base_addr, dram.min_burst_bytes, True,
                              serialized=True, force_miss=True)
        return

    if lsu.lsu_type is LsuType.BC_WRITE_ACK:
        span = lsu.span_bytes or max(dram.min_burst_bytes, lsu.total_bytes)
        n_blocks = max(1, span // dram.min_burst_bytes)
        blocks = rng.integers(0, n_blocks, size=lsu.ls_acc)
        for b in blocks:
            # write-recovery (t_WR) is paid on row transitions, not per
            # pipelined same-row write — handled in run() at miss time.
            yield Transaction(base_addr + int(b) * dram.min_burst_bytes,
                              dram.min_burst_bytes, lsu.is_write)
        return

    # Burst-coalesced streaming (aligned / cache / prefetch / non-aligned).
    bsz = _model.burst_size_bytes(lsu, dram, bsp)       # useful bytes/request
    if lsu.lsu_type in (LsuType.BC_ALIGNED, LsuType.BC_CACHE):
        # maximal transactions streaming the whole strided extent
        physical = int(bsz)
        n = max(1, math.ceil(lsu.total_bytes * lsu.delta / physical))
    else:
        # one assembled request per `bsz` useful bytes, spanning bsz*delta
        physical = max(dram.min_burst_bytes, int(round(bsz * lsu.delta)))
        n = max(1, math.ceil(lsu.total_bytes / bsz))
    for k in range(n):
        yield Transaction(base_addr + k * physical, physical, lsu.is_write)


@dataclasses.dataclass
class SimResult:
    t_total: float
    n_transactions: int
    n_row_misses: int

    @property
    def row_miss_rate(self) -> float:
        return self.n_row_misses / max(1, self.n_transactions)


class DramSimulator:
    """Round-robin arbiter + banked DRAM with a shared data bus."""

    def __init__(self, dram: DramParams, bsp: BspParams | None = None,
                 interleave_bytes: int = 1024, seed: int = 0):
        self.dram = dram
        self.bsp = bsp if bsp is not None else _default_bsp()
        self.interleave = interleave_bytes
        self.seed = seed

    def _bank_row(self, addr: int) -> tuple[int, int]:
        block = addr // self.interleave
        bank = block % self.dram.banks
        row = (block // self.dram.banks) // max(1, self.dram.row_bytes // self.interleave)
        return bank, row

    def run(self, lsus: Sequence[Lsu]) -> SimResult:
        dram, bsp = self.dram, self.bsp
        rng = np.random.default_rng(self.seed)
        # All LSU streams start block-aligned at congruent bases: large
        # contiguous allocations on the devkit start page-aligned, so
        # concurrent streams collide on banks (SII-B arbitration).
        streams = []
        drains = []   # write-buffer drain batch per stream (SII-B: the read
                      # and write arbiters are independent; buffered ACK
                      # writes drain in batches, restoring row locality)
        base = 0
        for lsu in lsus:
            if not lsu.lsu_type.is_global:
                continue
            txns = list(_transactions_for_lsu(lsu, dram, bsp, base, rng))
            if txns:
                streams.append(txns)
                drains.append(16 if (lsu.lsu_type is LsuType.BC_WRITE_ACK
                                     and lsu.is_write) else 1)
            base += 1 << 32  # far apart: distinct rows, congruent banks
        if not streams:
            return SimResult(0.0, 0, 0)

        open_row = [-1] * dram.banks
        bank_ready = [0.0] * dram.banks
        bus_free = 0.0
        ptr = [0] * len(streams)
        stream_ready = [0.0] * len(streams)
        n_txn = 0
        n_miss = 0
        done = 0
        i = -1
        budget = 0
        while done < len(streams):
            # round-robin arbitration; write-buffered streams drain in batches
            if budget <= 0 or ptr[i] >= len(streams[i]):
                i = (i + 1) % len(streams)
                budget = drains[i]
            if ptr[i] >= len(streams[i]):
                budget = 0
                continue
            budget -= 1
            txn = streams[i][ptr[i]]
            ptr[i] += 1
            if ptr[i] == len(streams[i]):
                done += 1
            bank, row = self._bank_row(txn.addr)
            arrival = stream_ready[i]
            act_done = max(bank_ready[bank], arrival)
            if txn.force_miss or open_row[bank] != row:
                act_done += dram.t_row
                if txn.is_write:
                    act_done += dram.t_wr   # write recovery before re-open
                open_row[bank] = row
                n_miss += 1
            start = max(bus_free, act_done)
            end = start + txn.nbytes / dram.bw_mem + txn.extra_latency
            bus_free = end
            bank_ready[bank] = end
            n_txn += 1
            if txn.serialized:
                stream_ready[i] = end
        return SimResult(bus_free, n_txn, n_miss)


def simulate(lsus: Sequence[Lsu], dram: DramParams,
             bsp: BspParams | None = None, seed: int = 0,
             interleave_bytes: int = 1024) -> SimResult:
    """One-shot simulation; ``interleave_bytes`` is the controller
    interleave granularity (``repro.hw`` specs carry it as
    ``Hardware.dram.interleave_bytes``)."""
    return DramSimulator(dram, bsp, interleave_bytes=interleave_bytes,
                         seed=seed).run(lsus)
