"""Array-based core of the paper's analytical model (Eqs. 1-10).

This is the same math as :mod:`repro.core.model`, restated over arrays so a
whole design space can be scored in one vectorized pass.  The layout is a
structure-of-arrays over *LSU groups*:

* a **group** is ``count`` identical LSUs belonging to one kernel (one design
  point) — e.g. the paper's ``z[id] = x1[id] + ... + xn[id]`` microbenchmark
  with ``#ga = 4`` is a single group with ``count = 5`` (4 reads + 1 write);
* every per-group field (``lsu_type`` code, ``ls_width``, ``ls_acc``,
  ``ls_bytes``, ``delta``, …) and every per-kernel hardware field (DRAM
  timings, BSP parameters, vectorization factor ``f``) is an array
  broadcastable to a common shape ``[M]``;
* ``kernel`` maps each group to its kernel id in ``[0, n_kernels)``; Eq. 1's
  sum over LSUs becomes a segment-sum weighted by ``count``.

All arithmetic mirrors the scalar reference (`model.lsu_timing`) operation
for operation, so batched and scalar results agree to float64 round-off.
The math uses only ops that exist in both NumPy and ``jax.numpy``; pass
``xp=jax.numpy`` (and jax arrays) to run the core under ``jit``/``vmap``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core.fpga import BspParams, DramParams
from repro.core.lsu import Lsu, LsuType

# Integer codes for the GMI LSU types (the only ones that touch DRAM).
ALIGNED, NON_ALIGNED, CACHE, WRITE_ACK, ATOMIC = 0, 1, 2, 3, 4

TYPE_CODE = {
    LsuType.BC_ALIGNED: ALIGNED,
    LsuType.BC_NON_ALIGNED: NON_ALIGNED,
    LsuType.BC_CACHE: CACHE,
    LsuType.BC_WRITE_ACK: WRITE_ACK,
    LsuType.ATOMIC_PIPELINED: ATOMIC,
    # The high-end BSP compiles prefetching LSUs as burst-coalesced aligned
    # (lsu.py Table I note), so they share the aligned timing.
    LsuType.PREFETCHING: ALIGNED,
}
CODE_TYPE = {ALIGNED: LsuType.BC_ALIGNED, NON_ALIGNED: LsuType.BC_NON_ALIGNED,
             CACHE: LsuType.BC_CACHE, WRITE_ACK: LsuType.BC_WRITE_ACK,
             ATOMIC: LsuType.ATOMIC_PIPELINED}


class _ScalarNamespace:
    """Array-namespace shim over plain Python scalars.

    Lets the scalar `model.estimate` wrapper run `group_timing` per LSU with
    no array-construction overhead (a length-1 ndarray pipeline costs ~100x
    a float op) while keeping a single source of truth for the math.
    """

    @staticmethod
    def asarray(x):
        return x

    @staticmethod
    def where(cond, a, b):
        return a if cond else b

    @staticmethod
    def maximum(a, b):
        return a if a >= b else b


SCALAR_XP = _ScalarNamespace()


def _segment_sum(data, segment_ids, num_segments: int, xp=np):
    if xp is np:
        return np.bincount(segment_ids, weights=np.asarray(data, dtype=np.float64),
                           minlength=num_segments)
    import jax
    return jax.ops.segment_sum(data, segment_ids, num_segments)


def group_timing(
    *,
    lsu_type,
    ls_width,
    ls_acc,
    ls_bytes,
    delta,
    val_constant,
    n_lsu,
    f,
    dq,
    bl,
    f_mem,
    t_rcd,
    t_rp,
    t_wr,
    burst_cnt,
    max_th,
    xp=np,
) -> dict[str, Any]:
    """Eqs. 2 and 4-10 for a batch of LSU groups.

    All arguments are arrays (or scalars) broadcastable to a common shape.
    Returns per-single-LSU terms: multiply ``t_total`` by the group ``count``
    to get the group's Eq. 1 contribution.
    """
    lsu_type = xp.asarray(lsu_type)
    is_atomic = lsu_type == ATOMIC
    is_ack = lsu_type == WRITE_ACK
    is_nonaligned = lsu_type == NON_ALIGNED
    coalescing = (lsu_type == ALIGNED) | is_nonaligned | (lsu_type == CACHE)

    bw_mem = dq * 2.0 * f_mem                       # Eq. 2 denominator
    min_burst = dq * bl                              # dq * bl [B]
    max_txn = (2 ** xp.asarray(burst_cnt)) * min_burst  # Eq. 5 upper bound

    total_bytes = ls_acc * ls_bytes
    t_ideal = total_bytes / bw_mem                   # Eq. 2

    # Effective transaction size (Eq. 5 / Eqs. 7-8 / min-burst for atomics).
    max_reqs = max_th * ls_width / (delta + 1)       # Eq. 7
    bsz_nonaligned = xp.where(max_reqs <= max_txn,   # Eq. 8 knee
                              max_reqs / delta, ls_width / delta)
    bsz = xp.where(is_nonaligned, bsz_nonaligned, 1.0 * max_txn)
    bsz = xp.where(is_atomic, 1.0 * min_burst, bsz)

    n_bursts_bc = total_bytes / bsz
    t_row_bc = t_rcd + t_rp                          # Eq. 6
    t_row = xp.where(is_ack, t_row_bc + t_wr, t_row_bc)          # Eq. 9
    t_row = xp.where(is_atomic, 2.0 * t_row_bc + t_wr, t_row)    # Eq. 10

    # Atomic-pipelined (Eq. 10): per-operation overhead, merged across the
    # vectorization factor when the summed value is loop-constant.
    per_op = xp.where(xp.asarray(val_constant), t_row / f, t_row)
    t_ovh_atomic = ls_acc * per_op

    # Burst-coalesced family (Eq. 4): a single stream never thrashes rows.
    single = n_lsu < 2
    t_ovh_bc = xp.where(single, 0.0, n_bursts_bc * t_row)
    # Write-ACK wasted-burst transfer inflation (SIII-A3): each dq*bl burst
    # carries only ls_bytes useful bytes.
    waste = xp.maximum(min_burst - ls_bytes, 0)
    t_ovh_bc = t_ovh_bc + xp.where(is_ack, ls_acc * waste / bw_mem, 0.0)
    # The ACK round-trip itself is never hidden by bank interleaving.
    t_ovh_bc = t_ovh_bc + xp.where(is_ack & single, n_bursts_bc * t_row, 0.0)

    t_ovh = xp.where(is_atomic, t_ovh_atomic, t_ovh_bc)
    n_bursts = xp.where(is_atomic, 1.0 * ls_acc, n_bursts_bc)

    # Eq. 3 per-LSU term with K_lsu = delta for coalescing LSUs, 1 otherwise.
    k = xp.where(coalescing, 1.0 * delta, 1.0)
    ratio_term = ls_width / (min_burst * k)

    return {
        "burst_size": bsz,
        "n_bursts": n_bursts,
        "t_ideal": t_ideal,
        "t_ovh": t_ovh,
        "t_total": delta * (t_ideal + t_ovh),        # Eq. 1 summand
        "ratio_term": ratio_term,
        "total_bytes": total_bytes,
        "latency_bound": is_ack | is_atomic,
    }


@dataclasses.dataclass(frozen=True)
class GroupBatch:
    """Structure-of-arrays over LSU groups for ``n_kernels`` design points."""

    kernel: Any          # int [M] — kernel id per group
    n_kernels: int
    count: Any           # int [M] — identical LSUs this group represents
    lsu_type: Any        # int codes [M]
    ls_width: Any
    ls_acc: Any
    ls_bytes: Any
    delta: Any
    val_constant: Any    # bool [M]
    f: Any               # per-kernel vectorization factor, broadcast to [M]
    dq: Any
    bl: Any
    f_mem: Any
    t_rcd: Any
    t_rp: Any
    t_wr: Any
    burst_cnt: Any
    max_th: Any

    @classmethod
    def from_kernels(
        cls,
        kernels: Sequence[Sequence[Lsu]],
        dram: DramParams | Sequence[DramParams],
        bsp: BspParams | Sequence[BspParams] | None = None,
        *,
        f: int | Sequence[int] = 1,
    ) -> "GroupBatch":
        """Build a batch from per-kernel LSU lists (one group per global LSU).

        ``dram``/``bsp``/``f`` may be single values (shared by every kernel)
        or per-kernel sequences.  Non-global (on-chip) LSUs are ignored, like
        in the scalar ``estimate``.
        """
        if bsp is None:
            from repro.core.model import _default_bsp

            bsp = _default_bsp()
        n = len(kernels)
        drams = list(dram) if isinstance(dram, (list, tuple)) else [dram] * n
        bsps = list(bsp) if isinstance(bsp, (list, tuple)) else [bsp] * n
        fs = list(f) if isinstance(f, (list, tuple)) else [f] * n
        if not (len(drams) == len(bsps) == len(fs) == n):
            raise ValueError("per-kernel dram/bsp/f lengths must match kernels")

        cols: dict[str, list] = {k: [] for k in (
            "kernel", "lsu_type", "ls_width", "ls_acc", "ls_bytes", "delta",
            "val_constant", "f", "dq", "bl", "f_mem", "t_rcd", "t_rp", "t_wr",
            "burst_cnt", "max_th")}
        for ki, lsus in enumerate(kernels):
            d, b, fk = drams[ki], bsps[ki], fs[ki]
            for lsu in lsus:
                if not lsu.lsu_type.is_global:
                    continue
                cols["kernel"].append(ki)
                cols["lsu_type"].append(TYPE_CODE[lsu.lsu_type])
                cols["ls_width"].append(lsu.ls_width)
                cols["ls_acc"].append(lsu.ls_acc)
                cols["ls_bytes"].append(lsu.ls_bytes)
                cols["delta"].append(lsu.delta)
                cols["val_constant"].append(lsu.val_constant)
                cols["f"].append(fk)
                cols["dq"].append(d.dq)
                cols["bl"].append(d.bl)
                cols["f_mem"].append(d.f_mem)
                cols["t_rcd"].append(d.t_rcd)
                cols["t_rp"].append(d.t_rp)
                cols["t_wr"].append(d.t_wr)
                cols["burst_cnt"].append(b.burst_cnt)
                cols["max_th"].append(b.max_th)

        m = len(cols["kernel"])
        return cls(
            kernel=np.asarray(cols["kernel"], dtype=np.int64),
            n_kernels=n,
            count=np.ones(m, dtype=np.int64),
            lsu_type=np.asarray(cols["lsu_type"], dtype=np.int64),
            ls_width=np.asarray(cols["ls_width"], dtype=np.int64),
            ls_acc=np.asarray(cols["ls_acc"], dtype=np.int64),
            ls_bytes=np.asarray(cols["ls_bytes"], dtype=np.int64),
            delta=np.asarray(cols["delta"], dtype=np.int64),
            val_constant=np.asarray(cols["val_constant"], dtype=bool),
            f=np.asarray(cols["f"], dtype=np.int64),
            dq=np.asarray(cols["dq"], dtype=np.int64),
            bl=np.asarray(cols["bl"], dtype=np.int64),
            f_mem=np.asarray(cols["f_mem"], dtype=np.float64),
            t_rcd=np.asarray(cols["t_rcd"], dtype=np.float64),
            t_rp=np.asarray(cols["t_rp"], dtype=np.float64),
            t_wr=np.asarray(cols["t_wr"], dtype=np.float64),
            burst_cnt=np.asarray(cols["burst_cnt"], dtype=np.int64),
            max_th=np.asarray(cols["max_th"], dtype=np.int64),
        )


_JAX_REGISTERED = False


def enable_jax() -> bool:
    """Register GroupBatch as a jax pytree (idempotent; False without jax).

    Deliberately not done at import time: the numpy-only paths (sweep,
    scalar estimate, benchmarks) must not pay the jax import on startup.
    Call this before passing a GroupBatch through ``jax.jit``/``vmap``;
    ``estimate_batch`` also calls it whenever ``xp`` is not numpy.
    """
    global _JAX_REGISTERED
    if _JAX_REGISTERED:
        return True
    try:
        from jax import tree_util as _jtu
    except ImportError:
        return False
    fields = tuple(f.name for f in dataclasses.fields(GroupBatch)
                   if f.name != "n_kernels")
    try:
        _jtu.register_pytree_node(
            GroupBatch,
            lambda b: (tuple(getattr(b, n) for n in fields), b.n_kernels),
            lambda aux, ch: GroupBatch(n_kernels=aux, **dict(zip(fields, ch))),
        )
    except ValueError:  # pragma: no cover — already registered (reload)
        pass
    _JAX_REGISTERED = True
    return True


@dataclasses.dataclass(frozen=True)
class BatchEstimate:
    """Model output for a batch of kernels — array analogue of KernelEstimate."""

    t_exe: Any           # [n_kernels] Eq. 1 [s]
    t_ideal: Any         # [n_kernels] sum of delta * T_ideal
    t_ovh: Any           # [n_kernels] sum of delta * T_ovh
    bound_ratio: Any     # [n_kernels] LHS of Eq. 3
    memory_bound: Any    # bool [n_kernels]
    total_bytes: Any     # [n_kernels] useful bytes moved
    n_lsu: Any           # [n_kernels] number of global LSUs
    groups: dict         # per-group timing arrays (group_timing output)

    @property
    def effective_bandwidth(self) -> Any:
        """Useful bytes / predicted time [B/s] (inf where t_exe == 0)."""
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(np.asarray(self.t_exe) > 0,
                           self.total_bytes / np.maximum(self.t_exe, 1e-300),
                           np.inf)
        return out


def estimate_batch(batch: GroupBatch, xp=np,
                   paired_kernel: bool = False) -> BatchEstimate:
    """Eq. 3 classification + Eq. 1 execution time for every kernel at once.

    ``paired_kernel=True`` asserts ``batch.kernel`` is exactly
    ``concat([arange(n), arange(n)])`` (two groups per kernel, as the sweep
    scorer builds) and replaces every segment reduction with the split add
    ``data[:n] + data[n:]``.  This is bit-equal to the scatter-based
    segment sum — each segment receives exactly two contributions, and for
    two terms IEEE addition is order-independent (``0 + a == a`` and
    ``a + b == b + a`` are exact) — but avoids the serialized scatter,
    which dominates the fused device step's runtime on CPU.
    """
    if xp is not np:
        enable_jax()
    n = batch.n_kernels
    count = xp.asarray(batch.count)
    if paired_kernel:
        seg = lambda data: data[:n] + data[n:]  # noqa: E731
        n_lsu = xp.concatenate([seg(count)] * 2)
    else:
        seg = lambda data: _segment_sum(data, batch.kernel, n, xp)  # noqa: E731
        n_lsu = seg(count)[batch.kernel]
    g = group_timing(
        lsu_type=batch.lsu_type,
        ls_width=batch.ls_width,
        ls_acc=batch.ls_acc,
        ls_bytes=batch.ls_bytes,
        delta=batch.delta,
        val_constant=batch.val_constant,
        n_lsu=n_lsu,
        f=batch.f,
        dq=batch.dq,
        bl=batch.bl,
        f_mem=batch.f_mem,
        t_rcd=batch.t_rcd,
        t_rp=batch.t_rp,
        t_wr=batch.t_wr,
        burst_cnt=batch.burst_cnt,
        max_th=batch.max_th,
        xp=xp,
    )
    t_exe = seg(count * g["t_total"])
    t_ideal = seg(count * batch.delta * g["t_ideal"])
    t_ovh = seg(count * batch.delta * g["t_ovh"])
    ratio = seg(count * g["ratio_term"])
    total_bytes = seg(count * g["total_bytes"])
    latency_bound = seg(count * g["latency_bound"]) > 0
    return BatchEstimate(
        t_exe=t_exe,
        t_ideal=t_ideal,
        t_ovh=t_ovh,
        bound_ratio=ratio,
        memory_bound=(ratio >= 1.0) | latency_bound,
        total_bytes=total_bytes,
        n_lsu=seg(count),
        groups=g,
    )
