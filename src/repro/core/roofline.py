"""Three-term roofline report per (architecture x shape x mesh) cell.

Terms follow the mandated formulas (per-device / per-chip semantics — the
compiled SPMD module *is* the per-chip program):

    compute term    = HLO_FLOPs            / peak_FLOP/s          [s]
    memory term     = HLO_bytes            / HBM_bw               [s]
    collective term = collective_bytes     / link_bw              [s]

plus the refined memory term from the paper's access-class model
(``predictor.predict_step``) and bookkeeping:

    MODEL_FLOPS     = 6 * N(_active) * D   (train)  /  2 * N * D  (serve)
    MODEL_BYTES     = algorithmic-minimum HBM traffic (config.model_bytes)
    useful-FLOPs    = MODEL_FLOPS / (HLO_FLOPs * chips)
    useful-bytes    = MODEL_BYTES / (HLO_bytes * chips)
    roofline fraction = ideal-time-on-dominant-resource / t_step
                        (classical MFU when compute-dominant)
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.core.hbm import TpuParams, _as_tpu_params
from repro.core import predictor as _pred


def _chip() -> TpuParams:
    """The registry default chip's view (was the TPU_V5E constant)."""
    return _as_tpu_params(None)


@dataclasses.dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_operand_bytes: float   # formula-mandated "operand sizes" sum
    collective_wire_bytes: float
    n_collectives: int
    model_flops_global: float
    model_bytes_global: float = 0.0
    t_compute: float = 0.0
    t_memory_naive: float = 0.0
    t_memory_refined: float = 0.0
    t_collective: float = 0.0
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)
    hw: TpuParams | None = None   # the chip the terms were computed against

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory_refined or self.t_memory_naive,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        return max(self.t_compute, self.t_memory_refined or self.t_memory_naive,
                   self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def useful_bytes_ratio(self) -> float:
        """MODEL_BYTES / (HLO bytes x chips) — how much of the compiled
        traffic is algorithmically necessary (catches scan-carry spills,
        resharding copies, f32 legalization)."""
        hlo_global = self.bytes_per_chip * self.chips
        return (self.model_bytes_global / hlo_global) if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant roofline used by *useful* work:
        compute-dominant -> classical MFU (MODEL_FLOPS time / t_step);
        memory-dominant  -> MODEL_BYTES time / t_step;
        collective-dominant -> wire-ideal / t_step."""
        if self.t_step <= 0:
            return 0.0
        chip = self.hw if self.hw is not None else _chip()
        if self.dominant == "compute":
            ideal = self.model_flops_global / (self.chips * chip.peak_flops)
        elif self.dominant == "memory":
            if self.model_bytes_global:
                ideal = self.model_bytes_global / (self.chips * chip.hbm_bw)
            else:
                ideal = self.t_memory_naive
        else:
            ideal = self.collective_wire_bytes / (chip.ici_bw * chip.ici_links)
        return min(1.0, ideal / self.t_step)

    def as_row(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory_naive,
            "t_memory_refined_s": self.t_memory_refined,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "t_step_s": self.t_step,
            "hlo_flops_per_chip": self.flops_per_chip,
            "hlo_bytes_per_chip": self.bytes_per_chip,
            "collective_operand_bytes": self.collective_operand_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "model_flops_global": self.model_flops_global,
            "model_bytes_global": self.model_bytes_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "useful_bytes_ratio": self.useful_bytes_ratio,
            "roofline_fraction": self.roofline_fraction,
            **self.extra,
        }


def build_cell(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    hlo_text: str,
    cost: dict[str, float] | None = None,
    model_flops_global: float,
    hw: TpuParams | None = None,
    extra: dict[str, Any] | None = None,
) -> RooflineCell:
    """Cell from compiled HLO text (trip-aware static analysis; the raw
    ``cost_analysis`` dict is kept in ``extra`` for cross-checking)."""
    hw = _as_tpu_params(hw)
    pred = _pred.predict_step(hlo_text, cost, hw)
    flops = pred.flops
    nbytes = pred.hbm_bytes
    extra = dict(extra or {})
    if cost:
        extra.setdefault("xla_cost_flops", cost.get("flops"))
        extra.setdefault("xla_cost_bytes", cost.get("bytes_accessed"))
    return RooflineCell(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=nbytes,
        collective_operand_bytes=pred.collective_operand_bytes,
        collective_wire_bytes=pred.collective_wire_bytes,
        n_collectives=pred.n_collectives,
        model_flops_global=model_flops_global,
        t_compute=flops / hw.peak_flops,
        t_memory_naive=nbytes / hw.hbm_bw,
        t_memory_refined=pred.t_memory,
        t_collective=pred.t_collective,
        extra=extra or {},
        hw=hw,
    )


def write_report(cells: list[RooflineCell], path: str) -> None:
    with open(path, "w") as f:
        json.dump([c.as_row() for c in cells], f, indent=1, default=float)


def markdown_table(cells: list[RooflineCell]) -> str:
    hdr = ("| arch | shape | mesh | compute [ms] | memory [ms] | refined-mem [ms] "
           "| collective [ms] | dominant | useful-FLOPs | roofline-frac |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for c in cells:
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.t_compute*1e3:.2f} "
            f"| {c.t_memory_naive*1e3:.2f} | {c.t_memory_refined*1e3:.2f} "
            f"| {c.t_collective*1e3:.2f} | {c.dominant} "
            f"| {c.useful_flops_ratio:.2f} | {c.roofline_fraction:.2f} |"
        )
    return "\n".join(rows)
