"""The paper's analytical model (Eqs. 1-10), faithful to the FPGA/HLS setting.

Execution time of a memory-bound kernel is estimated as

    T_exe = sum_i  delta_i * (T_ideal_i + T_ovh_i)            (Eq. 1)

over all GMI LSUs ``i``, where

    T_ideal_i = ls_bytes_i * ls_acc_i / bw_mem                (Eq. 2)

is the DRAM-bandwidth floor (identical for every LSU type) and ``T_ovh_i``
captures the DRAM row-miss overhead, whose form depends on the LSU type:

* burst-coalesced (Eq. 4):  0 when #lsu < 2 (bank interleaving hides row
  opens for a single stream), else one ``T_row`` per effective burst,
  with ``T_row = T_RCD + T_RP``  (Eq. 6) and the effective ``burst_size``
  from Eq. 5 (aligned), Eqs. 7-8 (non-aligned, the ``max_th`` knee), or
  Eq. 5 + wasted-burst inflation + ``T_WR``  (write-ACK, Eq. 9);
* atomic-pipelined (Eq. 10): every atomic performs a read and a write, so
  ``T_row = 2*(T_RCD + T_RP) + T_WR`` per operation (divided by the
  vectorization factor ``f`` when the operand is loop-constant and the
  compiler merges updates).

The static memory-bound criterion is

    sum_i ls_width_i / (dq * bl * K_lsu_i)  >=  1             (Eq. 3)

with ``K_lsu = delta`` for coalescing LSUs and 1 for write-ACK/atomic.

Interpretation notes (ambiguities in the paper text, resolved here and
validated against the paper's own numbers in tests/benchmarks):

* Write-ACK "each burst only consumes ls_bytes increasing the total time by
  dq*bl/ls_bytes" (SIII-A3) is modelled as extra *transfer* time inside
  ``T_ovh`` (Eq. 2 is explicitly type-independent), i.e.
  ``T_ovh += ls_acc * (dq*bl - ls_bytes) / bw_mem``.
* Atomic Eq. 10 gives a *per-operation* overhead; the LSU total is
  ``ls_acc`` times that (Fig. 4d shows time linear in #ga).

The heavy lifting lives in :mod:`repro.core.model_batch`, an array-based
restatement of the same equations that scores whole design spaces in one
vectorized pass (see :mod:`repro.core.sweep`).  ``estimate`` below is a thin
scalar wrapper over that core; ``lsu_timing`` is kept as the readable scalar
reference implementation and is cross-checked against the array core in the
tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.fpga import BspParams, DramParams
from repro.core.lsu import Lsu, LsuType


def _default_bsp() -> BspParams:
    """The registry default board's BSP view (was the STRATIX10_BSP const)."""
    from repro.hw import DEFAULT_BOARD, get as _get

    return _get(DEFAULT_BOARD).bsp_params()


@dataclasses.dataclass(frozen=True)
class LsuTiming:
    """Per-LSU breakdown of the estimate."""

    lsu: Lsu
    burst_size: float      # effective bytes per DRAM transaction
    n_bursts: float        # number of DRAM transactions issued
    t_ideal: float         # Eq. 2 [s]
    t_ovh: float           # Eq. 4 / 9 / 10 [s]

    @property
    def t_total(self) -> float:
        """Contribution to Eq. 1: delta * (T_ideal + T_ovh)."""
        return self.lsu.delta * (self.t_ideal + self.t_ovh)


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    """Model output for one kernel."""

    t_exe: float                     # Eq. 1 [s]
    memory_bound: bool               # Eq. 3
    bound_ratio: float               # LHS of Eq. 3
    per_lsu: tuple[LsuTiming, ...]

    @property
    def t_ideal(self) -> float:
        return sum(t.lsu.delta * t.t_ideal for t in self.per_lsu)

    @property
    def t_ovh(self) -> float:
        return sum(t.lsu.delta * t.t_ovh for t in self.per_lsu)

    @property
    def total_bytes(self) -> int:
        return sum(t.lsu.total_bytes for t in self.per_lsu)

    @property
    def effective_bandwidth(self) -> float:
        """Useful bytes / predicted time [B/s] — paper SV-A1's 14.2->10.5 GB/s."""
        return self.total_bytes / self.t_exe if self.t_exe > 0 else math.inf


def k_lsu(lsu: Lsu) -> float:
    """Eq. 3 coalescing-efficiency factor per LSU type."""
    if lsu.lsu_type in (LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED, LsuType.BC_CACHE):
        return float(lsu.delta)
    # write-ACK (paper SIII-A3: "K_lsu equals 1") and atomic.
    return 1.0


def burst_size_bytes(lsu: Lsu, dram: DramParams, bsp: BspParams) -> float:
    """Effective DRAM transaction size for this LSU [bytes]."""
    max_txn = bsp.max_transaction_bytes(dram)  # Eq. 5: 2**burst_cnt * dq * bl
    if lsu.lsu_type in (LsuType.BC_ALIGNED, LsuType.BC_CACHE, LsuType.BC_WRITE_ACK):
        return float(max_txn)
    if lsu.lsu_type is LsuType.BC_NON_ALIGNED:
        # Eq. 7: the thread-count trigger caps the assembled request.
        max_reqs = bsp.max_th * lsu.ls_width / (lsu.delta + 1)
        # Eq. 8: whichever trigger fires first defines the effective burst.
        if max_reqs <= max_txn:
            return max_reqs / lsu.delta
        return lsu.ls_width / lsu.delta
    if lsu.lsu_type is LsuType.ATOMIC_PIPELINED:
        return float(dram.min_burst_bytes)  # no burst grouping at all
    raise ValueError(f"{lsu.lsu_type} does not issue DRAM bursts")


def t_row_seconds(lsu: Lsu, dram: DramParams) -> float:
    """Row-miss inter-command delay for this LSU type [s]."""
    if lsu.lsu_type in (LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED, LsuType.BC_CACHE):
        return dram.t_row                                   # Eq. 6
    if lsu.lsu_type is LsuType.BC_WRITE_ACK:
        return dram.t_row + dram.t_wr                       # Eq. 9
    if lsu.lsu_type is LsuType.ATOMIC_PIPELINED:
        return 2.0 * dram.t_row + dram.t_wr                 # Eq. 10 (read+write)
    raise ValueError(f"{lsu.lsu_type} has no DRAM row timing")


def lsu_timing(
    lsu: Lsu,
    dram: DramParams,
    bsp: BspParams,
    *,
    n_lsu: int,
    f: int = 1,
) -> LsuTiming:
    """Timing terms for a single LSU (Eqs. 2, 4-10)."""
    t_ideal = lsu.total_bytes / dram.bw_mem                 # Eq. 2
    bsz = burst_size_bytes(lsu, dram, bsp)
    n_bursts = lsu.total_bytes / bsz
    t_row = t_row_seconds(lsu, dram)

    if lsu.lsu_type is LsuType.ATOMIC_PIPELINED:
        # Eq. 10: per-operation overhead, merged across f when val is constant.
        per_op = t_row / f if lsu.val_constant else t_row
        t_ovh = lsu.ls_acc * per_op
        return LsuTiming(lsu=lsu, burst_size=bsz, n_bursts=float(lsu.ls_acc),
                         t_ideal=t_ideal, t_ovh=t_ovh)

    # Burst-coalesced family, Eq. 4: a single stream never thrashes rows.
    if n_lsu < 2:
        t_ovh = 0.0
    else:
        t_ovh = n_bursts * t_row
    if lsu.lsu_type is LsuType.BC_WRITE_ACK:
        # Wasted-burst transfer inflation (SIII-A3): each dq*bl burst carries
        # only ls_bytes useful bytes.
        waste = dram.min_burst_bytes - lsu.ls_bytes
        if waste > 0:
            t_ovh += lsu.ls_acc * waste / dram.bw_mem
        if n_lsu < 2:
            # the ACK round-trip itself is never hidden
            t_ovh += n_bursts * t_row
    return LsuTiming(lsu=lsu, burst_size=bsz, n_bursts=n_bursts,
                     t_ideal=t_ideal, t_ovh=t_ovh)


def memory_bound_ratio(lsus: Sequence[Lsu], dram: DramParams) -> float:
    """LHS of Eq. 3."""
    return sum(lsu.ls_width / (dram.min_burst_bytes * k_lsu(lsu)) for lsu in lsus)


def _estimate(
    lsus: Sequence[Lsu],
    dram: DramParams,
    bsp: BspParams | None = None,
    *,
    f: int = 1,
) -> KernelEstimate:
    """Full model: Eq. 3 classification + Eq. 1 execution time.

    Thin scalar wrapper over the array core: each LSU runs through the same
    `model_batch.group_timing` math, on plain Python scalars (the
    `SCALAR_XP` namespace shim keeps the call as cheap as the old scalar
    code).  This is the implementation behind ``Session(backend="scalar")``;
    the public surface is ``repro.Session.estimate(repro.Design(...))``.
    """
    from repro.core import model_batch as _mb

    bsp = bsp if bsp is not None else _default_bsp()
    glob = [l for l in lsus if l.lsu_type.is_global]
    if not glob:
        return KernelEstimate(t_exe=0.0, memory_bound=False, bound_ratio=0.0,
                              per_lsu=())
    t_exe = 0.0
    ratio = 0.0
    latency_bound = False
    timings = []
    for l in glob:
        g = _mb.group_timing(
            lsu_type=_mb.TYPE_CODE[l.lsu_type],
            ls_width=l.ls_width, ls_acc=l.ls_acc, ls_bytes=l.ls_bytes,
            delta=l.delta, val_constant=l.val_constant,
            n_lsu=len(glob), f=f,
            dq=dram.dq, bl=dram.bl, f_mem=dram.f_mem,
            t_rcd=dram.t_rcd, t_rp=dram.t_rp, t_wr=dram.t_wr,
            burst_cnt=bsp.burst_cnt, max_th=bsp.max_th,
            xp=_mb.SCALAR_XP,
        )
        timings.append(LsuTiming(lsu=l, burst_size=float(g["burst_size"]),
                                 n_bursts=float(g["n_bursts"]),
                                 t_ideal=float(g["t_ideal"]),
                                 t_ovh=float(g["t_ovh"])))
        t_exe += g["t_total"]                               # Eq. 1
        ratio += g["ratio_term"]                            # Eq. 3 LHS
        latency_bound = latency_bound or bool(g["latency_bound"])
    return KernelEstimate(
        t_exe=float(t_exe),
        memory_bound=ratio >= 1.0 or latency_bound,
        bound_ratio=float(ratio),
        per_lsu=tuple(timings),
    )


def pipeline_time(
    n_work_items: int,
    *,
    f: int = 1,
    f_kernel: float = 300e6,
    depth: int = 300,
    ii: int = 1,
) -> float:
    """Simple kernel-pipeline bound (outside the paper's scope; used only to
    reproduce Fig. 3's compute-bound points — the paper defers those to prior
    models [6,7]):  (n_wi/f * II + depth) / f_kernel.
    """
    return (n_work_items / f * ii + depth) / f_kernel
