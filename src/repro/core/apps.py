"""Benchmark descriptors: the paper's microbenchmarks (SIV, Listings 3-5) and
the nine Table IV applications.

Microbenchmarks are fully specified by the paper (sum reductions with a
tunable number of global accesses #ga, SIMD vector lanes, stride delta).
For the Table IV applications the paper publishes the LSU structure (GMI
type, #lsu) and the measured/estimated times, but **not** the input sizes.
Since the model is linear in the input size, we calibrate one scalar per
application — the element count ``n_elems`` — against the paper's *estimated*
time, and then validate:

* the error against the paper's *measured* time reproduces the Table IV error
  column (genuine, not circular: the error is fixed once the scale is set);
* ``VectorAdd delta=2`` is predicted with the scale calibrated on the
  ``delta=1`` row — a true held-out check of the stride term;
* Table V model comparisons are scale-free (relative errors).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.fpga import BspParams, DramParams
from repro.core.lsu import Lsu, LsuType
from repro.core import model as _model


def _defaults(dram: DramParams | None, bsp: BspParams | None,
              ) -> tuple[DramParams, BspParams]:
    """Registry default board (was the DDR4_1866/STRATIX10_BSP constants)."""
    from repro.hw import DEFAULT_BOARD, get as _get

    board = _get(DEFAULT_BOARD)
    return (dram if dram is not None else board.dram_params(),
            bsp if bsp is not None else board.bsp_params())


# ---------------------------------------------------------------------------
# Microbenchmarks (Listing 3 + Listing 4/5 bodies)
# ---------------------------------------------------------------------------

def microbench(
    lsu_type: LsuType,
    *,
    n_ga: int,
    simd: int = 16,
    n_elems: int = 1 << 22,
    delta: int = 1,
    elem_bytes: int = 4,
    include_write: bool = True,
    span_bytes: int | None = None,
    val_constant: bool = False,
) -> list[Lsu]:
    """LSU list for the SIV sum-reduction microbenchmarks.

    ``z[id] = x1[id] + ... + xn[id]`` with ``n_ga`` read arrays; the write is
    of the same type as the reads (Listing 4 uses one body per modifier).
    Atomic microbenchmarks (Listing 5) have ``n_ga`` atomic updates and one
    aligned read per GA feeding the value.
    """
    lsus: list[Lsu] = []
    if lsu_type is LsuType.ATOMIC_PIPELINED:
        for g in range(n_ga):
            lsus.append(Lsu(LsuType.ATOMIC_PIPELINED, ls_width=elem_bytes,
                            ls_acc=n_elems, ls_bytes=elem_bytes, is_write=True,
                            val_constant=val_constant, name=f"atomic{g}"))
        return lsus

    if lsu_type is LsuType.BC_WRITE_ACK:
        # data-dependent store: the compiler replicates `simd` scalar LSUs for
        # the write; the reads stay burst-coalesced aligned.  The paper's
        # microbenchmark confines the random target to 2048 ints (= one 8 KB
        # DRAM row), which is the default footprint here.
        span_bytes = span_bytes or 2048 * elem_bytes
        for g in range(n_ga):
            lsus.append(Lsu(LsuType.BC_ALIGNED, ls_width=simd * elem_bytes,
                            ls_acc=n_elems // simd, ls_bytes=simd * elem_bytes,
                            name=f"x{g}"))
        if include_write:
            for k in range(simd):
                lsus.append(Lsu(LsuType.BC_WRITE_ACK, ls_width=elem_bytes,
                                ls_acc=n_elems // simd, ls_bytes=elem_bytes,
                                is_write=True, span_bytes=span_bytes,
                                name=f"z[{k}]"))
        return lsus

    for g in range(n_ga):
        lsus.append(Lsu(lsu_type, ls_width=simd * elem_bytes,
                        ls_acc=n_elems // simd, ls_bytes=simd * elem_bytes,
                        delta=delta, name=f"x{g}"))
    if include_write:
        lsus.append(Lsu(lsu_type, ls_width=simd * elem_bytes,
                        ls_acc=n_elems // simd, ls_bytes=simd * elem_bytes,
                        delta=delta, is_write=True, name="z"))
    return lsus


# ---------------------------------------------------------------------------
# Table IV applications
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AppDescriptor:
    """One Table IV row: LSU structure + paper-reported times."""

    name: str
    source: str
    gmi: LsuType
    n_read: int
    n_write: int
    delta: int = 1
    simd: int = 16
    elem_bytes: int = 4
    measured_ms: float = 0.0     # Table IV "M.Time"
    paper_est_ms: float = 0.0    # Table IV "E.Time"
    paper_err_pct: float = 0.0   # Table IV "Error"
    calibrate_to: str | None = None  # calibrate scale on another app's row

    @property
    def n_lsu(self) -> int:
        return self.n_read + self.n_write

    def lsus(self, n_elems: int) -> list[Lsu]:
        out: list[Lsu] = []
        if self.gmi is LsuType.BC_WRITE_ACK:
            # Table IV reports total #lsu directly for ACK apps (NW: 4).
            per = max(1, n_elems)
            for k in range(self.n_read):
                out.append(Lsu(LsuType.BC_WRITE_ACK, ls_width=self.elem_bytes,
                               ls_acc=per, ls_bytes=self.elem_bytes,
                               name=f"{self.name}.r{k}"))
            for k in range(self.n_write):
                out.append(Lsu(LsuType.BC_WRITE_ACK, ls_width=self.elem_bytes,
                               ls_acc=per, ls_bytes=self.elem_bytes,
                               is_write=True, name=f"{self.name}.w{k}"))
            return out
        w = self.simd * self.elem_bytes
        acc = max(1, n_elems // self.simd)
        for k in range(self.n_read):
            out.append(Lsu(self.gmi, ls_width=w, ls_acc=acc, ls_bytes=w,
                           delta=self.delta, name=f"{self.name}.r{k}"))
        for k in range(self.n_write):
            out.append(Lsu(self.gmi, ls_width=w, ls_acc=acc, ls_bytes=w,
                           delta=self.delta, is_write=True,
                           name=f"{self.name}.w{k}"))
        return out

    def calibrated_elems(self, dram: DramParams | None = None,
                         bsp: BspParams | None = None) -> int:
        """Input size such that the model reproduces the paper's E.Time.

        Calibrated against ``calibrate_to``'s row when set (the held-out
        VectorAdd delta=2 case), else against this app's own E.Time.
        """
        dram, bsp = _defaults(dram, bsp)
        ref = APPS[self.calibrate_to] if self.calibrate_to else self
        probe = 1 << 20
        t_probe = _model._estimate(ref.lsus(probe), dram, bsp).t_exe
        scale = (ref.paper_est_ms * 1e-3) / t_probe
        n = int(round(probe * scale / self.simd)) * self.simd
        return max(self.simd, n)


_T = LsuType
APPS: dict[str, AppDescriptor] = {
    a.name: a
    for a in [
        # name        source            gmi            r  w  delta
        AppDescriptor("dot", "FBLAS [16]", _T.BC_ALIGNED, 2, 1,
                      measured_ms=60.2, paper_est_ms=64.5, paper_err_pct=7.3),
        AppDescriptor("fft1d", "Intel SDK [10]", _T.BC_ALIGNED, 1, 1,
                      measured_ms=9.5, paper_est_ms=8.8, paper_err_pct=7.3),
        AppDescriptor("nn", "Rodinia [5]", _T.BC_ALIGNED, 1, 1,
                      measured_ms=157.5, paper_est_ms=172.1, paper_err_pct=9.2),
        AppDescriptor("rot", "FBLAS [16]", _T.BC_ALIGNED, 2, 2,
                      measured_ms=92.7, paper_est_ms=86.1, paper_err_pct=7.2),
        AppDescriptor("vectoradd", "Intel SDK [10]", _T.BC_ALIGNED, 2, 1,
                      measured_ms=33.3, paper_est_ms=33.2, paper_err_pct=5.1),
        AppDescriptor("vectoradd_d2", "Intel SDK [10]", _T.BC_ALIGNED, 2, 1,
                      delta=2, measured_ms=67.9, paper_est_ms=63.0,
                      paper_err_pct=6.5, calibrate_to="vectoradd"),
        AppDescriptor("hotspot", "Rodinia [5]", _T.BC_NON_ALIGNED, 2, 1,
                      measured_ms=9.7, paper_est_ms=8.8, paper_err_pct=8.7),
        AppDescriptor("pathfinder", "Rodinia [5]", _T.BC_NON_ALIGNED, 2, 1,
                      measured_ms=275.9, paper_est_ms=254.0, paper_err_pct=7.9),
        AppDescriptor("wm", "Vivado [17]", _T.BC_NON_ALIGNED, 1, 1,
                      measured_ms=59.8, paper_est_ms=55.8, paper_err_pct=6.6),
        AppDescriptor("nw", "Rodinia [5]", _T.BC_WRITE_ACK, 3, 1,
                      measured_ms=1.4, paper_est_ms=1.4, paper_err_pct=4.0),
    ]
}


def table4_rows(dram: DramParams | None = None,
                bsp: BspParams | None = None) -> list[dict]:
    """Reproduce Table IV: per-app estimate vs the paper's measured time."""
    dram, bsp = _defaults(dram, bsp)
    rows = []
    for app in APPS.values():
        n = app.calibrated_elems(dram, bsp)
        est = _model._estimate(app.lsus(n), dram, bsp)
        est_ms = est.t_exe * 1e3
        err = abs(est_ms - app.measured_ms) / app.measured_ms * 100.0
        rows.append({
            "kernel": app.name,
            "gmi": app.gmi.value,
            "n_lsu": app.n_lsu,
            "measured_ms": app.measured_ms,
            "est_ms": round(est_ms, 2),
            "paper_est_ms": app.paper_est_ms,
            "err_pct": round(err, 2),
            "paper_err_pct": app.paper_err_pct,
            "memory_bound": est.memory_bound,
            "n_elems": n,
        })
    return rows
