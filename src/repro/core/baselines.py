"""Reimplementations of the two state-of-the-art models the paper compares
against in Table V.

The original tools are not public (paper SIV: "their dynamic profiling tools
feeding the models are not publicly available"), so — like the paper's
authors, who "manually computed their estimations" — we reimplement the
*memory components* of each model as described in the respective papers and
in our paper's SV-C / SVI analysis:

* **Wang** [6] (HPCA'16): coarse-grain memory model.  Global accesses are
  charged at a fixed effective bandwidth calibrated once on the original
  evaluation board (Stratix V + DDR3-1600); LSU modifiers are not
  distinguished ("incomplete support of all LSU modifiers"), strides are
  folded into the coalesced stream, and the DRAM parameters (frequency, row
  misses) are not inputs — so the model cannot adapt when the BSP memory
  changes (the DDR4-2666 rows of Table V).  Data-dependent accesses fall
  outside the pipelined-coalesced assumption and are charged the full
  unpipelined DRAM round trip per access, which produces the 8049 % / 11279 %
  ACK signatures.

* **HLScope+** [7] (ICCAD'17): memory time = bytes / characterized bandwidth
  plus a board-characterized controller overhead ``Tco`` per DRAM burst
  (SV-C: "Tco = 2.5 ns for #lsu > 3, Tco = 0 ns in other cases").  The
  characterization is performed once per board at nominal frequency, so a
  different DRAM clock degrades accuracy; stride/data-dependence enter only
  through a fixed efficiency factor.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.fpga import DramParams
from repro.core.lsu import Lsu, LsuType
from repro.hw import DEFAULT_BOARD, get as _hw_get

# Wang [6] calibration constants (Stratix V devkit, DDR3-1600: 12.8 GB/s
# theoretical; ~85 % achievable in their microbenchmarks).
_WANG_BW = 12.8e9 * 0.85
# Unpipelined DRAM round trip charged per data-dependent access (CAS + row
# cycle + controller/PCIe-side queueing on their measurement path).
_WANG_RANDOM_LATENCY = 150e-9

# HLScope+ characterization (performed at DDR4-1866 nominal).
_HLSCOPE_BW = (_hw_get(DEFAULT_BOARD).dram_params().bw_mem
               * 0.92)                    # characterized stream bandwidth
_HLSCOPE_TCO_MANY_LSU = 2.5e-9            # SV-C: Tco=2.5ns for #lsu>3
_HLSCOPE_BURST_BYTES = 512                # their fixed burst granularity
_HLSCOPE_RANDOM_EFF = 0.5                 # efficiency knob for irregular LSUs


def wang_estimate(lsus: Sequence[Lsu], dram: DramParams) -> float:
    """Wang [6]: fixed-bandwidth coalesced model, latency-serial for
    data-dependent accesses.  ``dram`` is ignored by design — that is the
    model's documented weakness."""
    del dram
    t = 0.0
    for lsu in lsus:
        if not lsu.lsu_type.is_global:
            continue
        if lsu.lsu_type in (LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED):
            t += lsu.ls_acc * _WANG_RANDOM_LATENCY
        else:
            # stride collapses into the coalesced stream (useful bytes only)
            t += lsu.total_bytes / _WANG_BW
    return t


def hlscope_estimate(lsus: Sequence[Lsu], dram: DramParams) -> float:
    """HLScope+ [7]: characterized bandwidth + per-burst controller overhead.

    The characterization constants are tied to the board at DDR4-1866; the
    model reuses them verbatim at other DRAM frequencies (Table V, lower
    half).
    """
    del dram
    glob = [l for l in lsus if l.lsu_type.is_global]
    n_lsu = len(glob)
    tco = _HLSCOPE_TCO_MANY_LSU if n_lsu > 3 else 0.0
    t = 0.0
    for lsu in glob:
        eff = 1.0
        if lsu.lsu_type in (LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED,
                            LsuType.BC_NON_ALIGNED):
            eff = _HLSCOPE_RANDOM_EFF
        bytes_moved = lsu.total_bytes
        t += bytes_moved / (_HLSCOPE_BW * eff)
        t += (bytes_moved / _HLSCOPE_BURST_BYTES) * tco
    return t
