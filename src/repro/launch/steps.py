"""Step functions (train / prefill / decode) with sharding wiring.

``build_step`` returns the jitted function plus the in/out shardings and the
ShapeDtypeStruct inputs for one (cfg, shape, mesh) cell — shared by the
dry-run, the trainer and the server.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec, input_specs
from repro.models import transformer as TF
from repro.models.config import ModelConfig
from repro.models.pspec import axis_rules
from repro.optim import OptimizerConfig, adamw_init, adamw_update
from repro.runtime.compression import compress_grads, decompress_grads
from repro.launch import sharding as SH


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    grad_compression: str = "none"      # none | bf16 | int8
    kv_shard: str = "auto"              # auto | heads | seq
    # Keep FSDP weight sharding at decode: SPerf Cell A iter 3 measured the
    # alternative (replicated weights) at +62 ms HBM re-reads vs -36 ms of
    # gathers at batch 128 — replication only wins for latency-bound tiny
    # batches (and blows the footprint on MoE experts).
    fsdp_decode: bool = True


@dataclasses.dataclass
class BuiltStep:
    fn: Callable                         # jitted
    args: tuple                          # ShapeDtypeStructs (dry-run inputs)
    in_shardings: Any
    out_shardings: Any
    plan: SH.ShardingPlan
    kind: str


def make_train_step(cfg: ModelConfig, mesh: Mesh, plan: SH.ShardingPlan,
                    tcfg: TrainConfig):
    def train_step(params, opt_state, batch):
        with axis_rules(mesh, plan.rules()):
            (loss, metrics), grads = jax.value_and_grad(
                TF.loss_fn, has_aux=True)(params, cfg, batch)
            if tcfg.grad_compression != "none":
                wire, _ = compress_grads(grads, tcfg.grad_compression)
                grads = decompress_grads(wire, tcfg.grad_compression, grads)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 tcfg.optimizer)
        return params, opt_state, {"loss": loss, **metrics, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, plan: SH.ShardingPlan):
    def prefill_step(params, batch):
        with axis_rules(mesh, plan.rules()):
            x = TF.embed_inputs(params, cfg,
                                tokens=batch.get("tokens"),
                                features=batch.get("features"))
            h, _ = TF.forward_hidden(params, cfg, x)
            return TF.logits_fn(params, cfg, h[:, -1:, :])
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh, plan: SH.ShardingPlan):
    def serve_step(params, tokens, caches, index):
        with axis_rules(mesh, plan.rules()):
            logits, caches = TF.decode_step(params, cfg, tokens, caches, index)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, caches
    return serve_step


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               tcfg: TrainConfig = TrainConfig()) -> BuiltStep:
    """Assemble the jitted step + shardings + abstract inputs for one cell."""
    plan = SH.make_plan(cfg, mesh, global_batch=shape.global_batch,
                        kv_shard=tcfg.kv_shard, kind=shape.kind,
                        fsdp_decode=tcfg.fsdp_decode)
    specs = input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    params_shape = jax.eval_shape(
        lambda: TF.init_params(jax.random.PRNGKey(0), cfg))
    pshard = SH.param_shardings(params_shape, plan, mesh)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(
            lambda: adamw_init(params_shape, tcfg.optimizer))
        oshard = SH.opt_state_shardings(opt_shape, pshard, mesh, plan)
        bshard = SH.batch_shardings(specs["batch"], plan, mesh)
        fn = jax.jit(make_train_step(cfg, mesh, plan, tcfg),
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, repl),
                     donate_argnums=(0, 1))
        return BuiltStep(fn=fn, args=(params_shape, opt_shape, specs["batch"]),
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, repl),
                         plan=plan, kind="train")

    if shape.kind == "prefill":
        bshard = SH.batch_shardings(specs["batch"], plan, mesh)
        logits_shard = NamedSharding(
            mesh, P(plan.batch_axes, None, plan.vocab_axes))
        fn = jax.jit(make_prefill_step(cfg, mesh, plan),
                     in_shardings=(pshard, bshard),
                     out_shardings=logits_shard)
        return BuiltStep(fn=fn, args=(params_shape, specs["batch"]),
                         in_shardings=(pshard, bshard),
                         out_shardings=logits_shard, plan=plan, kind="prefill")

    # decode / long_decode
    cshard = SH.cache_shardings(specs["caches"], plan, mesh, cfg)
    tok_shard = NamedSharding(mesh, P(plan.batch_axes, None))
    logits_shard = NamedSharding(mesh, P(plan.batch_axes, plan.vocab_axes))
    fn = jax.jit(make_decode_step(cfg, mesh, plan),
                 in_shardings=(pshard, tok_shard, cshard, repl),
                 out_shardings=(tok_shard, logits_shard, cshard),
                 donate_argnums=(2,))
    return BuiltStep(fn=fn,
                     args=(params_shape, specs["tokens"], specs["caches"],
                           specs["index"]),
                     in_shardings=(pshard, tok_shard, cshard, repl),
                     out_shardings=(tok_shard, logits_shard, cshard),
                     plan=plan, kind=shape.kind)
