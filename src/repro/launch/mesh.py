"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
initialization, while smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh as _make_mesh  # noqa: F401
# AxisType is re-exported for callers that used the old shim location.


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(*, model_parallel: int | None = None) -> Mesh:
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    model = model_parallel or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return _make_mesh((data, model), ("data", "model"))
