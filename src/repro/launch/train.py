"""Training driver: data -> step -> checkpoint, with fault tolerance wired.

Runs at any scale: the production pod meshes (on real TPUs), or a local
host mesh for the examples/tests (``--local``).  Features exercised here and
covered by tests:

* auto-resume from the latest atomic checkpoint (restart-safe data by step);
* SIGTERM preemption -> checkpoint -> clean exit;
* straggler watchdog on per-step wall times;
* async checkpointing off the training thread;
* optimizer-state dtype + gradient compression knobs (TrainConfig).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, list_archs, reduced_config
from repro.configs.shapes import ShapeSpec
from repro.data import make_dataset
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import BuiltStep, TrainConfig, build_step
from repro.models import transformer as TF
from repro.optim import OptimizerConfig, adamw_init
from repro.runtime import PreemptionHandler, StepWatchdog


def train_loop(cfg, built: BuiltStep, tcfg: TrainConfig, *,
               steps: int, ckpt_dir: str, data_cfg: DataConfig,
               ckpt_every: int = 50, log_every: int = 10,
               data_path: str | None = None,
               preemption: PreemptionHandler | None = None) -> dict:
    """Returns final metrics dict (used by tests and examples)."""
    ckpt = CheckpointManager(ckpt_dir)
    watchdog = StepWatchdog()
    preemption = preemption or PreemptionHandler().install()
    dataset = make_dataset(cfg, data_cfg, data_path)

    params = jax.jit(lambda: TF.init_params(jax.random.PRNGKey(0), cfg),
                     out_shardings=built.in_shardings[0])()
    opt_state = jax.jit(lambda: adamw_init(params, tcfg.optimizer),
                        out_shardings=built.in_shardings[1])()
    start_step = 0
    if ckpt.latest_step() is not None:
        (params, opt_state), start_step = ckpt.restore(
            (params, opt_state),
            shardings=(built.in_shardings[0], built.in_shardings[1]))
        print(f"[train] resumed from step {start_step}")

    metrics = {}
    step = start_step
    for step in range(start_step, steps):
        watchdog.start_step(step)
        batch = dataset.get_batch(step)
        params, opt_state, metrics = built.fn(params, opt_state, batch)
        dt = watchdog.end_step()
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            print(f"[train] step {step} loss {m.get('loss', float('nan')):.4f} "
                  f"({dt*1e3:.0f} ms)", flush=True)
        if (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state), blocking=False)
        if preemption.should_stop:
            print(f"[train] preempted at step {step}; checkpointing")
            break
    ckpt.save(step + 1, (params, opt_state), blocking=True)
    ckpt.wait()
    return {k: float(np.asarray(v)) for k, v in metrics.items()} | {
        "final_step": step + 1,
        "median_step_s": watchdog.median_step_time,
        "stragglers": len(watchdog.straggler_steps),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--local", action="store_true",
                    help="host mesh + reduced config (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--opt-state-dtype", default="float32")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", default=None, help="memmap token file")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.local:
        cfg = reduced_config(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(1, args.steps // 20),
                                  state_dtype=args.opt_state_dtype),
        grad_compression=args.grad_compression)
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    built = build_step(cfg, shape, mesh, tcfg)
    data_cfg = DataConfig(seq_len=args.seq_len, batch_size=args.batch)
    out = train_loop(cfg, built, tcfg, steps=args.steps,
                     ckpt_dir=args.ckpt_dir, data_cfg=data_cfg,
                     data_path=args.data)
    print("[train] done:", out)


if __name__ == "__main__":
    main()
