"""Serving driver: batched prefill + decode with a static-shape KV cache.

Implements a minimal continuous-batching server core: a request pool fills
fixed batch slots; finished sequences free their slot, which is immediately
refilled (prefill of the newcomer) while the rest of the batch keeps
decoding.  Everything runs through the same ``build_step`` machinery the
dry-run proves at pod scale.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.configs.shapes import ShapeSpec
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import TrainConfig, build_step, make_decode_step
from repro.models import transformer as TF
from repro.models.pspec import axis_rules
from repro.launch import sharding as SH


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot continuous batching on top of decode_step."""

    def __init__(self, cfg, mesh, *, batch_slots: int = 4,
                 max_len: int = 256, params=None):
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.slots = batch_slots
        self.plan = SH.make_plan(cfg, mesh, global_batch=batch_slots)
        self.params = params if params is not None else TF.init_params(
            jax.random.PRNGKey(0), cfg)
        self.caches = TF.init_caches(cfg, batch_slots, max_len)
        self._decode = jax.jit(make_decode_step(cfg, mesh, self.plan))
        # per-slot position counters; -1 = free slot
        self.pos = np.full((batch_slots,), -1, np.int64)
        self.active: dict[int, Request] = {}
        self.pending: list[Request] = []
        # prefill/decode accounting: prompt-feeding steps emit no tokens but
        # burn the same decode-step latency, so lumping them into one wall
        # clock deflates tokens/sec.  run() buckets every step by whether it
        # produced a token; report decode throughput from decode_s only.
        self.metrics = {"prefill_s": 0.0, "decode_s": 0.0,
                        "prefill_steps": 0, "decode_steps": 0,
                        "new_tokens": 0}

    # ------------------------------------------------------------ pool
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _fill_slots(self) -> None:
        for slot in range(self.slots):
            if self.pos[slot] >= 0 or not self.pending:
                continue
            req = self.pending.pop(0)
            self.active[slot] = req
            # sequential prefill through the shared cache (slot-local
            # correctness: each block's cache update is batched, so we feed
            # the prompt one token at a time for the whole batch; idle slots
            # feed padding token 0 and ignore the logits)
            self.pos[slot] = 0
            self._prefill_queue = getattr(self, "_prefill_queue", {})
            self._prefill_queue[slot] = list(req.prompt)

    def step(self) -> int:
        """One global decode step across all slots.

        Returns the number of tokens appended this step (0 for a pure
        prefill step) so callers can bucket its wall time honestly.
        """
        self._fill_slots()
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.active.items():
            q = getattr(self, "_prefill_queue", {}).get(slot) or []
            if q:
                tokens[slot, 0] = q.pop(0)
            elif req.generated:
                tokens[slot, 0] = req.generated[-1]
            elif req.prompt:
                tokens[slot, 0] = req.prompt[-1]
        index = int(self.pos[self.active and max(self.active) or 0])
        # NOTE: the static-shape cache uses one shared index; slots are
        # aligned because every slot advances every step (padding for idle).
        next_tok, logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(index, jnp.int32))
        next_np = np.asarray(next_tok)
        n_new = 0
        for slot, req in list(self.active.items()):
            self.pos[slot] += 1
            still_prefilling = bool(getattr(self, "_prefill_queue", {}).get(slot))
            if still_prefilling:
                continue
            req.generated.append(int(next_np[slot, 0]))
            n_new += 1
            if (len(req.generated) >= req.max_new
                    or self.pos[slot] >= self.max_len - 1):
                req.done = True
                del self.active[slot]
                self.pos[slot] = -1
        return n_new

    def run(self, requests: list[Request], *, max_steps: int = 10_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        out = list(requests)
        steps = 0
        m = self.metrics
        while (self.pending or self.active) and steps < max_steps:
            t0 = time.perf_counter()
            n_new = self.step()
            dt = time.perf_counter() - t0
            if n_new:
                m["decode_s"] += dt
                m["decode_steps"] += 1
                m["new_tokens"] += n_new
            else:
                m["prefill_s"] += dt
                m["prefill_steps"] += 1
            steps += 1
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.local:
        cfg = reduced_config(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh()
    server = BatchedServer(cfg, mesh, batch_slots=args.batch_slots,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    server.run(reqs)
    m = server.metrics
    total_new = sum(len(r.generated) for r in reqs)
    tok_s = total_new / m["decode_s"] if m["decode_s"] > 0 else 0.0
    print(f"[serve] {len(reqs)} requests, {total_new} tokens: "
          f"prefill {m['prefill_s']:.2f}s ({m['prefill_steps']} steps), "
          f"decode {m['decode_s']:.2f}s ({m['decode_steps']} steps, "
          f"{tok_s:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
