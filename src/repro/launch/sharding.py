"""Sharding policy: logical-axis rules for activations and path-based
PartitionSpecs for parameters, optimizer state, batches and caches.

Strategy (DESIGN.md S3):
* batch over ("pod","data") — pure DP across pods, FSDP within a pod;
* parameters FSDP-sharded over "data" on one dimension and tensor-parallel
  over "model" on the other (ZeRO-3 via GSPMD: per-layer all-gather under
  the remat'd scan);
* MoE experts expert-parallel over "model" when the expert count divides the
  axis, else tensor-parallel inside experts (grok-1's 8 experts);
* GQA KV heads shard over "model" when divisible; otherwise the *decode KV
  cache shards its sequence dim* over "model" (pod-level flash-decoding: XLA
  inserts the softmax-merge collectives) — selectable via ``kv_shard``;
* single-stream long-context decode (batch=1) can't data-parallelize, so
  channel-like axes spill onto ("data","model") jointly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Resolved axis assignment for one (cfg, mesh, shape) combination."""

    batch_axes: tuple[str, ...] | None
    fsdp_axes: tuple[str, ...] | None       # weight-dim sharding (ZeRO-3)
    tp_axis: str | None                     # tensor-parallel axis
    heads_axes: Any
    kv_heads_axes: Any
    kv_seq_axes: Any                        # decode-cache sequence sharding
    expert_axes: Any
    expert_ff_axes: Any
    rnn_axes: Any
    ff_axes: Any
    vocab_axes: Any
    mlstm_dh_axes: Any = None

    def rules(self) -> dict[str, Any]:
        """Logical-axis rules for ``pspec.axis_rules`` (activations)."""
        return {
            "batch": self.batch_axes,
            "seq": None,
            "kv_seq": self.kv_seq_axes,
            "heads": self.heads_axes,
            "kv_heads": self.kv_heads_axes,
            "ff": self.ff_axes,
            "vocab": self.vocab_axes,
            "experts": self.expert_axes,
            "expert_cap": self.batch_axes,
            "expert_ff": self.expert_ff_axes,
            "tokens": self.batch_axes,
            "rnn": self.rnn_axes,
            "mlstm_dh": self.mlstm_dh_axes,
            # sequence-parallel activation sharding at remat boundaries: the
            # saved (L, B, S, d) residual stack shards its seq dim over the
            # tensor-parallel axis (Megatron-SP style); blocks gather on
            # entry.  Disabled automatically for S=1 decode (dim < axis).
            "act_seq": self.tp_axis if self.batch_axes else None,
            # MoE einsum-dispatch token groups: batch axes + the TP axis.
            # (Dropping "pod" here was tried and REFUTED: wire rose 6x to
            # 56 TB/chip — pod-local groups force the dispatch contraction
            # to re-gather tokens across pods.  EXPERIMENTS.md SPerf.)
            "moe_groups": (tuple(self.batch_axes) + (self.tp_axis,)
                           if self.batch_axes and self.tp_axis
                           else self.batch_axes),
        }


def make_plan(cfg: ModelConfig, mesh: Mesh, *, global_batch: int,
              kv_shard: str = "auto", kind: str = "train",
              fsdp_decode: bool = False) -> ShardingPlan:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    data = sizes.get("data", 1)
    pod = sizes.get("pod", 1)

    batch_axes: tuple[str, ...] | None
    if global_batch % (pod * data) == 0 and global_batch >= pod * data:
        batch_axes = ("pod", "data") if pod > 1 else ("data",)
    elif pod > 1 and global_batch % pod == 0:
        batch_axes = ("pod",)
    else:
        batch_axes = None                      # single-stream decode

    fsdp: tuple[str, ...] | None = ("data",) if batch_axes else None
    if kind in ("decode", "long_decode") and not fsdp_decode:
        # Inference has no optimizer state: FSDP-sharded weights would be
        # all-gathered per layer *per token* (measured 7.2 GB wire/step on
        # command-r decode).  Keep weights TP-sharded only; the footprint
        # cost is params_bf16/model_axis per chip (SPerf Cell A iter 3).
        fsdp = None
    joint = ("data", "model") if batch_axes is None else None

    def div(n: int, axis_size: int):
        return n > 0 and n % axis_size == 0

    def div_pad(n: int, axis_size: int):
        # uneven sharding (GSPMD pads) — fine when the dim >= axis
        return n >= axis_size

    heads = "model" if div_pad(cfg.n_heads, model) else None
    kv_heads = "model" if div(cfg.n_kv_heads, model) else None
    if kv_shard == "heads" and kv_heads is None:
        raise ValueError("kv heads not divisible by model axis")
    kv_seq = None
    if kv_heads is None or kv_shard == "seq":
        kv_heads = None
        kv_seq = "model"

    experts = "model" if div(cfg.n_experts, model) else None
    expert_ff = None if experts else ("model" if div(cfg.d_ff, model) else None)

    rnn = (joint if joint and div(cfg.rnn_width, data * model)
           else ("model" if div(cfg.rnn_width, model) else None))
    # effective FFN width: mLSTM blocks (d_ff == 0) use the up-projection
    ff_width = cfg.d_ff if cfg.d_ff > 0 else int(cfg.d_model * cfg.mlstm_proj_factor)
    ff = (joint if joint and div(ff_width, data * model)
          else ("model" if div(ff_width, model) else None))
    mlstm_dh = ff_width // max(1, cfg.n_heads)
    mlstm_dh_axes = "model" if div(mlstm_dh, model) else None
    vocab = (joint if joint and div(cfg.padded_vocab, data * model)
             else ("model" if div(cfg.padded_vocab, model) else None))

    return ShardingPlan(
        batch_axes=batch_axes,
        fsdp_axes=fsdp,
        tp_axis="model" if model > 1 else None,
        heads_axes=heads,
        kv_heads_axes=kv_heads,
        kv_seq_axes=kv_seq,
        expert_axes=experts,
        expert_ff_axes=expert_ff,
        rnn_axes=rnn,
        ff_axes=ff,
        vocab_axes=vocab,
        mlstm_dh_axes=mlstm_dh_axes,
    )


# ---------------------------------------------------------------------------
# parameter specs (path-pattern based)
# ---------------------------------------------------------------------------

def _param_spec(path: str, shape: tuple[int, ...], plan: ShardingPlan,
                mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    f = plan.fsdp_axes
    t = plan.tp_axis
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fits(spec: P) -> P:
        """Drop axis assignments larger than the dimension (uneven sharding
        with padding is allowed and GSPMD-handled when dim >= axis size)."""
        out = []
        for dim, s in zip(shape, spec + (None,) * (len(shape) - len(spec))):
            if s is None:
                out.append(None)
                continue
            ax = (s,) if isinstance(s, str) else tuple(s)
            n = 1
            for a in ax:
                n *= sizes.get(a, 1)
            out.append(s if dim % n == 0 else None)
        return P(*out)

    stacked = path.startswith("groups/")
    def st(spec: P) -> P:
        return fits(P(None, *spec) if stacked else spec)

    p = path
    if re.search(r"embed$", p):
        return fits(P(plan.vocab_axes, f))
    if re.search(r"head/w$", p):
        return st(P(f, plan.vocab_axes))
    if re.search(r"frontend/w$", p):
        return fits(P(None, t))
    if re.search(r"attn/w[qkv]/w$", p):
        which = p[-4]
        ax = plan.heads_axes if which == "q" else plan.kv_heads_axes
        return st(P(f, ax))
    if re.search(r"attn/w[qkv]/b$", p):
        which = p[-4]
        ax = plan.heads_axes if which == "q" else plan.kv_heads_axes
        return st(P(ax))
    if re.search(r"attn/wo/w$", p):
        return st(P(plan.heads_axes, f))
    if re.search(r"mo e?/router/w$", p) or re.search(r"moe/router/w$", p):
        return st(P(f, None))
    if re.search(r"moe/w[ig]$", p):
        return st(P(plan.expert_axes, f, plan.expert_ff_axes))
    if re.search(r"moe/wo$", p):
        return st(P(plan.expert_axes, plan.expert_ff_axes, f))
    if re.search(r"(mlp|ffn)/w[ig]/w$", p):
        return st(P(f, plan.ff_axes))
    if re.search(r"(mlp|ffn)/wo/w$", p):
        return st(P(plan.ff_axes, f))
    if re.search(r"rec/(wx|wgate)/w$", p):
        return st(P(f, plan.rnn_axes))
    if re.search(r"rec/wo/w$", p):
        return st(P(plan.rnn_axes, f))
    if re.search(r"rec/conv$", p) or re.search(r"rec/gate_[ri]$", p):
        return st(P(None, plan.rnn_axes))
    if re.search(r"rec/lam$", p):
        return st(P(plan.rnn_axes))
    if re.search(r"cell/(up|up_gate)/w$", p):
        return st(P(f, plan.ff_axes))
    if re.search(r"cell/down/w$", p):
        return st(P(None, plan.mlstm_dh_axes, f))
    if re.search(r"cell/w[qkv]$", p):          # mLSTM per-head maps
        return st(P(None, f, None))
    if re.search(r"cell/wif/w$", p):
        return st(P(f, None))
    if re.search(r"cell/w/w$", p):             # sLSTM gate projection
        return st(P(f, plan.rnn_axes))
    if re.search(r"cell/r$", p):               # sLSTM diagonal recurrence
        return st(P(None, plan.rnn_axes))
    if re.search(r"cell/b$", p):
        return st(P(None))
    if re.search(r"cell/conv$", p):
        return st(P(None, None))
    # norms, scalars, biases: replicate
    return st(P())


def _tree_paths(tree: Any, prefix: str = "") -> Any:
    """Mirror pytree with 'a/b/c' path strings at the leaves."""
    if isinstance(tree, dict):
        return {k: _tree_paths(v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_tree_paths(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
        return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
    return prefix[:-1]


def param_shardings(params_shape: Any, plan: ShardingPlan, mesh: Mesh) -> Any:
    """NamedSharding pytree for a params (or optimizer-moment) pytree of
    ShapeDtypeStructs / arrays."""
    paths = _tree_paths(params_shape)

    def one(path: str, leaf) -> NamedSharding:
        # strip the leading container ("groups/", "rest/0/") for matching but
        # keep stacking awareness
        spec = _param_spec(_norm_path(path), leaf.shape, plan, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, paths, params_shape)


def _norm_path(path: str) -> str:
    # groups/bN/... keeps 'groups/' marker; rest/N/... drops it
    p = re.sub(r"^rest/\d+/", "", path)
    p = re.sub(r"^groups/b\d+/", "groups/", p)
    p = re.sub(r"/b\d+/", "/", p)
    return p


def opt_state_shardings(opt_shape: Any, params_plan: Any, mesh: Mesh,
                        plan: ShardingPlan) -> Any:
    """Moments shard exactly like their parameters; step is replicated."""
    m = param_shardings(opt_shape["m"], plan, mesh)
    v = param_shardings(opt_shape["v"], plan, mesh)
    return {"step": NamedSharding(mesh, P()), "m": m, "v": v}


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_shardings(batch_shape: Any, plan: ShardingPlan, mesh: Mesh) -> Any:
    b = plan.batch_axes

    def one(leaf):
        spec = [b] + [None] * (len(leaf.shape) - 1)
        if b is not None:
            n = 1
            for a in b:
                n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
            if leaf.shape[0] % n != 0:
                spec[0] = None
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: Any, plan: ShardingPlan, mesh: Mesh,
                    cfg: ModelConfig) -> Any:
    """KV caches: (R?, B, S, Hkv, D) -> batch + (kv_heads | kv_seq) sharding;
    recurrent states: (R?, B, ...) -> batch + channel sharding."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_fits(ax, dim):
        if ax is None:
            return None
        n = 1
        for a in ((ax,) if isinstance(ax, str) else ax):
            n *= sizes.get(a, 1)
        return ax if dim % n == 0 else None

    def one(path, leaf):
        shape = leaf.shape
        stacked = path.startswith("groups/")
        dims = list(shape[1:]) if stacked else list(shape)
        spec: list[Any] = []
        if len(dims) == 4:                       # (B, S, Hkv, D) attention
            spec = [axis_fits(plan.batch_axes, dims[0]),
                    axis_fits(plan.kv_seq_axes, dims[1]),
                    axis_fits(plan.kv_heads_axes, dims[2]), None]
        elif len(dims) >= 2:                     # recurrent states
            spec = [axis_fits(plan.batch_axes, dims[0])]
            spec += [None] * (len(dims) - 2)
            spec.append(axis_fits(plan.rnn_axes if plan.rnn_axes else None,
                                  dims[-1]))
        else:
            spec = [None] * len(dims)
        if stacked:
            spec = [None] + spec
        return NamedSharding(mesh, P(*spec))

    paths = _tree_paths(cache_shape)
    return jax.tree.map(one, paths, cache_shape)
