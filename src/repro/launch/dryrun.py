import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, SPMD-
partitions and compiles, and extract the roofline inputs.

For each cell:
    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis            -> fits + XLA's own counts
    trip-aware HLO analysis (core.hlo_counter) -> FLOPs / per-class bytes /
                                                  collective bytes

Results are cached as JSON under ``results/dryrun/`` — the roofline
benchmark and EXPERIMENTS.md read from there.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, cell_status
from repro.core import hlo as HLO
from repro.core import hlo_counter as HC
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import TrainConfig, build_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def default_train_config(cfg) -> TrainConfig:
    """Per-arch defaults: >=100B-parameter models keep AdamW moments in bf16
    (the optimizer-state memory trick; 314B grok would not fit f32 moments
    on 256 chips — memory math in EXPERIMENTS.md SDry-run)."""
    from repro.optim import OptimizerConfig
    if cfg.param_count() >= 1e11:
        return TrainConfig(optimizer=OptimizerConfig(state_dtype="bfloat16"))
    return TrainConfig()


def cell_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    suffix = f"-{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             tcfg: TrainConfig | None = None, tag: str = "",
             save: bool = True, keep_text: bool = False,
             cfg_overrides: dict | None = None) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if tcfg is None:
        tcfg = default_train_config(cfg)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = cell_status(cfg, shape)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "skipped", "reason": reason,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        if save:
            _save(record, arch, shape_name, mesh_name, tag)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        built = build_step(cfg, shape, mesh, tcfg)
        lowered = built.fn.lower(*built.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        text = compiled.as_text()
        mem = HLO.memory_analysis_stats(compiled)
        cost = HLO.cost_analysis_stats(compiled)
        hc = HC.analyze(text)

        tokens = shape.global_batch * (shape.seq_len if shape.kind in
                                       ("train", "prefill") else 1)
        record.update({
            "status": "ok",
            "reason": "",
            "chips": int(mesh.devices.size),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": mem,
            "xla_cost": cost,
            "hlo_flops_per_chip": hc.flops,
            "hlo_bytes_per_chip": hc.total_bytes,
            "bytes_by_class": dict(hc.bytes_by_class),
            "collective_operand_bytes": hc.collective_operand_bytes,
            "collective_wire_bytes": hc.collective_wire_bytes,
            "collective_by_kind": dict(hc.collective_by_kind),
            "n_collectives": hc.n_collectives,
            "tokens_per_step": tokens,
            "model_flops_global": cfg.model_flops(
                tokens, training=shape.kind == "train"),
            "kind": shape.kind,
            "warnings": hc.warnings[:10],
        })
        if keep_text:
            record["hlo_text"] = text
        # archive the compiled HLO so analyses can re-run offline
        if save:
            import gzip
            os.makedirs(RESULTS_DIR, exist_ok=True)
            gz = cell_path(arch, shape_name, mesh_name, tag)[:-5] + ".hlo.gz"
            with gzip.open(gz, "wt") as f:
                f.write(text)
    except Exception as e:  # noqa: BLE001 — record the failure, it's a bug
        record.update({"status": "failed",
                       "reason": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    if save:
        _save({k: v for k, v in record.items() if k != "hlo_text"},
              arch, shape_name, mesh_name, tag)
    return record


def _save(record: dict, arch: str, shape: str, mesh_name: str, tag: str) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(cell_path(arch, shape, mesh_name, tag), "w") as f:
        json.dump(record, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--kv-shard", default="auto")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (repeatable)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    def _parse(v: str):
        for cast in (int, float):
            try:
                return cast(v)
            except ValueError:
                pass
        if v in ("true", "false"):
            return v == "true"
        return v

    overrides = {k: _parse(v) for k, v in
                 (item.split("=", 1) for item in args.set)}

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = cell_path(arch, shape, mesh_name, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {arch} {shape} {mesh_name}")
                    continue
                cfg = get_config(arch)
                tcfg = default_train_config(cfg)
                if args.kv_shard != "auto" or args.grad_compression != "none":
                    import dataclasses as _dc
                    tcfg = _dc.replace(tcfg, kv_shard=args.kv_shard,
                                       grad_compression=args.grad_compression)
                rec = run_cell(arch, shape, multi_pod=mp, tcfg=tcfg,
                               tag=args.tag, cfg_overrides=overrides)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    ma = rec.get("memory_analysis") or {}
                    gb = ma.get("total_bytes", 0) / 1e9
                    extra = (f" mem/chip={gb:.2f}GB compile={rec['compile_s']}s "
                             f"flops/chip={rec['hlo_flops_per_chip']:.3g}")
                elif status == "failed":
                    extra = " " + rec["reason"][:160]
                print(f"[{status}] {arch} {shape} {mesh_name}{extra}", flush=True)


if __name__ == "__main__":
    main()
