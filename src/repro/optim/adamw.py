"""AdamW with global-norm clipping, warmup+cosine schedule, and configurable
moment dtype.

``state_dtype="bfloat16"`` halves optimizer-state HBM (the distributed-
optimization memory trick required to fit grok-1's 314 B parameters on a
256-chip pod — memory math in EXPERIMENTS.md SDry-run).  Moments are
dequantized to f32 for the update, so the math stays the standard AdamW.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"      # "bfloat16" halves m/v memory


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params: Any, cfg: OptimizerConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, state: dict, params: Any,
                 cfg: OptimizerConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mf.astype(dt), vf.astype(dt)

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
