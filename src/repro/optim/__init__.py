from repro.optim.adamw import (OptimizerConfig, adamw_init, adamw_update,
                               lr_schedule)
