"""``repro.workload`` — whole-model estimation.

The paper predicts one memory-bound kernel from its early-known memory
architecture; this package composes that prediction over an *entire
compiled model step*:

* :mod:`~repro.workload.walker` decomposes a module's trip-aware traffic
  into per-op :class:`OpRecord` s (the per-op view of
  ``hlo_counter.analyze``);
* :mod:`~repro.workload.compose` turns each op into a
  :class:`~repro.api.Design` (the validation harness's class -> LSU-group
  mapping), scores all ops in one batched Eqs. 1-10 pass, and sums —
  phase totals equal the sum of per-op estimates by construction;
* :mod:`~repro.workload.report` is the result family
  (:class:`ModelReport` / :class:`PhaseReport` / :class:`OpEstimate`);
* :mod:`~repro.workload.steps` lowers the shipped transformer stack's
  train / prefill / decode phases to HLO from shape structs alone
  (jax-lazy);
* :mod:`~repro.workload.sweep` makes model shape x sharding x hardware a
  streaming grid (:class:`ModelSweepPlan`, picklable + JSON).

Per the repo conventions the entry points live on :class:`repro.Session`
(``estimate_model`` / ``plan_model`` / ``sweep_model``) — this package is
the implementation.  Importing it does not import jax.
"""
from repro.workload.compose import (
    compose_model,
    compose_phase,
    designs_from_records,
)
from repro.workload.report import ModelReport, OpEstimate, PhaseReport
from repro.workload.sweep import MODEL_AXES, ModelSweepPlan, ModelSweepReport
from repro.workload.walker import OP_CLASSES, OpRecord, walk_module

__all__ = [
    "OpRecord", "walk_module", "OP_CLASSES",
    "OpEstimate", "PhaseReport", "ModelReport",
    "designs_from_records", "compose_phase", "compose_model",
    "MODEL_AXES", "ModelSweepPlan", "ModelSweepReport",
    "PHASES", "phase_callable", "phase_hlo", "param_bytes",
]


def __getattr__(name):
    # steps needs the model zoo (and therefore jax at call time); load it
    # only when one of its names is actually requested.
    if name in ("PHASES", "phase_callable", "phase_hlo", "param_bytes"):
        from repro.workload import steps

        return getattr(steps, name)
    raise AttributeError(f"module 'repro.workload' has no attribute {name!r}")
