"""Walk a compiled model step into per-op traffic records.

``hlo_counter.analyze`` answers "how many bytes does this module move, by
access class" with one aggregate :class:`HloCost`.  Whole-model estimation
needs the *per-op* decomposition of the same numbers: each materialized
instruction becomes one :class:`OpRecord` carrying its whole-step byte
totals (per-execution cost x loop trips), its FLOPs, and enough identity
(scope path, opcode, op class) to attribute time back to layers and op
families in the report.

The walk recurses through control flow exactly the way the aggregate
analyzer does — ``while`` bodies multiply by the recovered trip count,
``call``/``conditional`` recurse into callees — and charges every leaf via
the same ``Analyzer._instr_cost``, so the sum of all records equals
``analyze(text)`` (tested; equality is up to float summation order).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core import hlo_counter as _hc
from repro.core.hlo import COLLECTIVE_KINDS

__all__ = ["OpRecord", "walk_module", "OP_CLASSES"]

#: The op taxonomy the per-class breakdown reports over.
OP_CLASSES = ("matmul", "collective", "gather", "dynamic", "layout",
              "reduce", "fused", "elementwise", "other")


@dataclasses.dataclass(frozen=True)
class OpRecord:
    """One materialized instruction's whole-step cost.

    ``trips`` is the product of enclosing loop trip counts; every numeric
    field below is already multiplied by it (whole-step totals, not
    per-execution).  ``scope`` is the enclosing computation path — ops
    inside the layer scan share a scope, which is what the per-layer
    breakdown groups by.
    """

    path: str                 # scope + instruction name (unique per record)
    opcode: str
    op_class: str             # one of OP_CLASSES
    scope: str
    trips: float
    flops: float
    bytes_by_class: Mapping[str, float]
    transcendentals: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    n_collectives: float = 0.0

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_class.values()))

    @property
    def name(self) -> str:
        return self.path.rsplit("/", 1)[-1]


def _op_class(an: _hc.Analyzer, ins: _hc.Instr) -> str:
    op = ins.opcode
    base = op[:-6] if op.endswith("-start") else op
    if base in COLLECTIVE_KINDS:
        return "collective"
    if op in ("dot", "convolution"):
        return "matmul"
    if op == "fusion":
        callee = _hc._called(ins.rest, "calls") or ""
        comp = an.comps.get(callee)
        if comp is not None and any(
                i.opcode in ("dot", "convolution") for i in comp.instrs):
            return "matmul"
        return {"gather": "gather", "strided": "layout",
                "stream": "fused"}[an._fusion_class(callee)]
    if op in _hc._CLASS_GATHER:
        return "gather"
    if op in ("dynamic-slice", "dynamic-update-slice"):
        return "dynamic"
    if op in ("reduce", "reduce-window"):
        return "reduce"
    if op in _hc._CLASS_STRIDED:
        return "layout"
    if op in _hc._ELEMENTWISE_FLOPS:
        return "elementwise"
    return "other"


def _walk_comp(an: _hc.Analyzer, comp: _hc.Computation, mult: float,
               path: str, out: list[OpRecord]) -> None:
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            body = an.comps.get(_hc._called(ins.rest, "body") or "")
            cond = an.comps.get(_hc._called(ins.rest, "condition") or "")
            trips = _hc._while_trips(cond) if cond else 1
            sub = f"{path}/{ins.name}"
            if body is not None:
                _walk_comp(an, body, mult * trips, sub, out)
            if cond is not None:
                _walk_comp(an, cond, mult * trips, sub + ".cond", out)
            continue
        if op in ("call", "conditional"):
            for key in ("to_apply", "true_computation",
                        "false_computation", "branch_computations"):
                callee = _hc._called(ins.rest, key)
                if callee and callee in an.comps:
                    _walk_comp(an, an.comps[callee], mult,
                               f"{path}/{ins.name}", out)
            continue
        cost = an._instr_cost(ins, comp)
        if not (cost.flops or cost.bytes_by_class or cost.n_collectives
                or cost.transcendentals):
            continue
        scaled = cost.scaled(mult)
        out.append(OpRecord(
            path=f"{path}/{ins.name}", opcode=op,
            op_class=_op_class(an, ins), scope=path, trips=mult,
            flops=scaled.flops, bytes_by_class=dict(scaled.bytes_by_class),
            transcendentals=scaled.transcendentals,
            collective_operand_bytes=scaled.collective_operand_bytes,
            collective_wire_bytes=scaled.collective_wire_bytes,
            n_collectives=scaled.n_collectives))


def walk_module(hlo_text: str, *, fused: bool = True) -> list[OpRecord]:
    """Per-op records for one compiled module (entry computation walk).

    A degenerate module (no parseable ENTRY — e.g. a fully constant-folded
    decode step) yields an empty list, mirroring the hardened
    ``Analyzer.entry_cost``.
    """
    an = _hc.Analyzer(hlo_text, fused=fused)
    entry = an.entry_comp()
    records: list[OpRecord] = []
    if entry is not None:
        _walk_comp(an, entry, 1.0, entry.name, records)
    return records
