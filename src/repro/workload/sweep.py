"""Model shape x sharding x hardware sweeps through the streaming engine.

A :class:`ModelSweepPlan` is the whole-model analogue of
:class:`repro.core.stream.SweepPlan`: a frozen, picklable, JSON-able
description of one grid over the axes

    ``phase`` x ``batch`` x ``seq_len`` x ``shards`` x ``hardware``

Each distinct ``(phase, batch, seq_len)`` combination is compiled and
walked **once at plan-build time** (the expensive jax lowering); what the
plan stores is pure data — per-op access-class byte totals and FLOPs — so
``evaluator()`` rebuilds the chunk-scoring function anywhere without jax
or the model code.  Every chunk scores all ops of all its points in one
``GroupBatch`` pass and aggregates per point with ``np.bincount``, whose
per-point accumulation order depends only on the point's own op order —
the property that makes streaming folds bit-equal to one materialized
pass (tested).

First-order sharding model (documented, not silently assumed): ``shards``
divides every op's per-device traffic (batch-dimension data parallelism),
and a ``train`` phase with ``shards > 1`` gains one synthetic stream-class
op of ``2 (s-1)/s * param_bytes`` — the per-device DRAM traffic of a ring
gradient all-reduce.  Replicated-weight reads are *also* divided, which
understates small-batch decode traffic; refine when a sharded-layout
walker lands.

Aggregate column definitions (per point): ``t_exe``/``t_ideal``/``t_ovh``
/``total_bytes``/``n_lsu`` are sums over the point's ops; ``bound_ratio``
is the time-weighted mean of per-op ratios; ``memory_bound`` is true when
ops that are individually memory-bound account for more than half of
``t_exe``; ``resource`` is the *peak* per-op LSU interconnect width — the
widest simultaneously-live crossbar the composed schedule needs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

from repro import hw as _hw
from repro.core import model as _model
from repro.core import model_batch as _mb
from repro.core import stream as _stream
from repro.core import validate as _validate
from repro.core.fpga import BspParams, DramParams

__all__ = ["MODEL_AXES", "ModelSweepPlan", "ModelSweepReport"]

MODEL_AXES = ("phase", "batch", "seq_len", "shards", "hardware")

_PLAN_BACKENDS = ("scalar", "numpy-batch", "jax-jit")

#: Columns every model-sweep evaluator emits (reducer contract).
MODEL_COLUMNS = (("id",) + MODEL_AXES + _stream.ESTIMATE_COLUMNS
                 + ("resource",))


def _combo_key(phase: str, batch: int, seq_len: int) -> str:
    return f"{phase}|{batch}|{seq_len}"


@dataclasses.dataclass(frozen=True)
class ModelSweepPlan:
    """Frozen data-only description of one whole-model sweep.

    ``tables`` maps ``"phase|batch|seq_len"`` to the walked op list of that
    compiled step: each op is ``{"classes": {access class: bytes},
    "flops": float}`` (whole-step totals).  ``dram``/``bsp`` and
    ``calibration_factor`` are the session context captured at build time,
    used for every point whose ``hardware`` axis value is ``None``; a
    point with its own :class:`~repro.hw.Hardware` scores against that
    spec's params and host factor instead (same semantics as the kernel
    sweep's hardware axis).

    Build with ``Session.plan_model(...)``, not by hand.
    """

    model: str
    lists: Mapping[str, Sequence]
    tables: Mapping[str, tuple]
    param_bytes: float
    dram: DramParams
    bsp: BspParams
    backend: str = "numpy-batch"
    calibration_factor: float = 1.0
    chunk_size: int = 256
    access_bytes: int = _validate.ACCESS_BYTES

    def __post_init__(self):
        if self.backend not in _PLAN_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}: pick one "
                             f"of {_PLAN_BACKENDS}")
        if self.chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        missing = [a for a in MODEL_AXES if a not in self.lists]
        if missing:
            raise ValueError(f"plan lists must cover every model axis; "
                             f"missing {missing}")
        lists = {
            "phase": tuple(str(p) for p in self.lists["phase"]),
            "batch": tuple(int(b) for b in self.lists["batch"]),
            "seq_len": tuple(int(s) for s in self.lists["seq_len"]),
            "shards": tuple(int(s) for s in self.lists["shards"]),
            "hardware": tuple(_hw.resolve(h)
                              for h in self.lists["hardware"]),
        }
        if any(s < 1 for s in lists["shards"]):
            raise ValueError("shards must be >= 1")
        object.__setattr__(self, "lists", lists)
        object.__setattr__(
            self, "tables",
            {k: tuple({"classes": dict(op["classes"]),
                       "flops": float(op.get("flops", 0.0))} for op in ops)
             for k, ops in dict(self.tables).items()})
        missing_combos = [
            _combo_key(p, b, s)
            for p in lists["phase"] for b in lists["batch"]
            for s in lists["seq_len"]
            if _combo_key(p, b, s) not in self.tables]
        if missing_combos:
            raise ValueError(f"tables missing walked combos "
                             f"{missing_combos[:4]}...")

    # -- geometry -----------------------------------------------------------

    def enumerator(self) -> _stream.GridEnumerator:
        return _stream.GridEnumerator(
            {a: list(self.lists[a]) for a in MODEL_AXES})

    @property
    def n(self) -> int:
        return self.enumerator().n

    # -- evaluation ---------------------------------------------------------

    def _point_kernels(self, phase: str, batch: int, seq_len: int,
                       shards: int):
        """(LSU lists, per-op resource widths) for one grid combo."""
        ops = [dict(op["classes"])
               for op in self.tables[_combo_key(phase, batch, seq_len)]]
        if shards > 1:
            ops = [{k: v / shards for k, v in cl.items()} for cl in ops]
            if phase == "train" and self.param_bytes > 0:
                ops.append({"stream":
                            2.0 * (shards - 1) / shards * self.param_bytes})
        kernels, widths = [], []
        for cl in ops:
            lsus = _validate.lsus_from_classes(
                cl, access_bytes=self.access_bytes)
            kernels.append(lsus)
            widths.append(float(sum(l.ls_width for l in lsus
                                    if l.lsu_type.is_global)))
        return kernels, widths

    def evaluator(self) -> Callable[[np.ndarray], dict[str, np.ndarray]]:
        """Chunk-scoring function over point ids (reducer-ready columns).

        Per-point aggregation is chunk-shape independent, so any chunking
        of the id range folds to bit-identical per-point values.
        """
        enum = self.enumerator()
        lists = self.lists
        backend = self.backend
        hw_ctx = []           # hardware code -> (dram, bsp, calibration)
        for h in lists["hardware"]:
            if h is None:
                hw_ctx.append((self.dram, self.bsp,
                               float(self.calibration_factor)))
            else:
                hw_ctx.append((h.dram_params(), h.bsp_params(),
                               float(h.host_factor)))

        kernel_cache: dict[tuple, tuple] = {}

        def combo(pc: int, bc: int, sc: int, shc: int):
            key = (pc, bc, sc, shc)
            hit = kernel_cache.get(key)
            if hit is None:
                hit = self._point_kernels(
                    lists["phase"][pc], lists["batch"][bc],
                    lists["seq_len"][sc], lists["shards"][shc])
                kernel_cache[key] = hit
            return hit

        if backend == "jax-jit":
            from repro import api as _api
            estimator = _api._jax_estimate_batch
        else:
            estimator = _mb.estimate_batch

        def eval_chunk(ids: np.ndarray) -> dict[str, np.ndarray]:
            ids = np.asarray(ids, dtype=np.int64)
            m = len(ids)
            codes = enum.codes(ids)
            pc, bc, sc = codes["phase"], codes["batch"], codes["seq_len"]
            shc, hc = codes["shards"], codes["hardware"]
            flat, point_of, widths, drams, bsps = [], [], [], [], []
            cal = np.ones(m, dtype=np.float64)
            resource = np.zeros(m, dtype=np.float64)
            for i in range(m):
                kernels, w = combo(int(pc[i]), int(bc[i]), int(sc[i]),
                                   int(shc[i]))
                dram, bsp, c = hw_ctx[int(hc[i])]
                cal[i] = c
                for lsus, width in zip(kernels, w):
                    flat.append(lsus)
                    point_of.append(i)
                    drams.append(dram)
                    bsps.append(bsp)
                if w:
                    resource[i] = max(w)
            point_of = np.asarray(point_of, dtype=np.int64)

            if len(flat):
                if backend == "scalar":
                    ests = [_model._estimate(list(l), d, b)
                            for l, d, b in zip(flat, drams, bsps)]
                    t_exe_k = np.asarray([e.t_exe for e in ests])
                    t_ideal_k = np.asarray([e.t_ideal for e in ests])
                    t_ovh_k = np.asarray([e.t_ovh for e in ests])
                    ratio_k = np.asarray([e.bound_ratio for e in ests])
                    mb_k = np.asarray([e.memory_bound for e in ests],
                                      dtype=np.float64)
                    bytes_k = np.asarray([float(e.total_bytes)
                                          for e in ests])
                    nlsu_k = np.asarray([len(e.per_lsu) for e in ests],
                                        dtype=np.float64)
                else:
                    est = estimator(_mb.GroupBatch.from_kernels(
                        flat, drams, bsps))
                    t_exe_k = np.asarray(est.t_exe, dtype=np.float64)
                    t_ideal_k = np.asarray(est.t_ideal, dtype=np.float64)
                    t_ovh_k = np.asarray(est.t_ovh, dtype=np.float64)
                    ratio_k = np.asarray(est.bound_ratio, dtype=np.float64)
                    mb_k = np.asarray(est.memory_bound, dtype=np.float64)
                    bytes_k = np.asarray(est.total_bytes, dtype=np.float64)
                    nlsu_k = np.asarray(est.n_lsu, dtype=np.float64)
            else:
                t_exe_k = t_ideal_k = t_ovh_k = ratio_k = mb_k = bytes_k \
                    = nlsu_k = np.empty(0, dtype=np.float64)

            def per_point(w):
                return np.bincount(point_of, weights=w, minlength=m)

            t_exe = per_point(t_exe_k)
            with np.errstate(invalid="ignore", divide="ignore"):
                bound_ratio = np.where(
                    t_exe > 0, per_point(t_exe_k * ratio_k)
                    / np.where(t_exe > 0, t_exe, 1.0), 0.0)
            memory_bound = per_point(t_exe_k * mb_k) > 0.5 * t_exe
            cols: dict[str, np.ndarray] = {
                "id": ids,
                "phase": np.asarray(pc, dtype=np.int64),
                "batch": np.asarray(lists["batch"])[bc],
                "seq_len": np.asarray(lists["seq_len"])[sc],
                "shards": np.asarray(lists["shards"])[shc],
                "hardware": np.asarray(hc, dtype=np.int64),
                "t_exe": t_exe * cal,
                "t_ideal": per_point(t_ideal_k) * cal,
                "t_ovh": per_point(t_ovh_k) * cal,
                "bound_ratio": bound_ratio,
                "memory_bound": memory_bound,
                "total_bytes": per_point(bytes_k),
                "n_lsu": per_point(nlsu_k).astype(np.int64),
                "resource": resource,
            }
            return cols

        return eval_chunk

    def run(self, reducers: Iterable[_stream.Reducer], *,
            workers: int | None = None) -> _stream.StreamOutcome:
        """Stream the whole grid into ``reducers`` (chunked fold)."""
        return _stream.run_stream(self.n, self.chunk_size,
                                  self.evaluator(), reducers,
                                  workers=workers)

    def materialize(self) -> dict[str, np.ndarray]:
        """All columns of the whole grid in one pass (no reducers)."""
        ids = np.arange(self.n, dtype=np.int64)
        return self.evaluator()(ids)

    # -- serialization ------------------------------------------------------

    def to_json(self) -> str:
        out = {
            "version": 1,
            "model": self.model,
            "backend": self.backend,
            "calibration_factor": self.calibration_factor,
            "chunk_size": self.chunk_size,
            "access_bytes": self.access_bytes,
            "param_bytes": self.param_bytes,
            "dram": _stream.axis_value_to_json(self.dram),
            "bsp": _stream.axis_value_to_json(self.bsp),
            "lists": {a: [_stream.axis_value_to_json(v)
                          for v in self.lists[a]] for a in MODEL_AXES},
            "tables": {k: [{"classes": dict(op["classes"]),
                            "flops": op["flops"]} for op in ops]
                       for k, ops in self.tables.items()},
        }
        return json.dumps(out, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ModelSweepPlan":
        d = json.loads(text)
        return cls(
            model=d["model"],
            lists={a: [_stream.axis_value_from_json(v)
                       for v in d["lists"][a]] for a in MODEL_AXES},
            tables={k: tuple(ops) for k, ops in d["tables"].items()},
            param_bytes=float(d["param_bytes"]),
            dram=_stream.axis_value_from_json(d["dram"]),
            bsp=_stream.axis_value_from_json(d["bsp"]),
            backend=d["backend"],
            calibration_factor=float(d["calibration_factor"]),
            chunk_size=int(d["chunk_size"]),
            access_bytes=int(d["access_bytes"]))


class ModelSweepReport:
    """Swept model grid as a Report (materialized or reducer-backed).

    ``cols`` holds the full grid's columns on a materialized run, or the
    survivors (Pareto front + top-k, deduplicated, ascending id) on a
    streaming run; ``stats`` is the exact whole-grid summary either way.
    """

    kind = "model-sweep"

    def __init__(self, plan: ModelSweepPlan, cols: Mapping[str, np.ndarray],
                 *, n_total: int, stats: Mapping | None,
                 streaming: bool, reducers: tuple = ()):
        self.plan = plan
        self.cols = {k: np.asarray(v) for k, v in cols.items()}
        self.n_total = int(n_total)
        self.stats = dict(stats) if stats else None
        self.streaming = bool(streaming)
        self.reducers = reducers
        self.backend = plan.backend

    @property
    def n_points(self) -> int:
        return self.n_total

    def __len__(self) -> int:
        return len(self.cols["id"])

    def _decode_row(self, i: int) -> dict:
        lists = self.plan.lists
        h = lists["hardware"][int(self.cols["hardware"][i])]
        row = {
            "id": int(self.cols["id"][i]),
            "phase": lists["phase"][int(self.cols["phase"][i])],
            "batch": int(self.cols["batch"][i]),
            "seq_len": int(self.cols["seq_len"][i]),
            "shards": int(self.cols["shards"][i]),
            "hardware": h.name if h is not None else self.plan.dram.name,
        }
        for name in _stream.ESTIMATE_COLUMNS + ("resource",):
            v = self.cols[name][i]
            row[name] = (bool(v) if name == "memory_bound"
                         else int(v) if name == "n_lsu" else float(v))
        return row

    def rows(self) -> list[dict]:
        return [self._decode_row(i) for i in range(len(self))]

    def to_csv(self) -> str:
        from repro.api import Report

        return Report.to_csv(self)

    def top_k(self, k: int = 10, key: str = "t_exe") -> list[dict]:
        """The k held rows with the smallest ``key`` (ascending, ties by
        ascending id — the TopKReducer convention)."""
        order = np.lexsort((self.cols["id"], self.cols[key]))
        return [self._decode_row(int(i)) for i in order[:k]]

    def best(self, key: str = "t_exe") -> dict:
        if not len(self):
            raise ValueError("empty sweep (no points held)")
        return self.top_k(1, key)[0]

    def summary(self) -> dict:
        out = {"kind": self.kind, "model": self.plan.model,
               "backend": self.backend, "n_points": self.n_total,
               "held": len(self), "streaming": self.streaming}
        if self.stats:
            out["stats"] = self.stats
        if len(self):
            out["best"] = self.best()
        return out
