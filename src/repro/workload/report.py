"""Whole-model result family: per-op estimates composed into phase and
model reports.

The contract that makes composition auditable: a phase's ``t_memory`` is
*defined* as the plain sum of its per-op ``Estimate.t_exe`` values, in op
order — so ``ModelReport`` totals always equal the sum of the per-op
``Session.estimate`` calls that produced them (the acceptance invariant,
tested on all three backends).  Compute and collective terms are reported
alongside as roofline context, never silently folded into the total.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.api import Design, Estimate, Report
from repro.workload.walker import OP_CLASSES, OpRecord

__all__ = ["OpEstimate", "PhaseReport", "ModelReport"]


@dataclasses.dataclass(frozen=True)
class OpEstimate:
    """One op's record, the Design built from it, and its scored Estimate."""

    record: OpRecord
    design: Design
    estimate: Estimate

    @property
    def t_exe(self) -> float:
        return self.estimate.t_exe


@dataclasses.dataclass(frozen=True)
class PhaseReport(Report):
    """One phase (train / prefill / decode / ...) of a walked model.

    ``ops`` holds only ops with DRAM traffic (each scored through Eqs.
    1-10); ``n_flops_only`` counts the fusion-internal ops whose FLOPs
    entered ``t_compute`` without a memory estimate.  Times are seconds.
    """

    name: str
    ops: tuple[OpEstimate, ...]
    n_flops_only: int
    flops: float
    transcendentals: float
    bytes_by_class: Mapping[str, float]
    t_memory: float               # sum of per-op t_exe — the phase total
    t_compute: float              # flops / peak_flops roofline floor
    t_collective: float
    collective_wire_bytes: float
    n_collectives: float
    backend: str
    peak_bandwidth: float         # session DRAM bandwidth [B/s]
    kind = "phase"

    @property
    def n_ops(self) -> int:
        return len(self.ops) + self.n_flops_only

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_class.values()))

    @property
    def t_total(self) -> float:
        """Phase latency under the memory model — exactly
        ``sum(op.t_exe for op in ops)``."""
        return self.t_memory

    @property
    def t_roofline(self) -> float:
        """Latency if memory, compute and interconnect overlap perfectly."""
        return max(self.t_memory, self.t_compute, self.t_collective)

    @property
    def bottleneck(self) -> str:
        t = {"memory": self.t_memory, "compute": self.t_compute,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def arithmetic_intensity(self) -> float:
        if not self.total_bytes:
            return math.inf if self.flops else 0.0
        return self.flops / self.total_bytes

    def by_class(self) -> list[dict]:
        """Per-op-class breakdown (time share, bytes, op count)."""
        acc: dict[str, dict] = {}
        for op in self.ops:
            d = acc.setdefault(op.record.op_class,
                               {"op_class": op.record.op_class, "n_ops": 0,
                                "bytes": 0.0, "t_exe": 0.0})
            d["n_ops"] += 1
            d["bytes"] += op.record.total_bytes
            d["t_exe"] += op.t_exe
        order = {c: i for i, c in enumerate(OP_CLASSES)}
        out = sorted(acc.values(), key=lambda d: order.get(d["op_class"], 99))
        for d in out:
            d["share"] = d["t_exe"] / self.t_memory if self.t_memory else 0.0
        return out

    def by_layer(self) -> list[dict]:
        """Per-scope breakdown: the layer scan shows up as one scope whose
        ``trips`` is the layer count, with per-trip time alongside."""
        acc: dict[str, dict] = {}
        for op in self.ops:
            d = acc.setdefault(op.record.scope,
                               {"scope": op.record.scope,
                                "trips": op.record.trips,
                                "n_ops": 0, "bytes": 0.0, "t_exe": 0.0})
            d["n_ops"] += 1
            d["bytes"] += op.record.total_bytes
            d["t_exe"] += op.t_exe
        out = sorted(acc.values(), key=lambda d: -d["t_exe"])
        for d in out:
            d["t_per_trip"] = d["t_exe"] / d["trips"] if d["trips"] else 0.0
        return out

    def rows(self) -> list[dict]:
        t_total = self.t_memory
        return [{
            "phase": self.name,
            "op": op.record.name,
            "op_class": op.record.op_class,
            "scope": op.record.scope,
            "trips": op.record.trips,
            "total_bytes": op.record.total_bytes,
            "flops": op.record.flops,
            "t_exe_us": op.t_exe * 1e6,
            "share": op.t_exe / t_total if t_total else 0.0,
            "memory_bound": bool(op.estimate.memory_bound),
            "backend": self.backend,
        } for op in sorted(self.ops, key=lambda o: -o.t_exe)]

    def summary(self) -> dict:
        return {
            "kind": self.kind, "phase": self.name, "backend": self.backend,
            "n_ops": self.n_ops, "n_scored": len(self.ops),
            "t_total_ms": self.t_total * 1e3,
            "t_compute_ms": self.t_compute * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "bottleneck": self.bottleneck,
            "total_bytes": self.total_bytes, "flops": self.flops,
            "arithmetic_intensity": self.arithmetic_intensity,
            "by_class": self.by_class(),
        }


@dataclasses.dataclass(frozen=True)
class ModelReport(Report):
    """End-to-end estimate of a walked model: one PhaseReport per compiled
    step, plus the aggregate roofline position.

    ``total_latency()`` (and each phase's ``t_total``) is the sum of the
    per-op Eqs. 1-10 estimates — the number the acceptance test compares
    against per-op ``Session.estimate`` calls.
    """

    name: str
    phases: tuple[PhaseReport, ...]
    backend: str
    hardware: str
    access_bytes: int
    ridge_intensity: float        # peak_flops / peak_bandwidth [flop/B]
    kind = "model"

    def phase(self, name: str) -> PhaseReport:
        for p in self.phases:
            if p.name == name:
                return p
        raise KeyError(f"no phase {name!r}; have "
                       f"{[p.name for p in self.phases]}")

    @property
    def phase_names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.phases)

    def total_latency(self, phase: str | None = None) -> float:
        """Summed memory-model latency [s] of one phase (or all phases)."""
        if phase is not None:
            return self.phase(phase).t_total
        return float(sum(p.t_total for p in self.phases))

    @property
    def flops(self) -> float:
        return float(sum(p.flops for p in self.phases))

    @property
    def total_bytes(self) -> float:
        return float(sum(p.total_bytes for p in self.phases))

    @property
    def arithmetic_intensity(self) -> float:
        if not self.total_bytes:
            return math.inf if self.flops else 0.0
        return self.flops / self.total_bytes

    @property
    def memory_bound(self) -> bool:
        """Aggregate roofline position: left of the ridge point."""
        return self.arithmetic_intensity < self.ridge_intensity

    def split(self) -> dict[str, float]:
        """Each phase's share of the summed latency (prefill-vs-decode
        split when those phases were walked)."""
        total = self.total_latency()
        return {p.name: (p.t_total / total if total else 0.0)
                for p in self.phases}

    def rows(self) -> list[dict]:
        return [r for p in self.phases for r in p.rows()]

    def summary(self) -> dict:
        return {
            "kind": self.kind, "model": self.name, "backend": self.backend,
            "hardware": self.hardware,
            "t_total_ms": self.total_latency() * 1e3,
            "split": self.split(),
            "arithmetic_intensity": self.arithmetic_intensity,
            "ridge_intensity": self.ridge_intensity,
            "memory_bound": self.memory_bound,
            "phases": {p.name: p.summary() for p in self.phases},
        }


def op_table(phase: PhaseReport, top: int = 12) -> str:
    """Readable per-class table for examples/README (not part of the API
    surface promise; formatting only)."""
    lines = [f"phase={phase.name}  t_total={phase.t_total * 1e3:.3f} ms  "
             f"bottleneck={phase.bottleneck}",
             f"{'op class':<12} {'ops':>4} {'MiB':>10} "
             f"{'t [us]':>10} {'share':>7}"]
    for d in phase.by_class()[:top]:
        lines.append(f"{d['op_class']:<12} {d['n_ops']:>4} "
                     f"{d['bytes'] / 2**20:>10.2f} "
                     f"{d['t_exe'] * 1e6:>10.1f} {d['share']:>6.1%}")
    return "\n".join(lines)
