"""Lower model phases (train / prefill / decode) to compiled HLO text.

The builders here are the shape-only analogue of ``launch.steps``: every
array is a ``jax.ShapeDtypeStruct`` from ``jax.eval_shape`` — no
parameters are ever materialized, no mesh is required — and the phase
callable is lowered + compiled on CPU, exactly the artifact
``Design.from_kernel`` reads for a single kernel.  Requires jax (imported
lazily so ``import repro.workload`` stays jax-free).
"""
from __future__ import annotations

from typing import Any, Callable

__all__ = ["PHASES", "phase_callable", "phase_hlo", "param_bytes"]

PHASES = ("train", "prefill", "decode")


def _check_cfg(cfg) -> None:
    if getattr(cfg, "frontend", None):
        raise ValueError(
            f"workload.steps lowers token-frontend models only; "
            f"{cfg.name!r} has frontend={cfg.frontend!r} (build the phase "
            f"callable yourself and pass it to Session.estimate_model)")


def _shape_params(cfg):
    import jax

    from repro.models import transformer as TF

    return jax.eval_shape(
        lambda: TF.init_params(jax.random.PRNGKey(0), cfg))


def phase_callable(cfg, phase: str, *, batch: int, seq_len: int,
                   ) -> tuple[Callable, tuple[Any, ...]]:
    """(fn, example_args) for one phase of the shipped transformer stack.

    ``train`` is loss + grads (``value_and_grad`` over ``loss_fn``),
    ``prefill`` runs the stack over the full prompt and keeps the last
    position's logits, ``decode`` is one cached decoding step.
    """
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as TF

    _check_cfg(cfg)
    params = _shape_params(cfg)
    tok = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)

    if phase == "train":
        def fn(params, tokens, labels):
            (loss, _), grads = jax.value_and_grad(
                TF.loss_fn, has_aux=True)(
                    params, cfg, {"tokens": tokens, "labels": labels})
            return loss, grads
        return fn, (params, tok, tok)

    if phase == "prefill":
        def fn(params, tokens):
            x = TF.embed_inputs(params, cfg, tokens=tokens)
            h, _ = TF.forward_hidden(params, cfg, x)
            return TF.logits_fn(params, cfg, h[:, -1:, :])
        return fn, (params, tok)

    if phase == "decode":
        caches = jax.eval_shape(
            lambda: TF.init_caches(cfg, batch, seq_len))

        def fn(params, tokens, caches, index):
            return TF.decode_step(params, cfg, tokens, caches, index)
        return fn, (params, jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                    caches, jax.ShapeDtypeStruct((), jnp.int32))

    raise ValueError(f"unknown phase {phase!r}; pick one of {PHASES}")


def phase_hlo(cfg, phase: str, *, batch: int, seq_len: int) -> str:
    """Compiled HLO text of one phase (lower + compile on this host)."""
    import jax

    fn, args = phase_callable(cfg, phase, batch=batch, seq_len=seq_len)
    return jax.jit(fn).lower(*args).compile().as_text()


def param_bytes(cfg) -> float:
    """Total parameter bytes (from shape structs — nothing materialized).
    Feeds the data-parallel gradient all-reduce term of the sharding
    axis in :mod:`repro.workload.sweep`."""
    import jax

    leaves = jax.tree_util.tree_leaves(_shape_params(cfg))
    return float(sum(l.size * l.dtype.itemsize for l in leaves))
