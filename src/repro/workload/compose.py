"""Compose walked op records into scored phase reports.

Each op with DRAM traffic becomes one :class:`repro.api.Design` via the
same class -> LSU-group mapping the validation harness uses
(``Design.from_classes``), all ops of a phase are scored in **one**
``Session.estimate_many`` batched pass (so the jax-jit backend compiles a
single batch, not one program per op), and the phase total is the plain
sum of the per-op times — by construction equal to summing individual
``Session.estimate`` calls.

FLOPs-only ops (fusion-internal compute with no materialized traffic)
carry no memory estimate; their FLOPs still enter the phase's
``t_compute`` roofline floor, and they are counted in ``n_flops_only``.
"""
from __future__ import annotations

from typing import Sequence

from repro.api import Design, Session
from repro.workload.report import ModelReport, OpEstimate, PhaseReport
from repro.workload.walker import OpRecord

__all__ = ["designs_from_records", "compose_phase", "compose_model"]


def designs_from_records(
        records: Sequence[OpRecord], *,
        access_bytes: int | None = None,
) -> tuple[list[tuple[OpRecord, Design]], list[OpRecord]]:
    """(record, Design) pairs for every op with traffic, plus the
    flops-only leftovers.  Collective-only ops never become designs —
    their cost is interconnect, not DRAM."""
    pairs: list[tuple[OpRecord, Design]] = []
    rest: list[OpRecord] = []
    for r in records:
        if r.total_bytes > 0:
            d = Design.from_classes(r.bytes_by_class,
                                    access_bytes=access_bytes,
                                    flops=r.flops, name=r.path)
            pairs.append((r, d))
        else:
            rest.append(r)
    return pairs, rest


def compose_phase(session: Session, name: str,
                  records: Sequence[OpRecord], *,
                  access_bytes: int | None = None) -> PhaseReport:
    """Score one phase's records on the session's backend and hardware."""
    pairs, rest = designs_from_records(records, access_bytes=access_bytes)
    estimates = session.estimate_many([d for _, d in pairs])
    ops = tuple(OpEstimate(record=r, design=d, estimate=e)
                for (r, d), e in zip(pairs, estimates))

    bytes_by_class: dict[str, float] = {}
    for r, _ in pairs:
        for cls, b in r.bytes_by_class.items():
            bytes_by_class[cls] = bytes_by_class.get(cls, 0.0) + b
    flops = sum(r.flops for r in records)
    trans = sum(r.transcendentals for r in records)
    wire = sum(r.collective_wire_bytes for r in records)
    n_coll = sum(r.n_collectives for r in records)

    hw = session.hw
    t_collective = (wire / (hw.ici_bw * hw.ici_links)
                    + n_coll * hw.ici_hop_latency) if n_coll else 0.0
    return PhaseReport(
        name=name, ops=ops, n_flops_only=len(rest),
        flops=float(flops), transcendentals=float(trans),
        bytes_by_class=bytes_by_class,
        t_memory=float(sum(op.t_exe for op in ops)),
        t_compute=float(flops) / hw.peak_flops,
        t_collective=float(t_collective),
        collective_wire_bytes=float(wire), n_collectives=float(n_coll),
        backend=session.backend,
        peak_bandwidth=float(session.dram.bw_mem))


def compose_model(session: Session, name: str,
                  phase_records: dict[str, Sequence[OpRecord]], *,
                  access_bytes: int | None = None) -> ModelReport:
    """All phases of one model, each composed on the same session."""
    from repro.core import validate as _validate

    phases = tuple(compose_phase(session, pname, recs,
                                 access_bytes=access_bytes)
                   for pname, recs in phase_records.items())
    hw_name = (session.hardware.name if session.hardware is not None
               else session.dram.name)
    return ModelReport(
        name=name, phases=phases, backend=session.backend,
        hardware=hw_name,
        access_bytes=access_bytes or _validate.ACCESS_BYTES,
        ridge_intensity=session.hw.peak_flops / session.hw.hbm_bw)
