"""``repro.hw`` — the pluggable hardware-spec layer.

One serializable description of a memory system (:class:`Hardware`,
composing :class:`MemorySystem` + :class:`DramOrganization` +
:class:`ClockDomain`) behind a named registry:

    >>> from repro import hw
    >>> board = hw.get("stratix10_ddr4_1866")       # preset lookup
    >>> sess = repro.Session().with_hardware(board) # evaluate against it
    >>> hw.register(board.with_efficiencies(k_gather=0.5).with_name("mine"))
    >>> spec = hw.Hardware.from_json(saved)         # persisted calibration

Presets: ``tpu_v5e``, ``tpu_v4``, ``stratix10_ddr4_1866``,
``stratix10_ddr4_2666`` (see :mod:`repro.hw.presets`).  The pre-0.4
module constants (``repro.core.fpga.DDR4_1866``/``STRATIX10_BSP``,
``repro.core.hbm.TPU_V5E``) are removed; these entries are their only
home (the curated ``repro``/``repro.core`` re-exports are built from
them).
"""
from repro.hw.registry import get, names, register, unregister
from repro.hw.spec import (
    SCHEMA_VERSION,
    ClockDomain,
    DramOrganization,
    Hardware,
    MemorySystem,
    enable_jax,
)
from repro.hw import presets  # populates the registry
from repro.hw.presets import DEFAULT_BOARD, DEFAULT_CHIP


def resolve(spec: "Hardware | str | None") -> "Hardware | None":
    """One place axis values become Hardware: a spec passes through, a
    string looks up the registry, ``None`` stays ``None`` (meaning "the
    session's own hardware" wherever an axis admits a default)."""
    if spec is None or isinstance(spec, Hardware):
        return spec
    if isinstance(spec, str):
        return get(spec)
    raise TypeError(f"cannot resolve {spec!r} to a Hardware spec "
                    f"(want Hardware | preset name | None)")


__all__ = [
    "Hardware", "MemorySystem", "DramOrganization", "ClockDomain",
    "get", "register", "unregister", "names", "enable_jax", "resolve",
    "DEFAULT_BOARD", "DEFAULT_CHIP", "SCHEMA_VERSION", "presets",
]
