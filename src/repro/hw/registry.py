"""Named registry of :class:`~repro.hw.spec.Hardware` specs.

``get("tpu_v5e")`` resolves a preset (or anything registered at runtime) by
name; ``register`` adds project- or session-specific specs — e.g. the
output of ``Hardware.from_calibration`` — so sweeps and benchmarks can fan
out over memory systems by name (``--hw`` flags resolve here).
"""
from __future__ import annotations

from repro.hw.spec import Hardware

_REGISTRY: dict[str, Hardware] = {}


def register(hardware: Hardware, *, overwrite: bool = False) -> Hardware:
    """Register ``hardware`` under its own name; returns it for chaining."""
    if not isinstance(hardware, Hardware):
        raise TypeError(f"expected a Hardware spec, got {type(hardware)!r}")
    if hardware.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"hardware {hardware.name!r} is already registered; pass "
            f"overwrite=True to replace it")
    _REGISTRY[hardware.name] = hardware
    return hardware


def get(name: str) -> Hardware:
    """Look a spec up by name; ``KeyError`` lists the known names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> tuple[str, ...]:
    """Registered spec names, sorted."""
    return tuple(sorted(_REGISTRY))


def unregister(name: str) -> Hardware:
    """Remove and return a registered spec (mostly for tests)."""
    return _REGISTRY.pop(name)
