"""Built-in ``Hardware`` presets — the canonical home of the numbers that
used to be scattered as module constants.

* ``stratix10_ddr4_1866`` / ``stratix10_ddr4_2666`` — the paper's Intel
  Stratix 10 GX devkit with one DDR4 DIMM (Table III datasheet rows + the
  BSP Verilog parameters; see :mod:`repro.core.fpga` for the original
  derivation of ``burst_cnt``/``max_th``).
* ``tpu_v5e`` / ``tpu_v4`` — the TPU transplant targets.  The DRAM
  organization expresses the HBM transaction model in bank/burst terms
  (``dq * bl`` = the 512 B transaction granularity, ``t_rcd + t_rp`` = the
  28 ns row-miss class), so the same Eqs. 1-10 machinery scores them.

The deprecated constants ``repro.core.fpga.DDR4_1866``/``DDR4_2666``/
``STRATIX10_BSP`` and ``repro.core.hbm.TPU_V5E`` are now thin aliases over
these entries.
"""
from __future__ import annotations

from repro.hw.registry import register
from repro.hw.spec import ClockDomain, DramOrganization, Hardware, MemorySystem
from repro.search.envelope import ResourceEnvelope

#: Registry names the library itself relies on for defaults.
DEFAULT_BOARD = "stratix10_ddr4_1866"
DEFAULT_CHIP = "tpu_v5e"

# -- the paper's FPGA board (Stratix 10 GX devkit, one DDR4 DIMM) -----------

_S10_CLOCK = ClockDomain(
    burst_cnt=4,            # BURSTCOUNT_WIDTH: max txn = 2**4 * dq * bl = 1 KiB
    max_th=128,             # MAX_THREADS: Fig. 5b knee at stride 7 for SIMD=16
    f_kernel=300e6,
    peak_flops=9.2e12,      # Stratix 10 GX 2800 single-precision peak
)


#: What one Stratix-10 board can actually host: the global-memory
#: interconnect arbitrates up to 128 LSU ports, a kernel wider than 4 KiB
#: of aggregate LSU width does not close timing, one DDR4 channel, and the
#: burst buffers must fit the ~30 MB of on-chip BRAM.
_S10_ENVELOPE = ResourceEnvelope(
    lsu_ports=128, interconnect_bytes=4096,
    dram_channels=1, buffer_bytes=30e6)

#: TPU transplant budget: wider interconnect and more VMEM, one HBM stack
#: presented as a single channel to the model.
_TPU_ENVELOPE = ResourceEnvelope(
    lsu_ports=256, interconnect_bytes=16384,
    dram_channels=1, buffer_bytes=128e6)


def _s10_board(name: str, dram: DramOrganization) -> Hardware:
    return Hardware(
        name=name,
        dram=dram,
        clock=_S10_CLOCK,
        mem=MemorySystem(
            peak_bw=dram.bw_mem,
            txn_bytes=(1 << _S10_CLOCK.burst_cnt) * dram.min_burst_bytes,
            t_row=dram.t_row,
            mlp=dram.banks,         # bank interleaving hides row opens
            capacity_bytes=2e9,     # paper SIV: "2GB DDR4"
            local_bytes=30e6,       # on-chip BRAM order of magnitude
        ),
        envelope=_S10_ENVELOPE,
    )


STRATIX10_DDR4_1866 = register(_s10_board(
    "stratix10_ddr4_1866",
    DramOrganization(                # paper Table III: DDR4-1866
        name="DDR4-1866", f_mem=933.3e6, dq=8, bl=8,
        t_rcd=13.5e-9, t_rp=13.5e-9, t_wr=15e-9,
        banks=4, row_bytes=8192, interleave_bytes=1024)))

STRATIX10_DDR4_2666 = register(_s10_board(
    "stratix10_ddr4_2666",
    DramOrganization(                # JEDEC DDR4-2666 19-19-19 speed bin
        name="DDR4-2666", f_mem=1333.0e6, dq=8, bl=8,
        t_rcd=14.25e-9, t_rp=14.25e-9, t_wr=15e-9,
        banks=4, row_bytes=8192, interleave_bytes=1024)))

# -- TPU transplant targets -------------------------------------------------

TPU_V5E = register(Hardware(
    name="tpu_v5e",
    mem=MemorySystem(
        peak_bw=819e9, txn_bytes=512, t_row=28e-9, mlp=64,
        k_stream=0.92, k_strided=0.92, k_gather=0.92,
        capacity_bytes=16e9, local_bytes=128e6),
    # HBM expressed in bank/burst terms: dq*bl = 512 B transaction, f_mem
    # chosen so dq * 2 * f_mem equals the 819 GB/s interface bandwidth.
    dram=DramOrganization(
        name="HBM-v5e", f_mem=819e9 / (2 * 64), dq=64, bl=8,
        t_rcd=14e-9, t_rp=14e-9, t_wr=15e-9,
        banks=32, row_bytes=1024, interleave_bytes=512),
    clock=ClockDomain(
        burst_cnt=0,                 # one min-burst per transaction (512 B)
        max_th=128, f_kernel=940e6, peak_flops=197e12,
        ici_bw=50e9, ici_links=4, ici_hop_latency=1e-6),
    envelope=_TPU_ENVELOPE,
))

TPU_V4 = register(Hardware(
    name="tpu_v4",
    mem=MemorySystem(
        peak_bw=1228e9, txn_bytes=512, t_row=28e-9, mlp=64,
        k_stream=0.92, k_strided=0.92, k_gather=0.92,
        capacity_bytes=32e9, local_bytes=128e6),
    dram=DramOrganization(
        name="HBM2-v4", f_mem=1228e9 / (2 * 64), dq=64, bl=8,
        t_rcd=14e-9, t_rp=14e-9, t_wr=15e-9,
        banks=32, row_bytes=1024, interleave_bytes=512),
    clock=ClockDomain(
        burst_cnt=0, max_th=128, f_kernel=1050e6, peak_flops=275e12,
        ici_bw=50e9, ici_links=6,    # 3D torus: six ICI links per chip
        ici_hop_latency=1e-6),
    envelope=_TPU_ENVELOPE,
))
