"""The unified, serializable memory-system description: the ``Hardware`` spec.

The paper's core claim is that execution time of a memory-bound design is
predicted by a careful description of the *memory organization* — DRAM
timings, per-access-class efficiencies, BSP/clock parameters.  Before this
module that description was smeared across three places: the TPU constants
in :mod:`repro.core.hbm` (``TpuParams``), the DRAM datasheet / BSP values in
:mod:`repro.core.fpga`, and the bank organization buried in
:mod:`repro.core.dramsim`.  ``Hardware`` absorbs all three into one frozen,
registry-backed (:mod:`repro.hw.registry`), JSON-round-trippable spec:

* :class:`MemorySystem`   — per-access-class bandwidth efficiencies (the
  ``K_lsu`` analogue) + per-transaction overheads and capacities;
* :class:`DramOrganization` — channel/bank/burst geometry and the datasheet
  timings (paper Tables II-III + the simulator's bank model);
* :class:`ClockDomain`    — BSP/IP parameters and the clock-side numbers
  (kernel frequency, compute peak, interconnect).

A ``Hardware`` knows how to render itself as the three legacy parameter
views (:meth:`Hardware.dram_params`, :meth:`Hardware.bsp_params`,
:meth:`Hardware.tpu_params`) so every existing model path — scalar,
numpy-batch, jax-jit — consumes the same spec, and
:meth:`Hardware.from_calibration` folds a validation report's fitted
bandwidth, host factor and per-class errors back into a *persisted* spec
(``to_json``/``from_json``), closing the calibration loop that used to live
as a transient scalar on ``Session``.

All four dataclasses register as jax pytrees (:func:`enable_jax`) with the
numeric fields as leaves, so a spec can be threaded through ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import TYPE_CHECKING, Any, Mapping

from repro.search.envelope import ResourceEnvelope

if TYPE_CHECKING:  # the legacy view classes; imported lazily at runtime so
    # repro.hw stays import-clean of repro.core (repro.core re-exports the
    # registry-built constants, which would otherwise be circular).
    from repro.core.fpga import BspParams, DramParams
    from repro.core.hbm import TpuParams

#: Bump when a field is added/renamed so persisted specs are identifiable.
SCHEMA_VERSION = 1

#: Validation-kernel name -> the access class its error calibrates.
_KERNEL_CLASS = {
    "membench_aligned": "stream",
    "membench_strided": "strided",
    "membench_gather": "gather",
}


@dataclasses.dataclass(frozen=True)
class MemorySystem:
    """Bandwidth side of the spec: efficiencies + transaction overheads.

    ``k_*`` are the per-access-class efficiency factors (the paper's
    ``K_lsu`` analogue): the fraction of ``peak_bw`` a pure stream of that
    class sustains.  ``txn_bytes``/``t_row``/``mlp`` are the transaction
    model of :func:`repro.core.hbm.traffic_time` (granularity, row-miss
    latency, outstanding-transaction parallelism).
    """

    peak_bw: float                  # interface bandwidth ceiling [B/s]
    txn_bytes: int = 512            # transaction granularity [B]
    t_row: float = 28e-9            # row-miss latency class [s]
    mlp: int = 64                   # outstanding-transaction parallelism
    k_stream: float = 0.92          # per-class efficiencies (K_lsu analogue)
    k_strided: float = 0.92
    k_gather: float = 0.92
    capacity_bytes: float = 16e9    # device memory capacity [B]
    local_bytes: float = 128e6      # on-chip memory (VMEM / BRAM) [B]


@dataclasses.dataclass(frozen=True)
class DramOrganization:
    """Geometry + datasheet timings of the DRAM behind the interface.

    The ``f_mem``/``dq``/``bl``/``t_*`` rows are paper Table III; ``banks``/
    ``row_bytes``/``interleave_bytes`` are the bank organization the
    event-driven simulator models (previously hardcoded there); ``channels``
    scales the legacy single-channel :class:`DramParams` view's clock.
    """

    name: str = "dram"
    f_mem: float = 933.3e6          # I/O bus clock [Hz]
    dq: int = 8                     # data width [B]
    bl: int = 8                     # burst length [beats]
    t_rcd: float = 13.5e-9          # row activation [s]
    t_rp: float = 13.5e-9           # precharge [s]
    t_wr: float = 15e-9             # write recovery [s]
    channels: int = 1
    banks: int = 4
    row_bytes: int = 8192           # page size per bank [B]
    interleave_bytes: int = 1024    # controller interleave granularity [B]

    @property
    def bw_mem(self) -> float:
        """Peak DRAM bandwidth [B/s] across all channels (Eq. 2)."""
        return self.dq * 2.0 * self.f_mem * self.channels

    @property
    def t_row(self) -> float:
        """Row-miss inter-command delay (Eq. 6): T_RCD + T_RP."""
        return self.t_rcd + self.t_rp

    @property
    def min_burst_bytes(self) -> int:
        return self.dq * self.bl


@dataclasses.dataclass(frozen=True)
class ClockDomain:
    """BSP/IP parameters and the clock-side constants.

    ``burst_cnt``/``max_th`` are the generated-Verilog parameters of paper
    Table II (Eq. 5 / Eq. 7 triggers); ``f_kernel`` the kernel clock;
    ``peak_flops`` and the ``ici_*`` family feed the compute and collective
    terms of the TPU-transplant predictor.
    """

    burst_cnt: int = 4              # log2(max #min-bursts per transaction)
    max_th: int = 128               # max coalesced threads per request
    f_kernel: float = 300e6         # kernel/fabric clock [Hz]
    peak_flops: float = 197e12      # chip compute peak [FLOP/s]
    ici_bw: float = 50e9            # interconnect [B/s per link]
    ici_links: int = 4
    ici_hop_latency: float = 1e-6   # per-hop collective launch latency [s]


def _clamp_k(k: float) -> float:
    return min(1.0, max(1e-3, float(k)))


@dataclasses.dataclass(frozen=True)
class Hardware:
    """One complete, serializable memory-system description.

    Compose with the ``with_*`` builders (mirroring :class:`repro.Design`),
    persist with ``to_json``/``from_json``, look presets up by name through
    :mod:`repro.hw` (``hw.get("tpu_v5e")``), and hand to
    ``Session.with_hardware`` to evaluate designs against it.
    ``host_factor`` is the persisted calibration scalar (measured/modeled on
    the stream anchor, 1.0 = uncalibrated).  ``envelope`` is the spec's
    hard resource budget (:class:`repro.search.ResourceEnvelope`; ``None``
    = unconstrained) — pass it to ``Session.sweep(constraints=[...])`` /
    ``Session.optimize`` to restrict a search to designs the target can
    actually host.
    """

    name: str
    mem: MemorySystem
    dram: DramOrganization = DramOrganization()
    clock: ClockDomain = ClockDomain()
    host_factor: float = 1.0
    envelope: ResourceEnvelope | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("a Hardware spec needs a non-empty name")
        # plain numbers only: under jax tracing host_factor is a tracer and
        # must pass through unchecked (pytree unflatten rebuilds the spec).
        if isinstance(self.host_factor, (int, float)) \
                and not self.host_factor > 0:
            raise ValueError("host_factor must be > 0")

    # -- builder-style derivation ------------------------------------------

    def with_name(self, name: str) -> "Hardware":
        return dataclasses.replace(self, name=name)

    def with_mem(self, mem: MemorySystem) -> "Hardware":
        return dataclasses.replace(self, mem=mem)

    def with_dram(self, dram: DramOrganization) -> "Hardware":
        return dataclasses.replace(self, dram=dram)

    def with_clock(self, clock: ClockDomain) -> "Hardware":
        return dataclasses.replace(self, clock=clock)

    def with_host_factor(self, host_factor: float) -> "Hardware":
        return dataclasses.replace(self, host_factor=float(host_factor))

    def with_envelope(self, envelope: "ResourceEnvelope | None",
                      ) -> "Hardware":
        return dataclasses.replace(self, envelope=envelope)

    def with_efficiencies(self, **k: float) -> "Hardware":
        """Replace per-class efficiency factors: ``with_efficiencies(
        k_stream=0.9, k_gather=0.5)`` (values clamped to (0, 1])."""
        unknown = set(k) - {"k_stream", "k_strided", "k_gather"}
        if unknown:
            raise TypeError(f"unknown efficiency factors: {sorted(unknown)}")
        return dataclasses.replace(
            self, mem=dataclasses.replace(
                self.mem, **{n: _clamp_k(v) for n, v in k.items()}))

    # -- legacy parameter views --------------------------------------------

    def dram_params(self) -> "DramParams":
        """The faithful-FPGA-model view (:class:`repro.core.fpga.DramParams`).

        Multi-channel organizations fold ``channels`` into the view's clock
        so ``bw_mem`` stays the spec's aggregate bandwidth.
        """
        from repro.core.fpga import DramParams

        d = self.dram
        return DramParams(
            name=d.name, f_mem=d.f_mem * d.channels, dq=d.dq, bl=d.bl,
            t_rcd=d.t_rcd, t_rp=d.t_rp, t_wr=d.t_wr,
            banks=d.banks, row_bytes=d.row_bytes)

    def bsp_params(self) -> "BspParams":
        """The BSP/IP view (:class:`repro.core.fpga.BspParams`)."""
        from repro.core.fpga import BspParams

        return BspParams(burst_cnt=self.clock.burst_cnt,
                         max_th=self.clock.max_th)

    def tpu_params(self) -> "TpuParams":
        """The TPU-transplant view (:class:`repro.core.hbm.TpuParams`)."""
        from repro.core.hbm import TpuParams

        m, c = self.mem, self.clock
        return TpuParams(
            name=self.name, peak_flops=c.peak_flops, hbm_bw=m.peak_bw,
            ici_bw=c.ici_bw, ici_links=c.ici_links,
            hbm_bytes=m.capacity_bytes, vmem_bytes=m.local_bytes,
            txn_bytes=m.txn_bytes, t_row=m.t_row, mlp=m.mlp,
            ici_hop_latency=c.ici_hop_latency,
            k_stream=m.k_stream, k_strided=m.k_strided, k_gather=m.k_gather)

    # -- construction from the legacy parameter families -------------------

    @classmethod
    def from_parts(cls, name: str, *, dram: "DramParams",
                   bsp: "BspParams | None" = None,
                   tpu: "TpuParams | None" = None,
                   host_factor: float = 1.0) -> "Hardware":
        """Build a spec out of the legacy parameter objects.

        ``dram``/``bsp`` populate the organization and clock;  ``tpu`` (when
        given) supplies the bandwidth side, otherwise the memory system is
        derived from the DRAM datasheet (peak bandwidth, row latency, bank
        parallelism, BSP transaction granularity).
        """
        from repro.core.fpga import BspParams

        bsp = bsp if bsp is not None else BspParams()
        org = DramOrganization(
            name=dram.name, f_mem=dram.f_mem, dq=dram.dq, bl=dram.bl,
            t_rcd=dram.t_rcd, t_rp=dram.t_rp, t_wr=dram.t_wr,
            banks=dram.banks, row_bytes=dram.row_bytes)
        if tpu is not None:
            mem = MemorySystem(
                peak_bw=tpu.hbm_bw, txn_bytes=tpu.txn_bytes,
                t_row=tpu.t_row, mlp=tpu.mlp, k_stream=tpu.k_stream,
                k_strided=tpu.k_strided, k_gather=tpu.k_gather,
                capacity_bytes=tpu.hbm_bytes, local_bytes=tpu.vmem_bytes)
            clock = ClockDomain(
                burst_cnt=bsp.burst_cnt, max_th=bsp.max_th,
                peak_flops=tpu.peak_flops, ici_bw=tpu.ici_bw,
                ici_links=tpu.ici_links,
                ici_hop_latency=tpu.ici_hop_latency)
        else:
            mem = MemorySystem(
                peak_bw=org.bw_mem,
                txn_bytes=bsp.max_transaction_bytes(dram),
                t_row=org.t_row, mlp=org.banks)
            clock = ClockDomain(burst_cnt=bsp.burst_cnt, max_th=bsp.max_th)
        return cls(name=name, mem=mem, dram=org, clock=clock,
                   host_factor=float(host_factor))

    @classmethod
    def from_calibration(cls, report: Any, *,
                         base: "Hardware | None" = None,
                         name: str | None = None) -> "Hardware":
        """Fold a validation report back into a persistable spec.

        ``report`` is a ``Session.validate`` result (or the underlying
        ``repro.core.validate.ValidationReport``): its fitted DRAM parameter
        set becomes the organization, its stream-anchor bandwidth the memory
        system's ``peak_bw``, its host factor the persisted ``host_factor``,
        and each class-pure membench kernel's predicted/measured ratio
        scales that class's efficiency factor — so a re-used spec predicts
        what ``Session.with_calibration(report)`` predicts, but from disk.
        """
        from repro.hw.registry import get as _get

        base = base if base is not None else _get("stratix10_ddr4_1866")
        d: DramParams = report.dram
        org = DramOrganization(
            name=d.name, f_mem=d.f_mem, dq=d.dq, bl=d.bl,
            t_rcd=d.t_rcd, t_rp=d.t_rp, t_wr=d.t_wr,
            banks=d.banks, row_bytes=d.row_bytes,
            interleave_bytes=base.dram.interleave_bytes)
        k = {"k_stream": base.mem.k_stream, "k_strided": base.mem.k_strided,
             "k_gather": base.mem.k_gather}
        for r in report.results:
            cls_name = _KERNEL_CLASS.get(r.name)
            if cls_name and r.measured_s > 0 and r.predicted_s > 0:
                k[f"k_{cls_name}"] = _clamp_k(
                    k[f"k_{cls_name}"] * r.predicted_s / r.measured_s)
        measured_bw = float(getattr(report, "measured_bw", 0.0) or 0.0)
        mem = dataclasses.replace(
            base.mem, peak_bw=measured_bw or org.bw_mem, **k)
        return cls(
            name=name or f"{base.name}-calibrated",
            mem=mem, dram=org, clock=base.clock,
            host_factor=float(getattr(report, "calibration_factor", 1.0)))

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-able dict (stable keys; includes the schema version)."""
        out = {
            "schema": SCHEMA_VERSION,
            "name": self.name,
            "host_factor": self.host_factor,
            "mem": dataclasses.asdict(self.mem),
            "dram": dataclasses.asdict(self.dram),
            "clock": dataclasses.asdict(self.clock),
        }
        if self.envelope is not None:
            out["envelope"] = self.envelope.to_dict()
        return out

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, obj: Mapping[str, Any]) -> "Hardware":
        schema = obj.get("schema", SCHEMA_VERSION)
        if schema > SCHEMA_VERSION:
            raise ValueError(
                f"Hardware spec schema {schema} is newer than this "
                f"library's {SCHEMA_VERSION}")

        def _load(klass, data):
            known = {f.name for f in dataclasses.fields(klass)}
            return klass(**{k: v for k, v in dict(data).items() if k in known})

        env = obj.get("envelope")
        return cls(
            name=str(obj["name"]),
            mem=_load(MemorySystem, obj["mem"]),
            dram=_load(DramOrganization, obj["dram"]),
            clock=_load(ClockDomain, obj["clock"]),
            host_factor=float(obj.get("host_factor", 1.0)),
            envelope=(ResourceEnvelope.from_dict(env)
                      if env is not None else None))

    @classmethod
    def from_json(cls, text: str) -> "Hardware":
        return cls.from_dict(json.loads(text))


# ---------------------------------------------------------------------------
# jax pytree registration
# ---------------------------------------------------------------------------

_PYTREE_REGISTERED = False


def enable_jax() -> bool:
    """Register the spec family as jax pytrees (idempotent; False w/o jax).

    Numeric fields become leaves and name strings auxiliary data, so a
    ``Hardware`` can be passed straight through ``jax.jit``/``vmap`` like
    the :class:`repro.core.model_batch.GroupBatch` it rides along with.
    """
    global _PYTREE_REGISTERED
    if _PYTREE_REGISTERED:
        return True
    try:
        from jax import tree_util as _jtu
    except ImportError:
        return False

    def _register(klass, aux_fields: tuple[str, ...] = ()):
        leaf = tuple(f.name for f in dataclasses.fields(klass)
                     if f.name not in aux_fields)

        def flatten(x):
            return (tuple(getattr(x, n) for n in leaf),
                    tuple(getattr(x, n) for n in aux_fields))

        def unflatten(aux, children):
            return klass(**dict(zip(leaf, children)),
                         **dict(zip(aux_fields, aux)))

        try:
            _jtu.register_pytree_node(klass, flatten, unflatten)
        except ValueError:  # pragma: no cover — already registered (reload)
            pass

    _register(MemorySystem)
    _register(DramOrganization, aux_fields=("name",))
    _register(ClockDomain)
    # the envelope is plain hashable data, not arrays — aux, not leaves
    _register(Hardware, aux_fields=("name", "envelope"))
    _PYTREE_REGISTERED = True
    return True
