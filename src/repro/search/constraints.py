"""The constraint algebra and the vectorized feasibility mask.

A :class:`Constraint` maps per-point *columns* to a boolean keep-mask.
Columns are served lazily by :class:`GridColumns` so a mask that only
reads ``interconnect_bytes`` never materializes anything else; available
keys are the numeric sweep axes (values), ``lsu_type`` /
``lsu_type_code``, the categorical axis objects (``dram``/``bsp``/
``hardware``), and the resource-usage columns of
:mod:`repro.search.envelope` (computed against each point's *effective*
DRAM/BSP — hardware-axis overrides resolved exactly like the scorer).

Constraints compose by conjunction (a sequence passed to
``Session.sweep(constraints=[...])``, or ``a & b``), serialize to tagged
JSON dicts (so a :class:`repro.core.stream.SweepPlan` carrying them still
round-trips through text), and are consumed in three places:

* the streaming evaluator masks each chunk *before* scoring it;
* ``Space.random`` rejection-samples against them;
* ``Session.optimize`` filters its screen/refine candidates and turns
  envelope caps into differentiable penalties.

The contract that everything downstream relies on: masking before scoring
is bit-equal to post-filtering the unconstrained sweep, because the mask
is a pure function of each point's own configuration (tests/test_search).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

from repro.core import model_batch as _mb
from repro.search.envelope import (
    USAGE_COLUMNS,
    ResourceEnvelope,
    max_transaction_bytes,
    usage_from_axes,
)

_BOUND_OPS = ("<=", ">=")


class GridColumns(Mapping):
    """Lazy per-point column view over coded sweep points.

    Built from the same ``(numeric columns, categorical (table, codes))``
    currency the scorer consumes, so the streaming mask, the materialized
    pre-filter and ``Space.random`` all read identical values.  Usage
    columns resolve the hardware axis first (a point running on a
    ``hardware`` spec is budgeted against that spec's DRAM/BSP).
    """

    def __init__(self, numeric: Mapping[str, np.ndarray],
                 cats: Mapping[str, tuple[list, np.ndarray]], n: int):
        self._numeric = {k: np.asarray(v) for k, v in numeric.items()}
        self._cats = {k: (list(t), np.asarray(c, dtype=np.int64))
                      for k, (t, c) in cats.items()}
        self._n = int(n)
        self._cache: dict[str, np.ndarray] = {}

    @property
    def n(self) -> int:
        return self._n

    def _resolved(self):
        from repro.core import sweep as _sweep

        res = self._cache.get("$resolved")
        if res is None:
            res = _sweep._resolve_hardware_codes(dict(self._cats), self._n)[0]
            self._cache["$resolved"] = res
        return res

    def _usage(self) -> dict[str, np.ndarray]:
        usage = self._cache.get("$usage")
        if usage is None:
            res = self._resolved()
            d_table, d_codes = res["dram"]
            b_table, b_codes = res["bsp"]
            gather = lambda table, codes, attr: np.asarray(  # noqa: E731
                [getattr(o, attr) if o is not None else 0 for o in table],
                dtype=np.float64)[codes]
            txn = max_transaction_bytes(
                gather(d_table, d_codes, "dq"),
                gather(d_table, d_codes, "bl"),
                gather(b_table, b_codes, "burst_cnt"))
            usage = usage_from_axes(
                type_codes=self["lsu_type_code"],
                n_ga=self._numeric["n_ga"], simd=self._numeric["simd"],
                elem_bytes=self._numeric["elem_bytes"],
                include_write=self._numeric["include_write"],
                max_txn=txn)
            usage = {k: np.asarray(v) for k, v in usage.items()}
            self._cache["$usage"] = usage
        return usage

    def __getitem__(self, key: str) -> np.ndarray:
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        if key in self._numeric:
            val = self._numeric[key]
        elif key == "lsu_type_code":
            table, codes = self._cats["lsu_type"]
            val = np.asarray([_mb.TYPE_CODE[t] for t in table],
                             dtype=np.int64)[codes]
        elif key in USAGE_COLUMNS:
            val = self._usage()[key]
        elif key in self._cats:
            from repro.core.sweep import _object_array

            table, codes = self._cats[key]
            val = _object_array(table)[codes]
        else:
            raise KeyError(key)
        self._cache[key] = val
        return val

    def __iter__(self):
        return iter(sorted({*self._numeric, *self._cats,
                            "lsu_type_code", *USAGE_COLUMNS}))

    def __len__(self) -> int:
        return len(set(self._numeric) | set(self._cats)) \
            + 1 + len(USAGE_COLUMNS)


class Constraint:
    """One feasibility predicate over per-point columns.

    ``mask(cols)`` returns a boolean keep-array of the view's length.
    ``a & b`` builds the conjunction; sequences passed to the public
    entry points are normalized through :func:`normalize_constraints`.
    """

    def mask(self, cols: GridColumns) -> np.ndarray:
        raise NotImplementedError

    def __and__(self, other: "Constraint") -> "AllOf":
        return AllOf(parts=(self,) + (other.parts if isinstance(other, AllOf)
                                      else (as_constraint(other),)))

    def to_json_dict(self) -> dict:
        raise TypeError(
            f"{type(self).__name__} has no JSON encoding; only envelope, "
            f"bound and all-of constraints can ride a SweepPlan through "
            f"text (callables still pickle for process executors)")


@dataclasses.dataclass(frozen=True)
class EnvelopeConstraint(Constraint):
    """``usage <= envelope`` over every cap the envelope sets."""

    envelope: ResourceEnvelope

    def mask(self, cols: GridColumns) -> np.ndarray:
        caps = self.envelope.caps()
        out = np.ones(cols.n, dtype=bool)
        for name, cap in caps.items():
            out &= np.asarray(cols[name], dtype=np.float64) <= cap
        return out

    def to_json_dict(self) -> dict:
        return {"$kind": "envelope", "envelope": self.envelope.to_dict()}


@dataclasses.dataclass(frozen=True)
class BoundConstraint(Constraint):
    """``column <= bound`` (or ``>=``) on any servable column."""

    column: str
    bound: float
    op: str = "<="

    def __post_init__(self):
        if self.op not in _BOUND_OPS:
            raise ValueError(f"bound op must be one of {_BOUND_OPS}")

    def mask(self, cols: GridColumns) -> np.ndarray:
        v = np.asarray(cols[self.column], dtype=np.float64)
        return v <= self.bound if self.op == "<=" else v >= self.bound

    def to_json_dict(self) -> dict:
        return {"$kind": "bound", "column": self.column,
                "bound": float(self.bound), "op": self.op}


@dataclasses.dataclass(frozen=True)
class LambdaConstraint(Constraint):
    """A custom callable ``fn(cols) -> bool mask``.

    Picklable iff ``fn`` is (use a module-level function for process
    executors); never JSON-serializable.
    """

    fn: Callable[[GridColumns], np.ndarray]

    def mask(self, cols: GridColumns) -> np.ndarray:
        out = np.asarray(self.fn(cols))
        if out.dtype != bool or out.shape != (cols.n,):
            raise ValueError(
                f"constraint callable must return a bool mask of shape "
                f"({cols.n},); got dtype={out.dtype} shape={out.shape}")
        return out


@dataclasses.dataclass(frozen=True)
class AllOf(Constraint):
    """Conjunction of constraints (what ``a & b`` builds)."""

    parts: tuple[Constraint, ...]

    def mask(self, cols: GridColumns) -> np.ndarray:
        out = np.ones(cols.n, dtype=bool)
        for p in self.parts:
            out &= p.mask(cols)
        return out

    def to_json_dict(self) -> dict:
        return {"$kind": "all_of",
                "parts": [p.to_json_dict() for p in self.parts]}


def within(envelope: ResourceEnvelope) -> EnvelopeConstraint:
    """Readable alias: ``constraints=[within(board.envelope)]``."""
    return EnvelopeConstraint(envelope)


def as_constraint(obj: Any) -> Constraint:
    """Coerce user input: envelopes and callables lift automatically."""
    if isinstance(obj, Constraint):
        return obj
    if isinstance(obj, ResourceEnvelope):
        return EnvelopeConstraint(obj)
    if callable(obj):
        return LambdaConstraint(obj)
    raise TypeError(
        f"cannot interpret {obj!r} as a constraint; pass a Constraint, a "
        f"ResourceEnvelope, or a callable(cols) -> bool mask")


def normalize_constraints(constraints: Any) -> tuple[Constraint, ...]:
    """One constraint or a sequence -> a tuple of Constraint instances."""
    if constraints is None:
        return ()
    if isinstance(constraints, (Constraint, ResourceEnvelope)) \
            or callable(constraints):
        return (as_constraint(constraints),)
    return tuple(as_constraint(c) for c in constraints)


def feasibility_mask(constraints: Iterable[Constraint],
                     cols: GridColumns) -> np.ndarray:
    """AND of every constraint's mask (all-True when unconstrained)."""
    out = np.ones(cols.n, dtype=bool)
    for c in constraints:
        out &= np.asarray(c.mask(cols), dtype=bool)
    return out


def columns_from_lists(lists: Mapping[str, Sequence],
                       codes: Mapping[str, np.ndarray]) -> GridColumns:
    """The column view of coded grid points (the streaming-mask entry)."""
    from repro.core import sweep as _sweep

    some = next(iter(codes.values()))
    numeric = {k: np.asarray(list(lists[k]))[codes[k]]
               for k in lists if k not in _sweep._CATEGORICAL}
    cats = {k: (list(lists[k]), codes[k])
            for k in lists if k in _sweep._CATEGORICAL}
    return GridColumns(numeric, cats, len(np.asarray(some)))


def columns_from_parts(numeric: Mapping[str, np.ndarray],
                       cats: Mapping[str, tuple[list, np.ndarray]],
                       n: int) -> GridColumns:
    """The column view of materialized/random points (value columns)."""
    return GridColumns(numeric, cats, n)


# ---------------------------------------------------------------------------
# JSON codecs (SweepPlan round-trip)
# ---------------------------------------------------------------------------

def constraint_to_json(c: Constraint) -> dict:
    return c.to_json_dict()


def constraint_from_json(obj: Mapping[str, Any]) -> Constraint:
    kind = obj.get("$kind")
    if kind == "envelope":
        return EnvelopeConstraint(ResourceEnvelope.from_dict(obj["envelope"]))
    if kind == "bound":
        return BoundConstraint(column=str(obj["column"]),
                               bound=float(obj["bound"]),
                               op=str(obj["op"]))
    if kind == "all_of":
        return AllOf(parts=tuple(constraint_from_json(p)
                                 for p in obj["parts"]))
    raise TypeError(f"unknown encoded constraint {obj!r}")


def envelope_caps(constraints: Iterable[Constraint]) -> dict[str, float]:
    """Merged usage caps (min across envelopes) — the optimizer's
    differentiable-penalty terms.  Non-envelope constraints contribute
    nothing here; they still filter every discrete candidate."""
    caps: dict[str, float] = {}

    def visit(c: Constraint) -> None:
        if isinstance(c, AllOf):
            for p in c.parts:
                visit(p)
        elif isinstance(c, EnvelopeConstraint):
            for name, cap in c.envelope.caps().items():
                caps[name] = min(cap, caps.get(name, np.inf))
        elif isinstance(c, BoundConstraint) and c.op == "<=" \
                and c.column in USAGE_COLUMNS:
            caps[c.column] = min(float(c.bound), caps.get(c.column, np.inf))

    for c in constraints:
        visit(c)
    return caps
