"""Resource envelopes as data: the budget side and the usage side.

Real DSE tools prune candidate designs by hard resource constraints before
scoring anything (charm's CDSE prunes on DSP/BRAM/URAM/HBM channels); the
analytical model makes scoring nearly free, but a feasibility cut is
*entirely* free and composes with every search strategy.  This module
supplies both halves of that cut:

* :class:`ResourceEnvelope` — a frozen, hashable, JSON-round-trippable
  budget over the four resources the microbenchmark family consumes:
  LSU ports into the global-memory interconnect, interconnect data width
  in bytes (the sweep engine's ``resource`` objective), DRAM channels,
  and on-chip transaction-buffer bytes.  ``None`` means unbounded.
  Every :class:`repro.hw.Hardware` spec carries one (presets included),
  so ``constraints=[board.envelope]`` is the one-liner.
* The **usage model** — :func:`usage_from_axes` (vectorized over sweep
  columns; what the streaming feasibility mask evaluates) and
  :func:`usage_of_design` (one :class:`repro.Design`).  Both express the
  same accounting: one port and ``ls_width`` interconnect bytes per
  global LSU, one max-size transaction buffer per burst-coalesced LSU
  (``2**burst_cnt * dq * bl`` bytes — the generated Verilog's burst
  buffer), ``ls_width`` buffer bytes for non-burst (atomic) units, and
  one DRAM channel whenever the design issues global traffic at all.

This module must stay import-light (numpy + stdlib only):
:mod:`repro.hw.spec` imports it at class-definition time, while
:mod:`repro.hw` — which :mod:`repro.core` initializes from — is itself
still loading, so importing ``repro.core`` here would be circular.
:func:`usage_from_axes` therefore imports the type codes lazily.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

import numpy as np

#: The usage columns a feasibility mask can read, in canonical order.
USAGE_COLUMNS = ("lsu_ports", "interconnect_bytes", "dram_channels",
                 "buffer_bytes")

#: Bump when a field is added/renamed so persisted envelopes are identifiable.
ENVELOPE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class ResourceEnvelope:
    """A hard resource budget; ``None`` caps nothing.

    Fields mirror :data:`USAGE_COLUMNS`.  The envelope is plain data —
    hashable, picklable, JSON-round-trippable — so it rides on a
    :class:`repro.hw.Hardware` spec (as pytree aux data) and inside a
    :class:`repro.core.stream.SweepPlan` without dragging code along.
    """

    lsu_ports: float | None = None
    interconnect_bytes: float | None = None
    dram_channels: float | None = None
    buffer_bytes: float | None = None

    def __post_init__(self):
        for name in USAGE_COLUMNS:
            cap = getattr(self, name)
            if cap is not None and not float(cap) >= 0:
                raise ValueError(f"envelope cap {name}={cap!r} must be >= 0")

    def caps(self) -> dict[str, float]:
        """The bounded columns only: column name -> cap."""
        return {name: float(getattr(self, name)) for name in USAGE_COLUMNS
                if getattr(self, name) is not None}

    def admits(self, usage: Mapping[str, Any]) -> np.ndarray:
        """Vectorized ``usage <= cap`` over every bounded column."""
        caps = self.caps()
        if not caps:
            probe = next(iter(usage.values()), np.ones(0))
            return np.ones(np.shape(np.asarray(probe)), dtype=bool)
        mask: np.ndarray | None = None
        for name, cap in caps.items():
            ok = np.asarray(usage[name], dtype=np.float64) <= cap
            mask = ok if mask is None else (mask & ok)
        return mask

    def constraint(self):
        """This envelope as a :class:`repro.search.constraints.Constraint`."""
        from repro.search.constraints import EnvelopeConstraint

        return EnvelopeConstraint(self)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        return {"schema": ENVELOPE_SCHEMA,
                **{name: getattr(self, name) for name in USAGE_COLUMNS}}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, obj: Mapping[str, Any]) -> "ResourceEnvelope":
        schema = obj.get("schema", ENVELOPE_SCHEMA)
        if schema > ENVELOPE_SCHEMA:
            raise ValueError(
                f"ResourceEnvelope schema {schema} is newer than this "
                f"library's {ENVELOPE_SCHEMA}")
        def _num(v):
            # keep int caps int so to_json(from_json(x)) == x byte-for-byte
            if v is None or (isinstance(v, (int, float))
                             and not isinstance(v, bool)):
                return v
            return float(v)

        return cls(**{name: _num(obj.get(name)) for name in USAGE_COLUMNS})

    @classmethod
    def from_json(cls, text: str) -> "ResourceEnvelope":
        return cls.from_dict(json.loads(text))


def max_transaction_bytes(dq, bl, burst_cnt):
    """Per-burst-LSU transaction buffer [B]: ``2**burst_cnt * dq * bl``.

    Vectorized; mirrors ``BspParams.max_transaction_bytes`` (paper Table
    II: BURSTCOUNT_WIDTH sizes the largest coalesced transaction).
    """
    return (2.0 ** np.asarray(burst_cnt, dtype=np.float64)
            * np.asarray(dq, dtype=np.float64)
            * np.asarray(bl, dtype=np.float64))


def usage_from_axes(*, type_codes, n_ga, simd, elem_bytes, include_write,
                    max_txn, xp=np) -> dict[str, Any]:
    """Per-point resource usage from sweep-axis columns (vectorized).

    Inputs are per-point arrays: ``type_codes`` are
    :data:`repro.core.model_batch.TYPE_CODE` integers, ``max_txn`` the
    per-point burst-buffer size (:func:`max_transaction_bytes` of the
    point's effective DRAM/BSP).  The accounting matches the microbench
    group expansion of :func:`repro.core.sweep._score` exactly — in
    particular ``interconnect_bytes`` equals its ``resource`` column —
    so a feasibility mask computed here is bit-equal to post-filtering
    scored results.  ``xp=jnp`` (with float inputs) makes every column
    differentiable for the relaxed optimizer.
    """
    from repro.core import model_batch as _mb

    type_codes = xp.asarray(type_codes)
    n_ga = xp.asarray(n_ga)
    simd = xp.asarray(simd)
    elem_bytes = xp.asarray(elem_bytes)
    max_txn = xp.asarray(max_txn)
    is_atomic = type_codes == _mb.ATOMIC
    is_ack = type_codes == _mb.WRITE_ACK
    # include_write is inert for atomics (the atomic IS the write) — the
    # same normalization _score applies before expanding groups.
    iw = xp.asarray(include_write, dtype=bool) & ~is_atomic

    g1_count = xp.where(is_atomic | is_ack, n_ga, n_ga + iw)
    g1_width = xp.where(is_atomic, elem_bytes, simd * elem_bytes)
    g2_count = xp.where(is_ack & iw, simd, xp.zeros_like(simd))

    ports = g1_count + g2_count
    interconnect = g1_count * g1_width + g2_count * elem_bytes
    # Burst-coalesced LSUs buffer one max transaction each; atomic units
    # buffer one element-wide beat.  The ACK store group is burst-typed.
    g1_buf = xp.where(is_atomic, g1_width, max_txn)
    buffer_bytes = g1_count * g1_buf + g2_count * max_txn
    channels = xp.where(ports > 0, xp.ones_like(max_txn),
                        xp.zeros_like(max_txn))
    return {"lsu_ports": ports, "interconnect_bytes": interconnect,
            "dram_channels": channels, "buffer_bytes": buffer_bytes}


def usage_of_design(design, dram=None, bsp=None) -> dict[str, float]:
    """Resource usage of one :class:`repro.Design` (scalar totals).

    ``dram``/``bsp`` size the burst buffers (the design's own overrides
    win; both default to the library's default board).  Agrees with
    :func:`usage_from_axes` on every microbench design (tested).
    """
    dram = design.dram or dram
    bsp = design.bsp or bsp
    if dram is None or bsp is None:
        from repro.hw import DEFAULT_BOARD, get as _hw_get

        board = _hw_get(DEFAULT_BOARD)
        dram = dram or board.dram_params()
        bsp = bsp or board.bsp_params()
    txn = float(max_transaction_bytes(dram.dq, dram.bl, bsp.burst_cnt))
    ports = interconnect = buffer_bytes = 0.0
    for lsu in design.lsus:
        if not lsu.lsu_type.is_global:
            continue
        ports += 1
        interconnect += lsu.ls_width
        buffer_bytes += txn if lsu.lsu_type.is_burst else lsu.ls_width
    return {"lsu_ports": ports, "interconnect_bytes": interconnect,
            "dram_channels": 1.0 if ports else 0.0,
            "buffer_bytes": buffer_bytes}
