"""``repro.search`` — constrained + gradient-based design-space exploration.

The paper's value proposition is scoring a design point in microseconds
instead of hours of place-and-route; this package turns that speed into
*search* instead of enumeration:

* :mod:`repro.search.envelope` — :class:`ResourceEnvelope`, the frozen,
  serializable resource budget a :class:`repro.hw.Hardware` spec carries
  (LSU ports, interconnect bytes, DRAM channels, on-chip buffer bytes),
  plus the per-design resource-*usage* model that is compared against it.
* :mod:`repro.search.constraints` — the :class:`Constraint` algebra
  (``within(envelope)``, column bounds, custom callables, conjunction)
  and the vectorized feasibility mask the streaming sweep engine applies
  *before* scoring a chunk, bit-equal to post-filtering the unconstrained
  sweep.
* :mod:`repro.search.optimize` — ``Session.optimize``'s implementation:
  continuous relaxation of the integer axes, multi-start AdamW descent
  through the differentiable estimator (one lane per categorical
  combination), discrete refinement + Pareto local search through the
  existing streaming evaluator, reported as :class:`OptimizeReport`.

Import order matters: :mod:`repro.hw.spec` imports the envelope module at
class-definition time — while :mod:`repro.hw` itself is still
initializing — so this ``__init__`` must stay import-free: every public
name resolves lazily through PEP 562 ``__getattr__`` (the constraint and
optimizer modules reach back into :mod:`repro.core` / :mod:`repro.api`).
"""
import importlib

#: public name -> submodule that defines it (all served lazily).
_EXPORTS = {
    "ResourceEnvelope": "envelope",
    "USAGE_COLUMNS": "envelope",
    "usage_from_axes": "envelope",
    "usage_of_design": "envelope",
    "Constraint": "constraints",
    "EnvelopeConstraint": "constraints",
    "BoundConstraint": "constraints",
    "LambdaConstraint": "constraints",
    "AllOf": "constraints",
    "within": "constraints",
    "as_constraint": "constraints",
    "normalize_constraints": "constraints",
    "feasibility_mask": "constraints",
    "OptimizeReport": "optimize",
    "run_optimize": "optimize",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is not None:
        return getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
