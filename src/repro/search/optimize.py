"""``Session.optimize`` — find grid optima without enumerating the grid.

The analytical model is closed-form and differentiable, so design-space
search does not have to be exhaustive *or* black-box: the integer axes can
be relaxed to continuous coordinates and descended through the very same
Eqs. 1-10 the sweep engine scores.  The search runs in phases:

1. **screen** — a seeded uniform sample of the grid, feasibility-masked
   *before* scoring (rejection sampling against the constraint algebra),
   scored through the plan's streaming evaluator.
2. **descend** — the screened winners seed one *lane* per categorical
   combination; each lane relaxes the numeric axes to continuous
   sorted-index coordinates (``jnp.interp`` over the sorted axis values)
   and multi-start AdamW (:mod:`repro.optim.adamw`) descends
   ``log(objective)`` plus smooth envelope-cap penalties through the
   jax-differentiable estimator.  All lanes descend together as one
   batched :class:`~repro.core.model_batch.GroupBatch` of ``2 * lanes``
   LSU groups — the exact group expansion ``sweep._score`` uses.
3. **refine** — each continuous optimum is snapped to its discrete
   neighborhood (round plus axis-wise floor/ceil), then a greedy ±1-code
   coordinate descent polishes the incumbent.  Every candidate goes
   through the *unconstrained* plan evaluator, so each scored number is
   bit-identical to what the exhaustive sweep would have produced for
   that id.
4. **Pareto local search** (2-objective mode) — the running front's
   ±1-code neighbors are expanded, masked and scored until the front
   stops moving or the evaluation budget runs out.

Everything is budgeted: ``max_evals`` (default ``max(1024, n // 128)`` —
under 1% of any large grid) caps scored rows across all phases, jax
padding included, and the report carries the exact telemetry.  Without
jax the descent phase is skipped and screen/refine still run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core import model_batch as _mb
from repro.core import stream as _stream
from repro.core import sweep as _sweep
from repro.search.constraints import (
    columns_from_lists,
    envelope_caps,
    feasibility_mask,
    normalize_constraints,
)
from repro.search.envelope import max_transaction_bytes, usage_from_axes

#: Columns an objective may name: estimator outputs + the interconnect cost.
OBJECTIVE_COLUMNS = _stream.ESTIMATE_COLUMNS + ("resource",)

#: Weight of the smooth envelope penalty in the relaxed descent loss.
_PENALTY_RHO = 10.0


def _cat_label(v) -> str:
    if v is None:
        return "-"
    return getattr(v, "name", None) or str(v)


# ---------------------------------------------------------------------------
# evaluation log: every grid point ever scored, with budget accounting
# ---------------------------------------------------------------------------


class _EvalLog:
    """Scored-point store + the eval budget, shared by every phase.

    All ids handed to :meth:`evaluate` are deduplicated against what was
    already scored and feasibility-masked *before* spending budget, so the
    log only ever holds feasible rows and the budget only pays for fresh
    work.  The jax-jit backend is padded to power-of-two block sizes (min
    64) so it compiles O(log budget) shapes — padding rows are charged to
    the budget, keeping the <1%-of-points telemetry honest.
    """

    def __init__(self, plan, constraints, budget: int):
        self.plan = plan
        self.enum = plan.enumerator()
        self.lists = {k: list(v) for k, v in plan.lists.items()}
        self.constraints = constraints
        self.budget = int(budget)
        self.spent = 0              # total charged rows (padding included)
        self.grid_evals = 0         # distinct grid points actually scored
        self.relaxed_evals = 0      # continuous-descent model rows
        self._eval = plan.evaluator()
        self._pad_pow2 = plan.backend == "jax-jit"
        self._seen: set[int] = set()
        self._blocks: list[dict[str, np.ndarray]] = []
        self._cols: dict[str, np.ndarray] | None = None

    @property
    def remaining(self) -> int:
        return max(0, self.budget - self.spent)

    def feasible(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        if not self.constraints or not len(ids):
            return ids
        cols = columns_from_lists(self.lists, self.enum.codes(ids))
        return ids[feasibility_mask(self.constraints, cols)]

    def evaluate(self, ids: np.ndarray) -> int:
        """Score the fresh, feasible subset of ``ids`` (budget permitting).

        Returns how many new grid points were scored.
        """
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        ids = ids[(ids >= 0) & (ids < self.enum.n)]
        if len(self._seen):
            ids = np.asarray([i for i in ids.tolist() if i not in self._seen],
                             dtype=np.int64)
        ids = self.feasible(ids)
        if not len(ids) or self.remaining <= 0:
            return 0
        if len(ids) > self.remaining:
            ids = ids[:self.remaining]
        m = len(ids)
        if self._pad_pow2:
            padded_n = 64
            while padded_n < m:
                padded_n *= 2
            padded_n = min(padded_n, max(m, self.remaining))
            padded = np.concatenate(
                [ids, np.full(padded_n - m, ids[-1], dtype=np.int64)])
            cols = {k: np.asarray(v)[:m]
                    for k, v in self._eval(padded).items()}
            self.spent += padded_n
        else:
            cols = {k: np.asarray(v) for k, v in self._eval(ids).items()}
            self.spent += m
        self.grid_evals += m
        self._seen.update(ids.tolist())
        self._blocks.append(cols)
        self._cols = None
        return m

    def columns(self) -> dict[str, np.ndarray]:
        """Everything scored so far, concatenated (cached until next eval)."""
        if self._cols is None:
            if not self._blocks:
                return {}
            self._cols = {k: np.concatenate([b[k] for b in self._blocks])
                          for k in self._blocks[0]}
        return self._cols

    def argbest(self, objective: str) -> int | None:
        """Row index of the incumbent (min objective, min id tie-break)."""
        cols = self.columns()
        if not cols or not len(cols["id"]):
            return None
        vals = np.asarray(cols[objective], dtype=np.float64)
        best = np.flatnonzero(vals == vals.min())
        return int(best[np.argmin(cols["id"][best])])

    def front(self, objectives: Sequence[str]) -> np.ndarray:
        """Row indices of the Pareto front over the scored points."""
        cols = self.columns()
        if not cols or not len(cols["id"]):
            return np.empty(0, dtype=np.int64)
        vals = np.stack([np.asarray(cols[o], dtype=np.float64)
                         for o in objectives], axis=1)
        return _sweep.pareto_front(vals)


# ---------------------------------------------------------------------------
# neighborhoods on the coded grid
# ---------------------------------------------------------------------------


def _neighbor_ids(enum: _stream.GridEnumerator, ids: np.ndarray) -> np.ndarray:
    """±1-code neighbors of ``ids`` along every axis (clipped, deduped)."""
    ids = np.asarray(ids, dtype=np.int64)
    if not len(ids):
        return ids
    codes = enum.codes(ids)
    out = []
    for i, name in enumerate(enum.names):
        k = int(enum.sizes[i])
        if k < 2:
            continue
        for step in (-1, 1):
            c = codes[name] + step
            ok = (c >= 0) & (c < k)
            if not ok.any():
                continue
            shifted = dict(codes)
            shifted = {a: v[ok] for a, v in shifted.items()}
            shifted[name] = c[ok]
            out.append(enum.encode(shifted))
    if not out:
        return np.empty(0, dtype=np.int64)
    return np.unique(np.concatenate(out))


# ---------------------------------------------------------------------------
# phase 2: continuous relaxation + multi-start AdamW descent
# ---------------------------------------------------------------------------


def _descend(log: _EvalLog, seeds: np.ndarray, objective: str,
             constraints, steps: int) -> tuple[np.ndarray, dict]:
    """Relax the wide numeric axes and descend all seed lanes at once.

    Returns (candidate grid ids near the continuous optima, phase record).
    Gracefully returns no candidates when jax is unavailable, there is
    nothing to relax, or no seeds survived screening.
    """
    enum, lists = log.enum, log.lists
    relaxed = [a for a in _sweep._NUMERIC
               if len(set(map(float, lists[a]))) >= 3]
    record: dict[str, Any] = {"phase": "descend", "lanes": 0, "steps": 0,
                              "relaxed_axes": relaxed}
    if not len(seeds) or not relaxed or steps < 1:
        record["skipped"] = "no seeds" if not len(seeds) else "no relaxed axes"
        return np.empty(0, dtype=np.int64), record
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.optim.adamw import (
            OptimizerConfig,
            adamw_init,
            adamw_update,
        )
    except ImportError:                      # pragma: no cover — jax baked in
        record["skipped"] = "jax unavailable"
        return np.empty(0, dtype=np.int64), record

    S = len(seeds)
    codes = enum.codes(seeds)

    # Per-axis sorted value tables; ``perm`` maps sorted index -> grid code.
    svals, perms, inv = {}, {}, {}
    for a in relaxed:
        vals = np.asarray(lists[a], dtype=np.float64)
        perms[a] = np.argsort(vals, kind="stable")
        svals[a] = vals[perms[a]]
        inv[a] = np.argsort(perms[a])        # grid code -> sorted index

    # Per-lane fixed data (everything that is not being relaxed).
    type_table = [_mb.TYPE_CODE[t] for t in lists["lsu_type"]]
    tc = np.asarray(type_table, dtype=np.int64)[codes["lsu_type"]]
    is_atomic = tc == _mb.ATOMIC
    is_ack = tc == _mb.WRITE_ACK
    fixed_num = {a: np.asarray(lists[a], dtype=np.float64)[codes[a]]
                 for a in _sweep._NUMERIC if a not in relaxed}
    cats = {a: (lists[a], codes[a])
            for a in _sweep.AXES if a in _sweep._CATEGORICAL}
    cats, hw_scale, _ = _sweep._resolve_hardware_codes(cats, S)
    dram_table, dram_idx = cats["dram"]
    bsp_table, bsp_idx = cats["bsp"]
    hwf = {k: np.asarray([getattr(d, k) if d is not None else 0
                          for d in dram_table], dtype=np.float64)[dram_idx]
           for k in ("dq", "bl", "f_mem", "t_rcd", "t_rp", "t_wr")}
    hwf.update({k: np.asarray([getattr(b, k) if b is not None else 0
                               for b in bsp_table],
                              dtype=np.float64)[bsp_idx]
                for k in ("burst_cnt", "max_th")})
    max_txn = max_transaction_bytes(hwf["dq"], hwf["bl"], hwf["burst_cnt"])
    caps = envelope_caps(constraints)
    kernel = np.concatenate([np.arange(S), np.arange(S)])

    def lane_values(params):
        v = {a: jnp.asarray(x) for a, x in fixed_num.items()}
        for a in relaxed:
            u = jnp.clip(params[a], 0.0, len(svals[a]) - 1.0)
            v[a] = jnp.interp(u, jnp.arange(len(svals[a]), dtype=jnp.float64),
                              jnp.asarray(svals[a]))
        return v

    def loss_fn(params):
        v = lane_values(params)
        n_ga, simd, n_elems = v["n_ga"], v["simd"], v["n_elems"]
        eb = v["elem_bytes"]
        iw = jnp.asarray(v["include_write"], dtype=bool) & ~is_atomic
        vc = jnp.asarray(v["val_constant"], dtype=bool) & is_atomic
        delta = jnp.where(is_atomic | is_ack, 1.0, v["delta"])
        # The exact two-group expansion _score builds, in float.
        g1_type = np.where(is_ack, _mb.ALIGNED, tc)
        g1_count = jnp.where(is_atomic | is_ack, n_ga, n_ga + iw)
        g1_width = jnp.where(is_atomic, eb, simd * eb)
        g1_acc = jnp.where(is_atomic, n_elems, n_elems / simd)
        g2_count = jnp.where(is_ack & iw, simd, 0.0)
        two = lambda a, b: jnp.concatenate([jnp.asarray(a, dtype=jnp.float64),
                                            jnp.asarray(b, dtype=jnp.float64)])
        batch = _mb.GroupBatch(
            kernel=jnp.asarray(kernel), n_kernels=S,
            count=two(g1_count, g2_count),
            lsu_type=jnp.concatenate([
                jnp.asarray(g1_type),
                jnp.full(S, _mb.WRITE_ACK, dtype=np.int64)]),
            ls_width=two(g1_width, eb), ls_acc=two(g1_acc, n_elems / simd),
            ls_bytes=two(g1_width, eb), delta=two(delta, jnp.ones(S)),
            val_constant=jnp.concatenate([vc, jnp.zeros(S, dtype=bool)]),
            f=two(simd, simd),
            **{k: jnp.asarray(np.concatenate([x, x]))
               for k, x in hwf.items()})
        est = _mb.estimate_batch(batch, xp=jnp)
        if objective == "resource":
            obj = g1_count * g1_width + g2_count * eb
        else:
            obj = getattr(est, objective)
            if objective in ("t_exe", "t_ideal", "t_ovh"):
                obj = obj * hw_scale
        loss = jnp.sum(jnp.log(jnp.maximum(obj, 1e-300)))
        if caps:
            usage = usage_from_axes(
                type_codes=tc, n_ga=n_ga, simd=simd, elem_bytes=eb,
                include_write=iw, max_txn=jnp.asarray(max_txn), xp=jnp)
            for name, cap in caps.items():
                over = jnp.maximum((usage[name] - cap) / max(cap, 1e-300), 0.0)
                loss = loss + _PENALTY_RHO * jnp.sum(over ** 2)
        return loss

    cfg = OptimizerConfig(lr=0.15, warmup_steps=0, total_steps=steps,
                          weight_decay=0.0, clip_norm=1e6, min_lr_ratio=0.2,
                          state_dtype="float32")
    kmax = {a: float(len(svals[a]) - 1) for a in relaxed}

    with enable_x64():
        params = {a: jnp.asarray(inv[a][codes[a]], dtype=jnp.float64)
                  for a in relaxed}
        state = adamw_init(params, cfg)
        vg = jax.value_and_grad(loss_fn)

        @jax.jit
        def step(params, state):
            loss, grads = vg(params)
            params, state, _ = adamw_update(grads, state, params, cfg)
            params = {a: jnp.clip(p, 0.0, kmax[a])
                      for a, p in params.items()}
            return params, state, loss

        losses = []
        for _ in range(steps):
            params, state, loss = step(params, state)
            losses.append(float(loss))
        u_final = {a: np.asarray(params[a], dtype=np.float64)
                   for a in relaxed}

    # Descent evaluations count against the budget: S model rows per step.
    log.spent += S * steps
    log.relaxed_evals += S * steps

    # Snap each lane back to the grid: rounded point + axis-wise floor/ceil.
    base = {a: np.asarray(c) for a, c in codes.items()}
    cands = []

    def snap(u_codes):
        c = dict(base)
        for a in relaxed:
            c[a] = perms[a][u_codes[a]]
        cands.append(log.enum.encode(c))

    rounded = {a: np.clip(np.rint(u_final[a]).astype(np.int64), 0,
                          int(kmax[a])) for a in relaxed}
    snap(rounded)
    for a in relaxed:
        for f in (np.floor, np.ceil):
            variant = dict(rounded)
            variant[a] = np.clip(f(u_final[a]).astype(np.int64), 0,
                                 int(kmax[a]))
            snap(variant)
    record.update(lanes=S, steps=steps, loss_first=losses[0],
                  loss_last=losses[-1])
    return np.unique(np.concatenate(cands)), record


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------


def _report_base():
    from repro import api as _api

    return _api.Report


@dataclasses.dataclass(frozen=True)
class OptimizeReport:
    """What ``Session.optimize`` found, plus the telemetry backing it.

    ``best`` is a full :class:`repro.Estimate` for the winning grid point
    (scored by the same evaluator an exhaustive sweep uses, so it is
    bit-comparable to the grid optimum); ``front`` holds the evaluated
    2-objective Pareto approximation in Pareto mode.  ``n_evals`` counts
    every model row the search paid for — screen, relaxed descent and
    discrete refinement, jax padding included — and ``evals_fraction``
    is the headline <1%-of-the-grid number.
    """

    kind = "optimize"
    objectives: tuple
    backend: str
    n_total: int
    n_evals: int
    n_grid_evals: int
    n_relaxed_evals: int
    n_screened: int
    best_id: int
    best: Any                     # repro.Estimate
    best_config: Mapping[str, Any]
    front_ids: np.ndarray
    front: Mapping[str, np.ndarray]
    trajectory: tuple
    constraints: tuple = ()

    @property
    def evals_fraction(self) -> float:
        return self.n_evals / self.n_total if self.n_total else 0.0

    @property
    def n_front(self) -> int:
        return len(self.front_ids)

    def rows(self) -> list[dict]:
        """One dict per front point (the best point alone in scalar mode)."""
        cols = self.front
        out = []
        for i in range(len(self.front_ids)):
            row = {"id": int(self.front_ids[i])}
            for a in _sweep.AXES:
                v = cols[a][i]
                row[a] = _cat_label(v) if a in _sweep._CATEGORICAL else v
            for o in ("t_exe", "resource"):
                row[o] = float(cols[o][i])
            for o in self.objectives:
                row[o] = float(cols[o][i])
            out.append(row)
        return out

    def to_csv(self) -> str:
        return _report_base().to_csv(self)

    def summary(self) -> dict:
        return {
            "kind": self.kind,
            "objectives": list(self.objectives),
            "backend": self.backend,
            "n_total": self.n_total,
            "n_evals": self.n_evals,
            "n_grid_evals": self.n_grid_evals,
            "n_relaxed_evals": self.n_relaxed_evals,
            "n_screened": self.n_screened,
            "evals_fraction": self.evals_fraction,
            "best_id": self.best_id,
            "best_t_exe": self.best.t_exe,
            "best_" + self.objectives[0]: float(
                np.asarray(self.front[self.objectives[0]]).min())
            if len(self.front_ids) else None,
            "n_front": self.n_front,
            "phases": [dict(t) for t in self.trajectory],
        }


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------


def run_optimize(session, space, *, objective="t_exe", constraints=(),
                 seed: int = 0, max_evals: int | None = None,
                 n_starts: int = 2, steps: int = 16,
                 screen: int | None = None,
                 chunk_size: int | None = None) -> OptimizeReport:
    """The engine behind ``Session.optimize`` (see its docstring).

    Free function of (session, space) so tests can drive phases with
    explicit budgets; always returns an :class:`OptimizeReport`.
    """
    from repro import api as _api

    objectives = ((objective,) if isinstance(objective, str)
                  else tuple(objective))
    if not 1 <= len(objectives) <= 2:
        raise ValueError("objective must be one column or a pair of columns")
    for o in objectives:
        if o not in OBJECTIVE_COLUMNS:
            raise ValueError(f"unknown objective {o!r}: pick from "
                             f"{OBJECTIVE_COLUMNS}")
    primary = objectives[0]
    pareto_mode = len(objectives) == 2

    cons = normalize_constraints(constraints)
    plan = session.plan(space, chunk_size=chunk_size)
    n = plan.n
    if n == 0:
        raise ValueError("cannot optimize an empty space")
    budget = int(max_evals) if max_evals is not None else max(1024, n // 128)
    if budget < 1:
        raise ValueError("max_evals must be >= 1")
    log = _EvalLog(plan, cons, budget)
    enum = log.enum
    rng = np.random.default_rng(seed)
    trajectory: list[dict] = []

    if n <= budget:
        # Small grid: the budget covers exhaustive evaluation — be exact.
        scored = log.evaluate(np.arange(n, dtype=np.int64))
        if scored == 0 and cons:
            raise ValueError(
                "Session.optimize: constraints eliminated every point of "
                f"the {n}-point grid; relax the constraints or widen the "
                "space")
        trajectory.append({"phase": "exhaustive", "evals": scored})
        n_screened = scored
    else:
        # Phase 1: seeded feasible screen (rejection sampling on the grid).
        target = (int(screen) if screen is not None
                  else min(1024, max(128, budget // 8)))
        target = min(target, budget)
        feas: list[np.ndarray] = []
        found, drawn = 0, 0
        attempts = max(50_000, 64 * target)
        while found < target and drawn < attempts:
            batch = rng.integers(0, n, size=min(4 * target, attempts - drawn))
            drawn += len(batch)
            keep = log.feasible(np.unique(batch))
            if len(keep):
                feas.append(keep)
                found += len(keep)
        if not found:
            raise ValueError(
                "Session.optimize: no feasible point in the first "
                f"{drawn} seeded probes of the {n}-point grid; relax the "
                "constraints or widen the space")
        screened = np.unique(np.concatenate(feas))[:target]
        log.evaluate(screened)
        n_screened = len(screened)
        trajectory.append({"phase": "screen", "probes": drawn,
                           "feasible": int(found), "evals": n_screened})

        # Phase 2: lane seeds = best screened point(s) per categorical
        # combination (plus the narrow numeric axes descent cannot move).
        cols = log.columns()
        relaxed = {a for a in _sweep._NUMERIC
                   if len(set(map(float, log.lists[a]))) >= 3}
        key_axes = [a for a in _sweep.AXES if a not in relaxed]
        ids_sorted = np.asarray(cols["id"])[np.argsort(
            np.asarray(cols[primary], dtype=np.float64), kind="stable")]
        lane_cap = max(int(n_starts), int(0.4 * budget) // max(steps, 1))
        per_lane: dict[tuple, int] = {}
        seeds = []
        key_codes = enum.codes(ids_sorted)
        for i, pid in enumerate(ids_sorted.tolist()):
            key = tuple(int(key_codes[a][i]) for a in key_axes)
            if per_lane.get(key, 0) >= int(n_starts):
                continue
            per_lane[key] = per_lane.get(key, 0) + 1
            seeds.append(pid)
            if len(seeds) >= lane_cap:
                break
        seeds = np.asarray(seeds, dtype=np.int64)

        cands, record = _descend(log, seeds, primary, cons, steps)
        trajectory.append(record)
        if len(cands):
            scored = log.evaluate(cands)
            trajectory.append({"phase": "refine-snap", "candidates":
                               len(cands), "evals": scored})

        # Phase 3: greedy ±1-code coordinate descent from the incumbent.
        polish_evals, rounds = 0, 0
        while log.remaining > 0:
            b = log.argbest(primary)
            if b is None:
                break
            best_id = int(log.columns()["id"][b])
            best_val = float(log.columns()[primary][b])
            scored = log.evaluate(_neighbor_ids(enum, np.asarray([best_id])))
            polish_evals += scored
            rounds += 1
            nb = log.argbest(primary)
            if nb is None or float(log.columns()[primary][nb]) >= best_val:
                break
        trajectory.append({"phase": "polish", "rounds": rounds,
                           "evals": polish_evals})

        # Phase 4: Pareto local search — walk the front's neighbors until
        # it stops moving (2-objective mode only).
        if pareto_mode:
            pls_evals, rounds = 0, 0
            prev: frozenset = frozenset()
            while log.remaining > 0 and rounds < 16:
                fidx = log.front(objectives)
                fids = np.asarray(log.columns()["id"])[fidx]
                if frozenset(fids.tolist()) == prev:
                    break
                prev = frozenset(fids.tolist())
                scored = log.evaluate(_neighbor_ids(enum, fids))
                pls_evals += scored
                rounds += 1
                if scored == 0:
                    break
            trajectory.append({"phase": "pareto-local-search",
                               "rounds": rounds, "evals": pls_evals})

    cols = log.columns()
    if not cols or not len(cols["id"]):
        raise ValueError("Session.optimize: the evaluation budget "
                         f"({budget}) was too small to score any feasible "
                         "point; raise max_evals")
    b = log.argbest(primary)
    best_id = int(cols["id"][b])
    best = _api.Estimate(
        t_exe=float(cols["t_exe"][b]), t_ideal=float(cols["t_ideal"][b]),
        t_ovh=float(cols["t_ovh"][b]),
        bound_ratio=float(cols["bound_ratio"][b]),
        memory_bound=bool(cols["memory_bound"][b]),
        total_bytes=float(cols["total_bytes"][b]),
        n_lsu=int(cols["n_lsu"][b]), backend=plan.backend)

    tables = plan.tables()
    def config_at(rows: np.ndarray) -> dict[str, np.ndarray]:
        out = {}
        for a in _sweep.AXES:
            if a in _sweep._CATEGORICAL:
                out[a] = _sweep._object_array(tables[a])[
                    np.asarray(cols[a], dtype=np.int64)[rows]]
            else:
                out[a] = np.asarray(cols[a])[rows]
        return out

    front_rows = (log.front(objectives) if pareto_mode
                  else np.asarray([b], dtype=np.int64))
    front_cols = config_at(front_rows)
    for name in OBJECTIVE_COLUMNS:
        front_cols[name] = np.asarray(cols[name])[front_rows]
    best_cfg = {a: v[0] for a, v in config_at(
        np.asarray([b], dtype=np.int64)).items()}

    return OptimizeReport(
        objectives=objectives, backend=plan.backend, n_total=n,
        n_evals=log.spent, n_grid_evals=log.grid_evals,
        n_relaxed_evals=log.relaxed_evals,
        n_screened=n_screened, best_id=best_id, best=best,
        best_config=best_cfg,
        front_ids=np.asarray(cols["id"])[front_rows].astype(np.int64),
        front=front_cols, trajectory=tuple(trajectory), constraints=cons)
