"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM — linear matrix-memory recurrence per head:

    C_t = f_t C_{t-1} + i_t v_t k_t^T        (d_k x d_v matrix state)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, 1)

Training runs the **chunkwise-parallel** form: the sequence is split into
chunks of ``cfg.chunk_size``; within a chunk the contribution is an
attention-like masked matmul with cumulative log-decay weights, across chunks
the (C, n) state is carried by a ``lax.scan``.  Gating uses
``f_t = sigmoid(f̃_t)`` / ``i_t = sigmoid(ĩ_t)`` (the log-space cumulative
decays are then always <= 0, so the chunked form is overflow-free; the
original exp-input-gating with running max stabilizer is a documented
simplification — see DESIGN.md).  A strictly sequential reference
(`mlstm_sequential`) validates the chunked form in tests and serves decode.

sLSTM — scalar memory with exponential gating and normalizer state; its
recurrence reads h_{t-1} into the gates, so it is inherently sequential and
runs as a ``lax.scan`` over time (the TPU adaptation note in DESIGN.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.pspec import shard


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    d_in = int(d * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    dh = d_in // H
    ks = jax.random.split(key, 8)
    lim = 1.0 / math.sqrt(dh)
    return {
        "up": {"w": L.dense_init(ks[0], d, d_in, pd)},
        "up_gate": {"w": L.dense_init(ks[1], d, d_in, pd)},
        # per-head q,k,v maps (dh x dh), applied within heads
        "wq": jax.random.normal(ks[2], (H, dh, dh), pd) * lim,
        "wk": jax.random.normal(ks[3], (H, dh, dh), pd) * lim,
        "wv": jax.random.normal(ks[4], (H, dh, dh), pd) * lim,
        # scalar i/f gates per head from the block input
        "wif": {"w": L.dense_init(ks[5], d, 2 * H, pd)},
        "ln_heads": L.norm_params(dh, "rmsnorm"),
        # head-split (H, dh, d) layout: the down-projection contracts in
        # split form, so the dh-sharded heads never flatten (the flatten
        # all-gathered 25.8 GB x 48 on the 32k prefill — SPerf Cell C)
        "down": {"w": L.dense_init(ks[6], d_in, d, pd).reshape(H, dh, d)},
    }


def _mlstm_qkvif(p: dict, cfg: ModelConfig, x: jax.Array):
    """Project block input to per-head q,k,v and scalar gate logits.

    Sharding: the head count is small (4), so heads stay replicated and the
    *head feature* dim ``dh`` carries the tensor-parallel axis ("ff" rule).
    q/k are kept replicated over dh (they contract against the sharded
    matrix-memory state); v and the state's value dim shard over "ff"."""
    B, S, d = x.shape
    H = cfg.n_heads
    u = L.dense(p["up"], x)                       # (B,S,d_in)
    gate = jax.nn.silu(L.dense(p["up_gate"], x))
    dh = u.shape[-1] // H
    uh = u.reshape(B, S, H, dh)
    uh = shard(uh, "batch", "seq", None, "mlstm_dh")
    q = jnp.einsum("bshd,hde->bshe", uh, p["wq"].astype(x.dtype))
    k = jnp.einsum("bshd,hde->bshe", uh, p["wk"].astype(x.dtype)) / math.sqrt(dh)
    v = jnp.einsum("bshd,hde->bshe", uh, p["wv"].astype(x.dtype))
    q = shard(q, "batch", "seq", None, None)      # replicated dh
    k = shard(k, "batch", "seq", None, None)
    v = shard(v, "batch", "seq", None, "mlstm_dh")  # sharded value dim
    gates = L.dense(p["wif"], x).astype(jnp.float32)      # (B,S,2H)
    li = jax.nn.log_sigmoid(gates[..., :H])               # log i_t  (<= 0)
    lf = jax.nn.log_sigmoid(gates[..., H:])               # log f_t  (<= 0)
    return q, k, v, li, lf, gate


def mlstm_forward(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Chunkwise-parallel full-sequence mLSTM.  x: (B,S,d)."""
    B, S, d = x.shape
    H = cfg.n_heads
    q, k, v, li, lf, gate = _mlstm_qkvif(p, cfg, x)
    dh = q.shape[-1]
    if cfg.use_pallas:
        from repro.kernels.mlstm_chunk.ops import chunked_mlstm
        h = chunked_mlstm(q, k, v, li, lf, chunk=cfg.chunk_size)
        h = L.apply_norm(p["ln_heads"], h, "rmsnorm")
        h = h * gate.reshape(B, S, H, dh)
        h = shard(h, "batch", "seq", None, "mlstm_dh")
        return jnp.einsum("bshd,hde->bse", h, p["down"]["w"].astype(x.dtype))
    c = min(cfg.chunk_size, S)
    assert S % c == 0, (S, c)
    n_chunks = S // c

    def to_chunks(a):
        return a.reshape(B, n_chunks, c, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = map(to_chunks, (q, k, v))        # (n, B, c, H, dh)
    lic, lfc = map(to_chunks, (li, lf))           # (n, B, c, H)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)

    def chunk_step(carry, inp):
        C, n = carry
        qb, kb, vb, lib, lfb = inp                # (B,c,H,dh), (B,c,H)
        vb = shard(vb, "batch", None, None, "mlstm_dh")
        cum = jnp.cumsum(lfb, axis=1)             # (B,c,H)  log decay since chunk start
        total = cum[:, -1]                        # (B,H)
        # inter-chunk: state contribution decayed to each position
        qbf = qb.astype(jnp.float32)
        inter = jnp.einsum("bchd,bhde->bche", qbf * jnp.exp(cum)[..., None], C)
        n_inter = jnp.einsum("bchd,bhd->bch", qbf * jnp.exp(cum)[..., None], n)
        # intra-chunk: masked attention-like term with decay cum_i - cum_j + li_j
        w_log = (cum[:, :, None, :] - cum[:, None, :, :]
                 + lib[:, None, :, :])            # (B,c_i,c_j,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(w_log), 0.0)
        s = jnp.einsum("bihd,bjhd->bijh", qbf, kb.astype(jnp.float32)) * w
        intra = jnp.einsum("bijh,bjhd->bihd", s, vb.astype(jnp.float32))
        # normalizer: n_i = decayed state part + sum_j w_ij k_j
        n_intra = jnp.einsum("bijh,bjhd->bihd", w, kb.astype(jnp.float32))
        num = inter + intra                       # (B,c,H,dh)
        den = n_inter + jnp.einsum("bchd,bchd->bch", qbf, n_intra)
        hb = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # carry update
        decay_to_end = jnp.exp(total[:, None, :] - cum + lib)   # (B,c,H) weight per j
        kw = kb.astype(jnp.float32) * decay_to_end[..., None]
        C_new = C * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bchd,bche->bhde", kw, vb.astype(jnp.float32))
        C_new = shard(C_new, "batch", None, None, "mlstm_dh")
        n_new = n * jnp.exp(total)[..., None] + kw.sum(axis=1)
        return (C_new, n_new), shard(hb.astype(x.dtype),
                                     "batch", None, None, "mlstm_dh")

    C0 = shard(C0, "batch", None, None, "mlstm_dh")
    (_, _), hs = jax.lax.scan(chunk_step, (C0, n0), (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    h = L.apply_norm(p["ln_heads"], h, "rmsnorm")
    h = h * gate.reshape(B, S, H, dh)
    h = shard(h, "batch", "seq", None, "mlstm_dh")
    return jnp.einsum("bshd,hde->bse", h, p["down"]["w"].astype(x.dtype))


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d_in = int(cfg.d_model * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    dh = d_in // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
    }


def mlstm_decode_step(p: dict, cfg: ModelConfig, x: jax.Array, state: dict
                      ) -> tuple[jax.Array, dict]:
    """One-token mLSTM update.  x: (B,1,d)."""
    B = x.shape[0]
    H = cfg.n_heads
    q, k, v, li, lf, gate = _mlstm_qkvif(p, cfg, x)
    dh = q.shape[-1]
    i = jnp.exp(li[:, 0])                          # (B,H)
    f = jnp.exp(lf[:, 0])
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    qf = q[:, 0].astype(jnp.float32)
    C = state["C"] * f[..., None, None] + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", kf, vf)
    n = state["n"] * f[..., None] + i[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    h = (num / den[..., None])
    h = L.apply_norm(p["ln_heads"], h, "rmsnorm")
    h = h[:, None].astype(x.dtype) * gate.reshape(B, 1, H, dh)
    out = jnp.einsum("bshd,hde->bse", h, p["down"]["w"].astype(x.dtype))
    return out, {"C": C, "n": n}


def mlstm_sequential(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Step-by-step oracle for the chunked form (tests)."""
    B, S, d = x.shape
    state = mlstm_init_state(cfg, B)
    H = cfg.n_heads
    q, k, v, li, lf, gate = _mlstm_qkvif(p, cfg, x)

    def step(carry, inp):
        C, n = carry
        qf, kf, vf, lit, lft = inp
        i = jnp.exp(lit)
        f = jnp.exp(lft)
        C = C * f[..., None, None] + i[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kf, vf)
        n = n * f[..., None] + i[..., None] * kf
        num = jnp.einsum("bhd,bhde->bhe", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
        return (C, n), num / den[..., None]

    xs = (q.swapaxes(0, 1).astype(jnp.float32), k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32), li.swapaxes(0, 1), lf.swapaxes(0, 1))
    _, hs = jax.lax.scan(step, (state["C"], state["n"]), xs)
    h = hs.swapaxes(0, 1)                         # (B,S,H,dh)
    h = L.apply_norm(p["ln_heads"], h, "rmsnorm")
    dh = h.shape[-1]
    h = h.astype(x.dtype) * gate.reshape(B, S, -1, dh)
    return jnp.einsum("bshd,hde->bse", h, p["down"]["w"].astype(x.dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w": {"w": L.dense_init(ks[0], d, 4 * d, pd)},     # i,f,z,o from x
        "r": jnp.zeros((4, d), pd),                         # diagonal recurrent
        "conv": jax.random.normal(ks[1], (cfg.conv_width, d), pd)
                / math.sqrt(cfg.conv_width),
        "b": jnp.zeros((4 * d,), pd),
    }


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z() - 10.0,
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d), jnp.bfloat16)}


def _slstm_cell(p: dict, gates: jax.Array, state: tuple):
    """One sLSTM step.  ``gates``: (B, 4d) pre-computed input projection
    (x @ W + b is hoisted out of the recurrence — it does not depend on
    h_{t-1}, and leaving it inside the scan emits one tensor-parallel psum
    per *timestep*: 3.1M collectives on the 32k-prefill cell,
    EXPERIMENTS.md SPerf).  Only the diagonal recurrent term stays inside."""
    c, n, h, m = state
    gi, gf, gz, go = gates      # pre-split outside the scan: slicing the
    # rnn-sharded (B,4d) projection inside the loop emitted one collective-
    # permute per gate per timestep (1.9M+1.2M permutes on the 32k cell)
    r = p["r"].astype(jnp.float32)
    gi = gi + r[0] * h
    gf = gf + r[1] * h
    gz = gz + r[2] * h
    go = go + r[3] * h
    m_new = jnp.maximum(gf + m, gi)               # exponential-gating stabilizer
    i = jnp.exp(gi - m_new)
    f = jnp.exp(gf + m - m_new)
    c = shard(f * c + i * jnp.tanh(gz), "batch", "rnn")
    n = f * n + i
    h = shard(jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6), "batch", "rnn")
    return (c, n, h, m_new), h


def slstm_forward(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Sequential full-sequence sLSTM.  x: (B,S,d)."""
    B, S, d = x.shape
    from repro.models.recurrent import _conv
    u, _ = _conv({"conv": p["conv"]}, x)
    # hoisted input projection: one big matmul for the whole sequence
    gates_x = (u.astype(jnp.float32) @ p["w"]["w"].astype(jnp.float32)
               + p["b"].astype(jnp.float32))      # (B,S,4d)
    parts = []
    for j in range(4):                            # pre-split + reshard once
        gj = gates_x[:, :, j * d:(j + 1) * d]
        parts.append(shard(gj, "batch", "seq", "rnn").swapaxes(0, 1))
    st = slstm_init_state(cfg, B)

    def step(carry, gt):
        new, h = _slstm_cell(p, gt, carry)
        return new, h

    _, hs = jax.lax.scan(step, (st["c"], st["n"], st["h"], st["m"]),
                         tuple(parts))
    return hs.swapaxes(0, 1).astype(x.dtype)


def slstm_decode_step(p: dict, cfg: ModelConfig, x: jax.Array, state: dict
                      ) -> tuple[jax.Array, dict]:
    from repro.models.recurrent import _conv
    u, conv_state = _conv({"conv": p["conv"]}, x, state["conv"].astype(x.dtype))
    gates = (u[:, 0].astype(jnp.float32) @ p["w"]["w"].astype(jnp.float32)
             + p["b"].astype(jnp.float32))
    d = x.shape[-1]
    gsplit = tuple(gates[:, j * d:(j + 1) * d] for j in range(4))
    (c, n, h, m), out = _slstm_cell(p, gsplit,
                                    (state["c"], state["n"], state["h"], state["m"]))
    return out[:, None, :].astype(x.dtype), {
        "c": c, "n": n, "h": h, "m": m, "conv": conv_state}
