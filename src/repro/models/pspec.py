"""Logical-axis sharding annotations for model code.

Model code tags activations with *logical* axis names; the launcher installs
a rules table mapping logical names to mesh axes.  Outside a mesh context the
helpers are no-ops, so the same model code runs in single-device tests and in
the 512-chip dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _rules() -> dict[str, tuple[str, ...] | str | None]:
    return getattr(_STATE, "rules", None) or {}


def _mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, rules: dict[str, tuple[str, ...] | str | None]):
    """Install logical->mesh axis rules for the enclosed region."""
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "rules", None))
    _STATE.mesh, _STATE.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def logical_to_spec(names: Sequence[str | None]) -> P:
    rules = _rules()
    spec = []
    for n in names:
        if n is None:
            spec.append(None)
        else:
            spec.append(rules.get(n))
    return P(*spec)


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op without mesh)."""
    mesh = _mesh()
    if mesh is None:
        return x
    if x.ndim != len(names):
        raise ValueError(f"rank {x.ndim} vs {len(names)} logical names")
    spec = logical_to_spec(names)
    # Keep the assignment when the dim is at least the axis size (GSPMD
    # shards unevenly with padding — e.g. a 92553 vocab over 16 chips); drop
    # it only when the dim is *smaller* than the axis (degenerate padding).
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            fixed.append(None)
            continue
        n = 1
        for a in ((s,) if isinstance(s, str) else s):
            n *= sizes.get(a, 1)
        fixed.append(s if dim >= n else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def rule_axis_size(name: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 without mesh)."""
    mesh = _mesh()
    if mesh is None:
        return 1
    r = _rules().get(name)
    if r is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ((r,) if isinstance(r, str) else r):
        n *= sizes.get(a, 1)
    return n
