"""Dense MLP (GLU or plain two-layer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.pspec import shard


def init(key, cfg: ModelConfig, *, d_in: int | None = None,
         d_ff: int | None = None) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {
        "wi": {"w": L.dense_init(ks[0], d, f, pd)},
        "wo": {"w": L.dense_init(ks[2], f, d, pd)},
    }
    if cfg.glu:
        p["wg"] = {"w": L.dense_init(ks[1], d, f, pd)}
    if cfg.mlp_bias:
        p["wi"]["b"] = jnp.zeros((f,), pd)
        p["wo"]["b"] = jnp.zeros((d,), pd)
        if cfg.glu:
            p["wg"]["b"] = jnp.zeros((f,), pd)
    return p


def forward(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = L.dense(p["wi"], x)
    if cfg.glu:
        h = L.activate(L.dense(p["wg"], x), cfg.act) * h
    else:
        h = L.activate(h, cfg.act)
    h = shard(h, "batch", "seq", "ff")
    return L.dense(p["wo"], h)
