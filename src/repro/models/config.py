"""Model configuration for every architecture family in the zoo.

A model is a stack of *blocks*; ``block_pattern`` names the per-layer block
kinds and is cycled/structured into scan groups by the transformer driver:

    "attn"       full (GQA) attention + MLP
    "local"      sliding-window attention + MLP
    "rglru"      RG-LRU recurrent block + MLP      (RecurrentGemma/Griffin)
    "mlstm"      mLSTM block (matrix memory, internal up-proj, no MLP)
    "slstm"      sLSTM block (scalar memory + causal conv, post-FFN)

The pattern is repeated ``n_layers / len(pattern)`` times when it divides
evenly; otherwise ``pattern_repeats`` full repeats are scanned and the
remainder is applied unscanned (RecurrentGemma's 38 = 12x(R,R,A) + (R,R)).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    # block structure
    block_pattern: tuple[str, ...] = ("attn",)
    remainder_pattern: tuple[str, ...] = ()
    # attention
    qkv_bias: bool = False
    o_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 2048
    is_decoder: bool = True
    use_qk_norm: bool = False
    logit_softcap: float = 0.0       # grok-style tanh soft-capping (0 = off)
    # MLP
    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU or plain)
    glu: bool = True
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    moe_impl: str = "einsum"         # einsum (SPMD-native) | sort (gather)
    # recurrent (hybrid / ssm)
    d_rnn: int = 0                   # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 256            # chunkwise-parallel recurrence chunk
    # embeddings / head
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    frontend: str | None = None      # None | audio | vision
    frontend_dim: int = 0            # raw feature dim of the stubbed frontend
    # numerics / runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_cache_dtype: str = ""         # "" -> dtype; "float8_e4m3fn" halves KV traffic
    scan_layers_decode: bool = True  # False: unroll decode layers so cache
                                     # updates alias in place (the layer-scan
                                     # ys-stacking copies the whole cache
                                     # every token — EXPERIMENTS.md SPerf)
    remat: bool = True
    attn_block_q: int = 512          # chunked-attention tile sizes (XLA path)
    attn_block_kv: int = 1024
    use_pallas: bool = False         # TPU runs flip this; dry-run/CPU keep XLA

    # ----- derived -----
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so embedding/head parameters
        shard evenly on any production mesh (standard practice; the logical
        ``vocab_size`` is unchanged — padded rows only see the logsumexp
        gradient)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pattern_repeats(self) -> int:
        return (self.n_layers - len(self.remainder_pattern)) // len(self.block_pattern)

    def __post_init__(self):
        used = (self.pattern_repeats * len(self.block_pattern)
                + len(self.remainder_pattern))
        if used != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern {self.block_pattern} x "
                f"{self.pattern_repeats} + {self.remainder_pattern} != "
                f"{self.n_layers} layers")
        if self.is_moe and self.experts_per_token <= 0:
            raise ValueError(f"{self.name}: MoE needs experts_per_token")

    @property
    def block_kinds(self) -> tuple[str, ...]:
        """Flat per-layer kinds (scan repeats + remainder)."""
        return self.block_pattern * self.pattern_repeats + self.remainder_pattern

    @property
    def has_attention(self) -> bool:
        return any(k in ("attn", "local") for k in self.block_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if no *full* attention blocks (long-context capable)."""
        return "attn" not in self.block_kinds

    # ----- parameter counting (for MODEL_FLOPS and memory budgeting) -----
    def param_count(self) -> int:
        return sum(self._params_per_block(k) for k in self.block_kinds) + self._embed_params()

    def active_param_count(self) -> int:
        total = self._embed_params()
        for k in self.block_kinds:
            p = self._params_per_block(k)
            if k == "attn" or k == "local":
                if self.is_moe:
                    dense = self._attn_params()
                    moe_active = (self.experts_per_token * 3 * self.d_model * self.d_ff
                                  + self.n_experts * self.d_model)
                    p = dense + moe_active + 2 * self.d_model
            total += p
        return total

    def _embed_params(self) -> int:
        n = self.vocab_size * self.d_model  # logical (padding excluded)
        if not self.tie_embeddings:
            n *= 2
        if self.frontend:
            n += self.frontend_dim * self.d_model
        return n + self.d_model  # final norm

    def _attn_params(self) -> int:
        return (self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim
                + self.q_dim * self.d_model)

    def _mlp_params(self) -> int:
        if self.is_moe:
            return (self.n_experts * 3 * self.d_model * self.d_ff
                    + self.n_experts * self.d_model)
        mats = 3 if self.glu else 2
        return mats * self.d_model * self.d_ff

    def _params_per_block(self, kind: str) -> int:
        norms = 2 * self.d_model
        if kind in ("attn", "local"):
            return self._attn_params() + self._mlp_params() + norms
        if kind == "rglru":
            w = self.rnn_width
            rec = (2 * self.d_model * w            # in/gate projections
                   + w * self.conv_width           # temporal conv
                   + 2 * w                         # RG-LRU gates (diagonal)
                   + w * self.d_model)             # out projection
            return rec + self._mlp_params() + norms
        if kind == "mlstm":
            d_in = int(self.d_model * self.mlstm_proj_factor)
            return (self.d_model * 2 * d_in        # up projections (x, gate)
                    + 3 * d_in * d_in // max(1, self.n_heads)  # q,k,v per-head
                    + 3 * d_in                     # i,f,o gate vectors
                    + d_in * self.d_model          # down projection
                    + norms)
        if kind == "slstm":
            d_ff = int(self.d_model * self.slstm_proj_factor)
            return (4 * self.d_model * self.d_model  # i,f,z,o projections
                    + self.d_model * self.conv_width
                    + 2 * self.d_model * d_ff
                    + norms)
        raise ValueError(f"unknown block kind {kind!r}")

    def model_flops(self, tokens: int, *, training: bool) -> float:
        """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
        n = self.active_param_count() if self.is_moe else self.param_count()
        return (6.0 if training else 2.0) * n * tokens

    def model_bytes(self, tokens: int, *, kind: str, batch: int = 1,
                    seq_len: int = 0) -> float:
        """MODEL_BYTES: algorithmic-minimum global HBM traffic per step —
        the memory-side MODEL_FLOPS analogue used for the roofline's
        useful-bytes ratio.

        train:   active params read fwd+bwd (bf16) + grads written (f32) +
                 full params + moments updated (f32/bf16 mix ~16 B/param) +
                 one activation r/w per block boundary + logits.
        decode:  active params read once + the attention KV cache streamed
                 once + recurrent states.
        prefill: params read + per-block activation traffic (KV written).
        """
        n_act = self.active_param_count() if self.is_moe else self.param_count()
        n_tot = self.param_count()
        d = self.d_model
        L = self.n_layers
        act_rw = 4.0 * tokens * d * 2.0 * L          # x r/w per block fwd+bwd
        logits = 2.0 * tokens * self.padded_vocab * 2.0
        if kind == "train":
            return (4.0 * n_act                      # bf16 fwd+bwd weight reads
                    + 20.0 * n_tot                   # f32 grads + opt update
                    + act_rw + logits)
        if kind in ("decode", "long_decode"):
            kv = 0.0
            n_attn = sum(1 for k in self.block_kinds if k == "attn")
            n_local = sum(1 for k in self.block_kinds if k == "local")
            window = min(self.local_window, seq_len or self.local_window)
            kv = (2.0 * batch * self.n_kv_heads * self.head_dim * 2.0
                  * (n_attn * (seq_len or 0) + n_local * window))
            state = 0.0
            for k in self.block_kinds:
                if k == "rglru":
                    state += 4.0 * batch * self.rnn_width * 2
                elif k == "mlstm":
                    dh = int(d * self.mlstm_proj_factor) // max(1, self.n_heads)
                    state += 4.0 * batch * self.n_heads * dh * dh * 2
                elif k == "slstm":
                    state += 4.0 * batch * d * 8
            return 2.0 * n_act + kv + state + 2.0 * batch * self.padded_vocab * 2
        # prefill
        return 2.0 * n_act + act_rw / 2.0 + logits
