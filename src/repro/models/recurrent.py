"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block:  x -> [in-proj -> causal conv(4) -> RG-LRU] * gelu(gate-proj) -> out-proj

RG-LRU cell (Griffin Eq. 1-4, diagonal gates):
    r_t = sigmoid(w_r * x_t + b_r)                    recurrence gate
    i_t = sigmoid(w_i * x_t + b_i)                    input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))          per-channel decay
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (log-depth);
decode is the O(1) per-token update.  The temporal conv is realized as four
shifted adds (TPU-friendly; no convolution op), with the last three inputs
carried as decode state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.pspec import shard

_C = 8.0  # Griffin's fixed gate sharpness


def init(key, cfg: ModelConfig) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    w = cfg.rnn_width
    ks = jax.random.split(key, 5)
    # Lambda init so that a^c in ~(0.9, 0.999) (Griffin A.2)
    lam = jax.random.uniform(ks[4], (w,), pd, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(jnp.exp(jnp.log(-jnp.log(lam)) / _C)))  # softplus^-1
    return {
        "wx": {"w": L.dense_init(ks[0], cfg.d_model, w, pd)},
        "wgate": {"w": L.dense_init(ks[1], cfg.d_model, w, pd)},
        "conv": jax.random.normal(ks[2], (cfg.conv_width, w), pd) / math.sqrt(cfg.conv_width),
        "gate_r": jnp.zeros((2, w), pd),   # [w_r, b_r] diagonal
        "gate_i": jnp.zeros((2, w), pd),
        "lam": lam,
        "wo": {"w": L.dense_init(ks[3], w, cfg.d_model, pd)},
    }


def _decay_and_input(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-step (a_t, b_t) of the affine recurrence h = a*h + b.  f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["gate_r"][0] + p["gate_r"][1])
    i = jax.nn.sigmoid(xf * p["gate_i"][0] + p["gate_i"][1])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xf)
    return a, b


def _conv(p: dict, x: jax.Array, state: jax.Array | None = None):
    """Causal temporal conv of width W as shifted adds.

    x: (B, S, w).  ``state``: (B, W-1, w) trailing inputs from the previous
    call (decode); returns (y, new_state)."""
    W = p["conv"].shape[0]
    kern = p["conv"].astype(x.dtype)
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)         # (B, W-1+S, w)
    y = jnp.zeros_like(x)
    S = x.shape[1]
    for j in range(W):
        y = y + xx[:, j:j + S, :] * kern[W - 1 - j]
    new_state = xx[:, -(W - 1):, :]
    return y, new_state


def forward(p: dict, cfg: ModelConfig, x: jax.Array,
            h0: jax.Array | None = None) -> jax.Array:
    """Full-sequence block forward (training / prefill).  x: (B, S, d)."""
    B, S, _ = x.shape
    u = L.dense(p["wx"], x)
    gate = jax.nn.gelu(L.dense(p["wgate"], x))
    u, _ = _conv(p, u)
    u = shard(u, "batch", "seq", "rnn")
    a, b = _decay_and_input(p, u)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    if cfg.use_pallas:
        from repro.kernels.rglru.ops import scan as rglru_kernel_scan
        h = rglru_kernel_scan(a, b).astype(jnp.float32)
    else:
        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype) * gate
    h = shard(h, "batch", "seq", "rnn")
    return L.dense(p["wo"], h)


def init_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rnn_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.bfloat16),
    }


def decode_step(p: dict, cfg: ModelConfig, x: jax.Array, state: dict
                ) -> tuple[jax.Array, dict]:
    """One-token update.  x: (B, 1, d)."""
    u = L.dense(p["wx"], x)
    gate = jax.nn.gelu(L.dense(p["wgate"], x))
    u, conv_state = _conv(p, u, state["conv"].astype(u.dtype))
    a, b = _decay_and_input(p, u)
    h = a[:, 0] * state["h"] + b[:, 0]                # (B, w) f32
    out = h.astype(x.dtype)[:, None, :] * gate
    return L.dense(p["wo"], out), {"h": h, "conv": conv_state}
