"""Shared neural-net layers (pure JAX, explicit parameter pytrees).

Conventions:
* params are nested dicts of jnp arrays; layer fns take (params, x, ...).
* activations run in ``cfg.dtype`` (bf16), params kept in ``param_dtype``
  (f32) and cast at use — the standard mixed-precision recipe.
* attention uses a block-streamed online-softmax ("flash in XLA"): a static
  schedule of (q-block, kv-block) pairs is scanned, so the S x S score matrix
  is never materialized and causal/local patterns skip masked blocks
  *structurally* (no wasted FLOPs at the HLO level).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.pspec import shard

Params = dict


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_params(d: int, kind: str = "rmsnorm") -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        out = x * p["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        out = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(kind)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# dense / activation
# ---------------------------------------------------------------------------

def dense(p: Params, x: jax.Array) -> jax.Array:
    w = p["w"].astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: (..., S, H, D); positions: (..., S) int."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq   # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# block-streamed attention ("flash in XLA")
# ---------------------------------------------------------------------------

def _block_schedule(n_q: int, n_kv: int, block_q: int, block_kv: int,
                    *, causal: bool, window: int | None,
                    q_offset: int) -> np.ndarray:
    """Static (qi, kj) pairs whose blocks are not fully masked."""
    pairs = []
    for qi in range(n_q):
        q_lo = q_offset + qi * block_q
        q_hi = q_lo + block_q - 1
        for kj in range(n_kv):
            k_lo = kj * block_kv
            k_hi = k_lo + block_kv - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi < q_lo - window + 1:
                continue
            pairs.append((qi, kj))
    return np.asarray(pairs, dtype=np.int32)


def blocked_attention(
    q: jax.Array,                 # (B, Sq, Hq, D)
    k: jax.Array,                 # (B, Skv, Hkv, D)
    v: jax.Array,                 # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,    # sliding window size (local attention)
    q_offset: int = 0,            # absolute position of q[0] (decode/prefill)
    block_q: int = 512,
    block_kv: int = 1024,
    softcap: float = 0.0,
    kv_len: jax.Array | None = None,  # valid kv length (decode against cache)
    head_axis: str | None = "kv_heads",  # logical axis tag for the Hkv dim
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv

    def tag(x, *names):
        return shard(x, *names) if head_axis else x
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    n_q = -(-Sq // block_q)
    n_kv = -(-Skv // block_kv)
    # pad to block multiples
    pad_q = n_q * block_q - Sq
    pad_kv = n_kv * block_kv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    schedule = _block_schedule(n_q, n_kv, block_q, block_kv,
                               causal=causal, window=window, q_offset=q_offset)
    scale = 1.0 / math.sqrt(D)
    q = (q * scale).reshape(B, n_q, block_q, Hkv, G, D)
    k = k.reshape(B, n_kv, block_kv, Hkv, D)
    v = v.reshape(B, n_kv, block_kv, Hkv, D)
    q = tag(q, "batch", None, None, head_axis, None, None)
    k = tag(k, "batch", None, None, head_axis, None)
    v = tag(v, "batch", None, None, head_axis, None)

    neg = jnp.float32(-1e30)
    acc0 = tag(jnp.zeros((B, n_q, block_q, Hkv, G, D), jnp.float32),
               "batch", None, None, head_axis, None, None)
    m0 = tag(jnp.full((B, n_q, block_q, Hkv, G), neg, jnp.float32),
             "batch", None, None, head_axis, None)
    l0 = tag(jnp.zeros((B, n_q, block_q, Hkv, G), jnp.float32),
             "batch", None, None, head_axis, None)

    q_pos = (q_offset + jnp.arange(n_q * block_q).reshape(n_q, block_q))
    k_pos = jnp.arange(n_kv * block_kv).reshape(n_kv, block_kv)
    kv_limit = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)

    def step(carry, idx):
        acc, m, l = carry
        qi, kj = idx[0], idx[1]
        qb = jax.lax.dynamic_index_in_dim(q, qi, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(k, kj, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(v, kj, 1, keepdims=False)
        # scores: (B, bq, h, g, bk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qb, kb,
                       preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qp = jax.lax.dynamic_index_in_dim(q_pos, qi, 0, keepdims=False)
        kp = jax.lax.dynamic_index_in_dim(k_pos, kj, 0, keepdims=False)
        mask = kp[None, :] < kv_limit
        if causal:
            mask &= kp[None, :] <= qp[:, None]
        if window is not None:
            mask &= kp[None, :] > qp[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, neg)
        m_new = jnp.maximum(m_prev := jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False),
                            s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False) + p.sum(-1)
        acc_prev = jax.lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        acc_new = acc_prev * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, acc_new, qi, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        return (acc, m, l), None

    if len(schedule) == 1:
        (acc, m, l), _ = step((acc0, m0, l0), jnp.asarray(schedule[0]))
    else:
        (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                      jnp.asarray(schedule))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, n_q * block_q, Hq, D)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(v.dtype)


def dense_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    softcap=0.0, kv_len=None) -> jax.Array:
    """Reference unblocked attention (oracle for tests; also used for decode
    where Sq=1 and the score tensor is tiny)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qq = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qq * (1.0 / math.sqrt(D)), k,
                   preferred_element_type=jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask &= kp[None, :] < kv_len
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with z-loss, f32 accumulation.

    Sharding-friendly on a vocab-sharded logits tensor: the label logit is
    extracted with an iota-mask reduction instead of ``take_along_axis``
    (whose data-dependent gather over the sharded axis would force GSPMD to
    all-gather the full f32 logits — measured 24 GB/chip on the 2B VLM cell).
    Every op here is elementwise or a reduction, so XLA keeps the vocab axis
    sharded and emits only scalar-per-token all-reduces."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], shifted, 0.0),
                 axis=-1) + m[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
