"""GQA attention block: projections, RoPE, cache handling, sharding tags."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.pspec import rule_axis_size, shard


def _maybe_repeat_kv(cfg: ModelConfig, k: jax.Array, v: jax.Array):
    """Expand grouped KV to full query heads when the KV-head count cannot
    shard over the tensor-parallel axis.

    Rationale: GSPMD cannot propagate a 16-way head sharding through the
    (Hkv, G) grouping reshape when Hkv doesn't divide the axis — it gives up
    and replicates the whole attention computation (measured 80+ GB/chip).
    Repeating K/V to Hq heads keeps a clean per-head sharding; the repeated
    tensor is itself head-sharded, so per-chip KV bytes stay constant."""
    model = rule_axis_size("heads")
    if model > 1 and cfg.n_kv_heads % model != 0 and cfg.n_heads % model == 0:
        g = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = shard(k, "batch", "seq", "heads", None)
        v = shard(v, "batch", "seq", "heads", None)
    return k, v


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": {"w": L.dense_init(ks[0], cfg.d_model, cfg.q_dim, pd)},
        "wk": {"w": L.dense_init(ks[1], cfg.d_model, cfg.kv_dim, pd)},
        "wv": {"w": L.dense_init(ks[2], cfg.d_model, cfg.kv_dim, pd)},
        "wo": {"w": L.dense_init(ks[3], cfg.q_dim, cfg.d_model, pd)},
    }
    if cfg.qkv_bias:
        p["wq"]["b"] = jnp.zeros((cfg.q_dim,), pd)
        p["wk"]["b"] = jnp.zeros((cfg.kv_dim,), pd)
        p["wv"]["b"] = jnp.zeros((cfg.kv_dim,), pd)
    if cfg.o_bias:
        p["wo"]["b"] = jnp.zeros((cfg.d_model,), pd)
    if cfg.use_qk_norm:
        p["q_norm"] = L.norm_params(cfg.head_dim, "rmsnorm")
        p["k_norm"] = L.norm_params(cfg.head_dim, "rmsnorm")
    return p


def _qkv(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    q = L.dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.use_qk_norm:
        q = L.apply_norm(p["q_norm"], q, "rmsnorm")
        k = L.apply_norm(p["k_norm"], k, "rmsnorm")
    if cfg.is_decoder or cfg.frontend != "audio":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def forward(p: dict, cfg: ModelConfig, x: jax.Array, *,
            local: bool = False) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    k, v = _maybe_repeat_kv(cfg, k, v)
    head_axis = "heads" if k.shape[2] == cfg.n_heads else "kv_heads"
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import mha
        out = mha(q, k, v, causal=cfg.is_decoder,
                  window=cfg.local_window if local else None,
                  softcap=cfg.logit_softcap,
                  block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    else:
        out = L.blocked_attention(
            q, k, v,
            causal=cfg.is_decoder,
            window=cfg.local_window if local else None,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            softcap=cfg.logit_softcap,
            head_axis=head_axis,
        )
    out = shard(out, "batch", "seq", "heads", None)
    return L.dense(p["wo"], out.reshape(B, S, cfg.q_dim))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               local: bool = False) -> dict:
    """KV cache for one attention layer.  Local layers keep a ring buffer of
    ``local_window`` positions; full layers keep ``max_len``."""
    length = min(cfg.local_window, max_len) if local else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    dt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
    }


def decode_step(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                index: jax.Array, *, local: bool = False
                ) -> tuple[jax.Array, dict]:
    """One-token decode: update cache at ``index``, attend over the cache.

    The cache read is the memory-bound hot loop this framework's analytical
    model is about — every step streams the full (B, S, Hkv, D) cache.
    """
    B, S, _ = x.shape
    assert S == 1
    positions = jnp.full((B, 1), index, jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    length = cache["k"].shape[1]
    slot = jnp.where(jnp.asarray(local), index % length, index)
    cache_dt = cache["k"].dtype
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache_dt),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache_dt),
                                      (0, slot, 0, 0))
    ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
    cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
    kv_len = jnp.minimum(index + 1, length) if local else index + 1
    # the cache is *stored* (and streamed from HBM) in kv_cache_dtype; the
    # attention math upcasts at use (fp8 KV-quant halves the decode traffic)
    ck_c = ck.astype(q.dtype)
    cv_c = cv.astype(q.dtype)
    if cfg.use_pallas:
        # ring buffer: every slot older than `window` has been overwritten;
        # all valid slots attend (causality holds by construction).
        from repro.kernels.decode_attention.ops import gqa_decode
        out = gqa_decode(q, ck_c, cv_c, kv_len, softcap=cfg.logit_softcap)
    else:
        out = L.dense_attention(q, ck_c, cv_c, causal=False, kv_len=kv_len,
                                softcap=cfg.logit_softcap)
    out = shard(out, "batch", None, "heads", None)
    y = L.dense(p["wo"], out.reshape(B, 1, cfg.q_dim))
    return y, {"k": ck, "v": cv}
