"""Mixture-of-Experts layer: top-k routing with capacity-bounded, sort-based
dispatch (static shapes, SPMD-friendly).

The dispatch/combine are *data-dependent gathers/scatters* — the TPU
analogue of the paper's Write-ACK LSU class (DESIGN.md S2) and one of the
three hillclimb cells.

Sharding is tagged with MoE-specific logical axes so the launcher can choose
expert parallelism (experts -> "model", used when n_experts divides the model
axis) or tensor parallelism inside experts (expert_ff -> "model", used for
few-expert models like grok-1).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.pspec import shard


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.experts_per_token * cfg.capacity_factor
                  / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8


def init(key, cfg: ModelConfig) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    lim = 1.0 / math.sqrt(d)
    return {
        "router": {"w": jax.random.normal(ks[0], (d, E), pd) * 0.02},
        "wi": jax.random.normal(ks[1], (E, d, f), pd) * lim,
        "wg": jax.random.normal(ks[2], (E, d, f), pd) * lim,
        "wo": jax.random.normal(ks[3], (E, f, d), pd) / math.sqrt(f),
    }


def forward(p: dict, cfg: ModelConfig, x: jax.Array,
            decode: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux load-balance loss (scalar)).

    Dispatch implementation is selected by ``cfg.moe_impl``; decode steps
    default to the sort path regardless (SPerf Cell B: the einsum one-hots
    are sized for training token counts — at 128 decode tokens they cost
    4.15x in step time).

    Dispatch implementations:

    * ``einsum`` (default) — grouped one-hot dispatch/combine matmuls
      (GShard/MaxText style).  Under GSPMD the token->expert resharding is
      expressed as *contractions*, which the partitioner turns into
      reduce-scatters on the expert axis; the data-dependent form below
      would instead force a full all-gather of the token array (measured
      17 GB/chip on qwen3-235b).
    * ``sort``   — capacity assignment via argsort + gathers (the ragged
      form a custom TPU kernel would use; kept for single-chip use and as
      the comparison point in EXPERIMENTS.md SPerf).
    """
    impl = getattr(cfg, "moe_impl", "einsum")
    if decode and impl == "einsum":
        impl = "sort"
    if impl == "einsum":
        return forward_einsum(p, cfg, x)
    return forward_sort(p, cfg, x)


def _router(p: dict, cfg: ModelConfig, xt: jax.Array):
    """Shared routing: probs, top-k weights/experts, aux loss.  xt: (..., d)."""
    logits = xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(
        jax.nn.one_hot(experts[..., 0], cfg.n_experts, dtype=jnp.float32),
        axis=tuple(range(experts.ndim - 1)))
    density_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = cfg.n_experts * jnp.sum(density * density_prob)
    return probs, weights, experts, aux


def forward_einsum(p: dict, cfg: ModelConfig, x: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """Grouped one-hot einsum dispatch (SPMD-native)."""
    B, S, d = x.shape
    k = cfg.experts_per_token
    E = cfg.n_experts
    T = B * S
    sg = min(2048, S) if S > 1 else 1
    while T % sg:
        sg //= 2
    g = T // sg
    C = capacity(cfg, sg)

    xg = x.reshape(g, sg, d)
    xg = shard(xg, "moe_groups", None, None)
    probs, weights, experts, aux = _router(p, cfg, xg)   # (g,sg,k)

    # capacity assignment: earlier tokens and lower k-slots have priority
    counts = jnp.zeros((g, E), jnp.int32)
    combine = jnp.zeros((g, sg, E, C), x.dtype)
    for j in range(k):
        m_j = jax.nn.one_hot(experts[..., j], E, dtype=jnp.int32)  # (g,sg,E)
        pos_j = counts[:, None, :] + jnp.cumsum(m_j, axis=1) - m_j
        keep_j = (pos_j < C) & (m_j > 0)
        oh_c = jax.nn.one_hot(jnp.where(keep_j, pos_j, C), C, dtype=x.dtype)
        w_j = weights[..., j][..., None, None]               # (g,sg,1,1)
        combine = combine + oh_c * (w_j * keep_j[..., None]).astype(x.dtype)
        counts = counts + m_j.sum(axis=1)
    combine = shard(combine, "moe_groups", None, None, None)

    # dispatch / expert FFN / combine — contractions only
    dispatch_mask = (combine != 0).astype(x.dtype)
    dispatch = jnp.einsum("gsec,gsd->gecd", dispatch_mask, xg)   # (g,E,C,d)
    dispatch = shard(dispatch, "batch", "experts", None, None)
    wi = p["wi"].astype(x.dtype)
    wg = p["wg"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = jnp.einsum("gecd,edf->gecf", dispatch, wi)
    a = jnp.einsum("gecd,edf->gecf", dispatch, wg)
    h = L.activate(a, cfg.act) * h
    h = shard(h, "batch", "experts", None, "expert_ff")
    y = jnp.einsum("gecf,efd->gecd", h, wo)                      # (g,E,C,d)
    y = shard(y, "batch", "experts", None, None)
    out = jnp.einsum("gecd,gsec->gsd", y, combine)
    out = shard(out, "moe_groups", None, None)
    return out.reshape(B, S, d), aux


def forward_sort(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort/gather-based dispatch (single-chip & kernel-oriented path)."""
    B, S, d = x.shape
    T = B * S
    k = cfg.experts_per_token
    E = cfg.n_experts
    C = capacity(cfg, T)
    xt = x.reshape(T, d)

    # --- routing (f32 for stability) ---
    logits = (xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    weights, experts = jax.lax.top_k(probs, k)                 # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(experts[:, 0], E, dtype=jnp.float32), 0)
    density_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * density_prob)

    # --- capacity assignment via sort (position of each request within its
    #     expert; requests beyond capacity C are dropped) ---
    flat_e = experts.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                    # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < C
    token_id = (jnp.arange(T * k, dtype=jnp.int32) // k)

    # --- dispatch: src[e, c] = source token (sentinel T when empty) ---
    src = jnp.full((E, C), T, jnp.int32)
    src = src.at[flat_e, jnp.where(keep, pos, C)].set(
        jnp.where(keep, token_id, T), mode="drop")
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    dispatch = xpad[src]                                       # (E, C, d) gather
    dispatch = shard(dispatch, "experts", "expert_cap", None)

    # --- expert FFN (einsum batched over experts) ---
    wi = p["wi"].astype(x.dtype)
    wg = p["wg"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", dispatch, wi)
    g = jnp.einsum("ecd,edf->ecf", dispatch, wg)
    h = L.activate(g, cfg.act) * h
    h = shard(h, "experts", "expert_cap", "expert_ff")
    y = jnp.einsum("ecf,efd->ecd", h, wo)                      # (E, C, d)
    y = shard(y, "experts", "expert_cap", None)

    # --- combine: weighted gather back to token order ---
    out = jnp.zeros((T, d), x.dtype)
    pos_t = pos.reshape(T, k)
    keep_t = keep.reshape(T, k)
    for j in range(k):
        rows = y[experts[:, j], jnp.where(keep_t[:, j], pos_t[:, j], 0)]
        rows = shard(rows, "tokens", None)
        w_j = (weights[:, j] * keep_t[:, j]).astype(x.dtype)
        out = out + rows * w_j[:, None]
    return out.reshape(B, S, d), aux
