"""Model driver: block composition, scan-over-groups, remat, train loss,
prefill and decode.

Layer stack = ``pattern_repeats`` x ``block_pattern`` (scanned, params stacked
on a leading repeat axis) + ``remainder_pattern`` (unscanned).  Every block
kind exposes (init, forward, init_cache, decode_step); MoE replaces the MLP
in attention blocks when ``cfg.is_moe``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import mlp as MLP
from repro.models import moe as MOE
from repro.models import recurrent as REC
from repro.models import xlstm as XL
from repro.models.config import ModelConfig
from repro.models.pspec import shard

Params = dict


# ---------------------------------------------------------------------------
# per-block init / forward / cache / decode
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 2)
    if kind in ("attn", "local"):
        p = {"ln1": L.norm_params(cfg.d_model, cfg.norm),
             "attn": ATT.init(ks[0], cfg),
             "ln2": L.norm_params(cfg.d_model, cfg.norm)}
        if cfg.is_moe:
            p["moe"] = MOE.init(ks[1], cfg)
        else:
            p["mlp"] = MLP.init(ks[1], cfg)
        return p
    if kind == "rglru":
        return {"ln1": L.norm_params(cfg.d_model, cfg.norm),
                "rec": REC.init(ks[0], cfg),
                "ln2": L.norm_params(cfg.d_model, cfg.norm),
                "mlp": MLP.init(ks[1], cfg)}
    if kind == "mlstm":
        return {"ln1": L.norm_params(cfg.d_model, cfg.norm),
                "cell": XL.mlstm_init(ks[0], cfg)}
    if kind == "slstm":
        ffn_cfg = {"d_ff": int(cfg.d_model * cfg.slstm_proj_factor)}
        return {"ln1": L.norm_params(cfg.d_model, cfg.norm),
                "cell": XL.slstm_init(ks[0], cfg),
                "ln2": L.norm_params(cfg.d_model, cfg.norm),
                "ffn": _plain_mlp_init(ks[1], cfg, ffn_cfg["d_ff"])}
    raise ValueError(kind)


def _plain_mlp_init(key, cfg: ModelConfig, d_ff: int) -> Params:
    ks = jax.random.split(key, 2)
    pd = jnp.dtype(cfg.param_dtype)
    return {"wi": {"w": L.dense_init(ks[0], cfg.d_model, d_ff, pd)},
            "wo": {"w": L.dense_init(ks[1], d_ff, cfg.d_model, pd)}}


def _plain_mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return L.dense(p["wo"], jax.nn.gelu(L.dense(p["wi"], x)))


def _block_forward(p: Params, cfg: ModelConfig, kind: str, x: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence block.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local"):
        h = ATT.forward(p["attn"], cfg, L.apply_norm(p["ln1"], x, cfg.norm),
                        local=(kind == "local"))
        x = x + h
        u = L.apply_norm(p["ln2"], x, cfg.norm)
        if cfg.is_moe:
            m, aux = MOE.forward(p["moe"], cfg, u)
        else:
            m = MLP.forward(p["mlp"], cfg, u)
        return x + m, aux
    if kind == "rglru":
        x = x + REC.forward(p["rec"], cfg, L.apply_norm(p["ln1"], x, cfg.norm))
        x = x + MLP.forward(p["mlp"], cfg, L.apply_norm(p["ln2"], x, cfg.norm))
        return x, aux
    if kind == "mlstm":
        return x + XL.mlstm_forward(p["cell"], cfg,
                                    L.apply_norm(p["ln1"], x, cfg.norm)), aux
    if kind == "slstm":
        x = x + XL.slstm_forward(p["cell"], cfg,
                                 L.apply_norm(p["ln1"], x, cfg.norm))
        x = x + _plain_mlp(p["ffn"], cfg, L.apply_norm(p["ln2"], x, cfg.norm))
        return x, aux
    raise ValueError(kind)


def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind == "attn":
        return ATT.init_cache(cfg, batch, max_len, local=False)
    if kind == "local":
        return ATT.init_cache(cfg, batch, max_len, local=True)
    if kind == "rglru":
        return REC.init_state(cfg, batch)
    if kind == "mlstm":
        return XL.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return XL.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def _block_decode(p: Params, cfg: ModelConfig, kind: str, x: jax.Array,
                  cache, index) -> tuple[jax.Array, Any]:
    if kind in ("attn", "local"):
        h, cache_attn = ATT.decode_step(
            p["attn"], cfg, L.apply_norm(p["ln1"], x, cfg.norm), cache, index,
            local=(kind == "local"))
        x = x + h
        u = L.apply_norm(p["ln2"], x, cfg.norm)
        if cfg.is_moe:
            m, _ = MOE.forward(p["moe"], cfg, u, decode=True)
        else:
            m = MLP.forward(p["mlp"], cfg, u)
        return x + m, cache_attn
    if kind == "rglru":
        h, st = REC.decode_step(p["rec"], cfg,
                                L.apply_norm(p["ln1"], x, cfg.norm), cache)
        x = x + h
        x = x + MLP.forward(p["mlp"], cfg, L.apply_norm(p["ln2"], x, cfg.norm))
        return x, st
    if kind == "mlstm":
        h, st = XL.mlstm_decode_step(p["cell"], cfg,
                                     L.apply_norm(p["ln1"], x, cfg.norm), cache)
        return x + h, st
    if kind == "slstm":
        h, st = XL.slstm_decode_step(p["cell"], cfg,
                                     L.apply_norm(p["ln1"], x, cfg.norm), cache)
        x = x + h
        x = x + _plain_mlp(p["ffn"], cfg, L.apply_norm(p["ln2"], x, cfg.norm))
        return x, st
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    pd = jnp.dtype(cfg.param_dtype)
    params: Params = {}
    if cfg.is_decoder or cfg.family == "vlm":
        params["embed"] = L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, pd)
    if cfg.frontend:
        params["frontend"] = {
            "w": L.dense_init(keys[1], cfg.frontend_dim, cfg.d_model, pd)}

    def group_init(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}": _block_init(ks[i], cfg, kind)
                for i, kind in enumerate(cfg.block_pattern)}

    if cfg.pattern_repeats > 0:
        gkeys = jax.random.split(keys[2], cfg.pattern_repeats)
        params["groups"] = jax.vmap(group_init)(gkeys)
    rest_keys = jax.random.split(keys[3], max(1, len(cfg.remainder_pattern)))
    params["rest"] = [
        _block_init(rest_keys[i], cfg, kind)
        for i, kind in enumerate(cfg.remainder_pattern)
    ]
    params["ln_f"] = L.norm_params(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(keys[4], cfg.d_model,
                                            cfg.padded_vocab, pd)}
    return params


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig, *,
                 tokens: jax.Array | None = None,
                 features: jax.Array | None = None) -> jax.Array:
    """Token embeddings, stub-frontend features, or both (VLM prepends)."""
    parts = []
    if features is not None:
        f = features.astype(cfg.activation_dtype)
        parts.append(L.dense(params["frontend"], f))
    if tokens is not None:
        emb = params["embed"].astype(cfg.activation_dtype)
        parts.append(emb[tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return shard(x, "batch", "act_seq", None)


def forward_hidden(params: Params, cfg: ModelConfig, x: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """Run the block stack.  Returns (hidden, total aux loss).

    Hierarchical remat: the scan body (one pattern group) is checkpointed
    *and* every block inside it is checkpointed again.  Forward stores only
    group-boundary activations; the backward pass recomputes one group, which
    in turn stores only block boundaries and recomputes one block's internals
    (attention online-softmax state, mLSTM chunk carries) at a time — the
    difference between 159 GB/chip and fitting in HBM for the xLSTM cell
    (EXPERIMENTS.md SDry-run)."""
    aux_total = jnp.zeros((), jnp.float32)

    def block_fn(kind):
        def fn(p, x):
            # The barrier pins the bf16 residual read inside the backward
            # loop: without it XLA hoists the first f32 upcast (the norm)
            # out of the loop and bulk-converts the whole (L, B, S, d)
            # residual stack to f32 — a 2x memory pessimization measured at
            # +26 GB/chip on qwen2-7b.  compat supplies a differentiable
            # barrier on jax versions lacking the primitive's grad rule.
            x = compat.optimization_barrier(x)
            return _block_forward(p, cfg, kind, x)
        if cfg.remat:
            fn = jax.checkpoint(fn, prevent_cse=False)
        return fn

    def scan_body(carry, group_params):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, a = block_fn(kind)(group_params[f"b{i}"], x)
            # seq-shard the saved boundary activation (Megatron-SP)
            x = shard(x, "batch", "act_seq", None)
            aux = aux + a
        return (x, aux), None

    body = scan_body
    if cfg.remat:
        body = jax.checkpoint(scan_body, prevent_cse=False)
    if cfg.pattern_repeats > 0:
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["groups"])
    for i, kind in enumerate(cfg.remainder_pattern):
        x, a = block_fn(kind)(params["rest"][i], x)
        aux_total = aux_total + a
    return x, aux_total


def logits_fn(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
        logits = x @ w
    else:
        logits = L.dense(params["head"], x)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the sharding-padding rows (elementwise — keeps vocab sharded)
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                              logits.ndim - 1)
        logits = jnp.where(vocab_iota < cfg.vocab_size, logits, -1e30)
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(params: Params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    """batch keys: tokens? features? labels, mask? (all batch-major)."""
    x = embed_inputs(params, cfg,
                     tokens=batch.get("tokens"),
                     features=batch.get("features"))
    x, aux = forward_hidden(params, cfg, x)
    logits = logits_fn(params, cfg, x)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # VLM: loss only over the trailing text positions
        logits = logits[:, -labels.shape[1]:]
    ce = L.cross_entropy(logits, labels, batch.get("mask"))
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    def group_cache(_):
        return {f"b{i}": _block_cache(cfg, kind, batch, max_len)
                for i, kind in enumerate(cfg.block_pattern)}

    caches: dict = {"rest": [
        _block_cache(cfg, kind, batch, max_len)
        for kind in cfg.remainder_pattern]}
    if cfg.pattern_repeats > 0:
        one = group_cache(None)
        caches["groups"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.pattern_repeats,) + a.shape),
            one)
    return caches


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                caches: dict, index: jax.Array) -> tuple[jax.Array, dict]:
    """One decoding step for the whole stack.  tokens: (B, 1) int32."""
    x = embed_inputs(params, cfg, tokens=tokens)

    def scan_body(x, inp):
        group_params, group_caches = inp
        new = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, new[f"b{i}"] = _block_decode(group_params[f"b{i}"], cfg, kind,
                                            x, group_caches[f"b{i}"], index)
        return x, new

    new_caches: dict = {"rest": []}
    if cfg.pattern_repeats > 0:
        if cfg.scan_layers_decode:
            x, new_groups = jax.lax.scan(scan_body, x,
                                         (params["groups"], caches["groups"]))
            new_caches["groups"] = new_groups
        else:
            # unrolled: each layer's cache slice updates in place (dus on the
            # stacked buffer aliases; no whole-cache copy per token)
            new_groups = caches["groups"]
            for g in range(cfg.pattern_repeats):
                gp = jax.tree.map(lambda a: a[g], params["groups"])
                gc = jax.tree.map(lambda a: a[g], new_groups)
                x, gc_new = scan_body(x, (gp, gc))
                new_groups = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), g, 0),
                    new_groups, gc_new)
            new_caches["groups"] = new_groups
    for i, kind in enumerate(cfg.remainder_pattern):
        x, c = _block_decode(params["rest"][i], cfg, kind, x,
                             caches["rest"][i], index)
        new_caches["rest"].append(c)
    logits = logits_fn(params, cfg, x)
    return logits[:, 0], new_caches
