"""Assigned input-shape sets and ShapeDtypeStruct builders for the dry-run.

LM-family shapes (seq_len x global_batch):
    train_4k      4,096 x 256   training        -> lowers train_step
    prefill_32k   32,768 x 32   inference       -> lowers prefill (full fwd)
    decode_32k    32,768 x 128  decode          -> lowers serve_step (1 new
                                                   token, KV cache of seq_len)
    long_500k     524,288 x 1   long decode     -> serve_step; sub-quadratic
                                                   archs only

Skip rules (from the assignment):
    * decode/long shapes are skipped for encoder-only archs (hubert);
    * long_500k is skipped for pure full-attention archs (needs
      sub-quadratic attention) — see DESIGN.md S4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as TF

VISION_PATCHES = 256  # stubbed InternViT patch tokens prepended to the text


def vision_patches(seq_len: int) -> int:
    """Patch-token count for a given total sequence length (256 for the
    assigned shapes; scaled down for tiny smoke-test sequences)."""
    return min(VISION_PATCHES, max(1, seq_len // 8))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    if shape.kind in ("decode", "long_decode") and not cfg.is_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    Training/prefill inputs are token ids (and stub-frontend features for
    audio/vlm); decode inputs are the one-token batch plus the KV cache /
    recurrent state tree and the position index.
    """
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    act = cfg.activation_dtype

    def token_batch(with_labels: bool) -> dict:
        batch: dict = {}
        if cfg.frontend == "audio":
            batch["features"] = _sds((B, S, cfg.frontend_dim), act)
            if with_labels:
                batch["labels"] = _sds((B, S), i32)
                batch["mask"] = _sds((B, S), f32)
            return batch
        if cfg.frontend == "vision":
            patches = vision_patches(S)
            text = S - patches
            batch["features"] = _sds((B, patches, cfg.frontend_dim), act)
            batch["tokens"] = _sds((B, text), i32)
            if with_labels:
                batch["labels"] = _sds((B, text), i32)
            return batch
        batch["tokens"] = _sds((B, S), i32)
        if with_labels:
            batch["labels"] = _sds((B, S), i32)
        return batch

    if shape.kind == "train":
        return {"batch": token_batch(with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": token_batch(with_labels=False)}
    # decode / long_decode
    caches = jax.eval_shape(lambda: TF.init_caches(cfg, B, S))
    return {
        "tokens": _sds((B, 1), i32),
        "caches": caches,
        "index": jax.ShapeDtypeStruct((), i32),
    }
