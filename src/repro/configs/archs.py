"""The 10 assigned architectures (exact configs from the assignment table).

Known deviations from the HF reference implementations are noted inline and
in DESIGN.md (none affect the memory/compute accounting the framework is
about): stablelm's partial-rotary fraction, command-r's parallel block, and
conv/positional frontends replaced by the mandated stubs.
"""
from __future__ import annotations

from repro.models.config import ModelConfig

ARCHS: dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- dense -----------------------------------------------------------------
STABLELM_3B = _register(ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    block_pattern=("attn",), norm="layernorm", act="silu", glu=True,
    rope_theta=10_000.0,
))

QWEN2_7B = _register(ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    block_pattern=("attn",), qkv_bias=True, norm="rmsnorm",
    rope_theta=1_000_000.0,
))

CODEQWEN15_7B = _register(ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    block_pattern=("attn",), qkv_bias=True, norm="rmsnorm",
    rope_theta=1_000_000.0,
))

COMMAND_R_35B = _register(ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    block_pattern=("attn",), norm="layernorm", tie_embeddings=True,
    rope_theta=10_000.0,
))

# --- MoE ---------------------------------------------------------------
QWEN3_MOE_235B = _register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936,
    n_experts=128, experts_per_token=8,
    block_pattern=("attn",), use_qk_norm=True, norm="rmsnorm",
    rope_theta=1_000_000.0,
))

GROK1_314B = _register(ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, experts_per_token=2,
    block_pattern=("attn",), act="gelu", norm="rmsnorm",
    logit_softcap=30.0,
))

# --- audio (encoder-only; conv frontend stubbed) -----------------------
HUBERT_XLARGE = _register(ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    block_pattern=("attn",), is_decoder=False, frontend="audio",
    frontend_dim=512, act="gelu", glu=False, norm="layernorm",
))

# --- VLM (InternViT frontend stubbed; InternLM2-1.8B backbone) ---------
INTERNVL2_2B = _register(ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=92553,
    block_pattern=("attn",), frontend="vision", frontend_dim=1024,
    norm="rmsnorm",
))

# --- hybrid: Griffin pattern (RG-LRU, RG-LRU, local-attn) --------------
RECURRENTGEMMA_9B = _register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    remainder_pattern=("rglru", "rglru"),
    local_window=2048, act="gelu", norm="rmsnorm",
))

# --- ssm: xLSTM[7:1] ----------------------------------------------------
XLSTM_1_3B = _register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("slstm",) + ("mlstm",) * 7,
    norm="layernorm",
))
