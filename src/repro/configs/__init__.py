"""Architecture + shape registry (``--arch <id>`` selectable)."""
from __future__ import annotations

import dataclasses

from repro.configs.archs import ARCHS
from repro.configs.shapes import SHAPES, ShapeSpec, cell_status, input_specs
from repro.models.config import ModelConfig


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


def all_cells() -> list[tuple[str, str]]:
    """All 40 assigned (arch x shape) cells, in registry order."""
    return [(a, s) for a in list_archs() for s in SHAPES]


def reduced_config(cfg: ModelConfig, *, layers_scale: int = 1) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the block pattern, GQA ratio, MoE routing structure, frontend and
    norm/activation choices; shrinks every width so one train step runs on a
    single CPU device in seconds.
    """
    n_kv = max(1, min(cfg.n_kv_heads, 2))
    n_heads = max(n_kv, 4 if cfg.n_heads >= 4 else cfg.n_heads)
    n_heads = (n_heads // n_kv) * n_kv or n_kv
    pattern_layers = len(cfg.block_pattern) * layers_scale
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=pattern_layers + len(cfg.remainder_pattern),
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=16,
        d_ff=cfg.d_ff and 128,
        vocab_size=128,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        block_pattern=cfg.block_pattern,
        remainder_pattern=cfg.remainder_pattern,
        frontend_dim=cfg.frontend_dim and 16,
        local_window=16,
        chunk_size=8,
        attn_block_q=16,
        attn_block_kv=16,
        rope_theta=min(cfg.rope_theta, 10_000.0),
        d_rnn=0,
        remat=False,
    )
