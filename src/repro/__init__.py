"""repro — analytical model of memory-bound HLS applications, and its
TPU/XLA transplant, behind one unified public API.

Describe a design once (:class:`Design`), evaluate it in a hardware +
calibration context (:class:`Session`), and every pipeline stage — estimate,
sweep, autotune, validate, roofline, predict — speaks the same
:class:`Estimate`/:class:`Report` result family:

    >>> import repro
    >>> sess = repro.Session()                        # DDR4-1866, numpy-batch
    >>> d = repro.Design.microbench(repro.LsuType.BC_ALIGNED, n_ga=4)
    >>> sess.estimate(d).t_exe
    >>> sess.sweep(repro.Space.grid(n_ga=[1, 2, 4], simd=[1, 16])).top_k(3)

Hardware is data, not constants: :mod:`repro.hw` holds one serializable
:class:`Hardware` spec family behind a named registry —
``sess.with_hardware(repro.hw.get("tpu_v4"))`` swaps the whole memory
system, and a sweep can fan out over a ``hardware`` axis.  The convenience
constants re-exported below (``DDR4_1866`` …) are built from those registry
entries; their former homes (``repro.core.fpga.DDR4_1866``,
``repro.core.hbm.TPU_V5E``) completed their one-release deprecation cycle
and are removed — use ``repro.hw.get(name)`` views instead.

Million-point design spaces stream instead of materializing:
``sess.sweep(repro.Space.grid(...).stream(), chunk_size=65536)`` enumerates
points lazily, evaluates fixed-shape chunks (sharded across local devices
on the ``jax-jit`` backend) and folds them into online Pareto/top-k/stats
reducers, so peak memory is O(chunk + front + k) at any sweep size.

Streaming sweeps also distribute: ``sess.sweep(space,
executor="processes", workers=4)`` partitions the grid into chunk-aligned
id ranges, fans them out over a spawn-based process pool (each worker
rebuilds its evaluator from the picklable :class:`SweepPlan`), re-issues
stragglers, and merges reducer states into a report bit-equal to the
single-process run (:mod:`repro.core.distributed`).

Search does not have to enumerate at all: every :class:`Hardware` preset
carries a :class:`ResourceEnvelope` budget, ``sess.sweep(space,
constraints=[board.envelope])`` feasibility-masks each streaming chunk
*before* scoring (bit-equal to post-filtering the unconstrained sweep),
and ``sess.optimize(space, objective=("t_exe", "resource"))`` finds the
grid optimum / Pareto front by relaxing the integer axes and descending
the differentiable model — typically evaluating under 1% of the grid
(:mod:`repro.search`).

Whole models compose from the same per-kernel model:
``sess.estimate_model(cfg)`` walks a compiled train/decode step op by op
(trip-count aware), scores every op's DRAM traffic through Eqs. 1-10 in
one batched pass, and returns a :class:`ModelReport` whose phase totals
are exactly the sum of the per-op estimates; ``sess.sweep_model(...)``
makes model shape x sharding x hardware a streaming grid behind a
picklable :class:`ModelSweepPlan` (:mod:`repro.workload`).

Interactive advisor traffic goes through the serving layer:
``sess.serve()`` returns a :class:`Server` that micro-batches concurrent
``estimate`` calls from any number of threads into single batched scoring
passes (bit-equal to serial evaluation), memoizes results in a
content-hash LRU, and reports p50/p99 latency via ``stats()``.

Everything else (``repro.core.*``, ``repro.kernels.*``, ``repro.launch.*``)
is implementation; the pre-PR-3 module-level entry points
(``model.estimate``, ``sweep.sweep_grid``/``sweep_random``,
``predictor.predict``, ``autotune.autotune``, ``validate.validate``) have
completed their one-release deprecation cycle and are removed.

This module imports NumPy only; jax loads lazily, on first use of the
``jax-jit`` backend, ``Design.from_kernel`` or ``Session.validate``.
"""
from repro import hw
from repro.api import (
    BACKENDS,
    EXECUTORS,
    AutotuneReport,
    Design,
    Estimate,
    Report,
    RequestTimeout,
    RooflineReport,
    Server,
    ServerClosed,
    ServerOverloaded,
    Session,
    Space,
    SweepPlan,
    SweepReport,
    ValidateReport,
)
# Registry-backed convenience constants (the legacy parameter views of the
# repro.hw presets, built once in repro.core; reading them here does not
# warn).
from repro.core import (
    DDR4_1866,
    DDR4_2666,
    DRAM_CONFIGS,
    STRATIX10_BSP,
)
from repro.core.fpga import BspParams, DramParams
from repro.core.hbm import AccessClass, TpuParams
from repro.core.lsu import Lsu, LsuType, make_global_access
from repro.hw import ClockDomain, DramOrganization, Hardware, MemorySystem
# The constrained/gradient-based search layer (repro.search is lazy: these
# resolve through its PEP 562 __getattr__ after repro.api is fully loaded).
from repro.search import (
    Constraint,
    OptimizeReport,
    ResourceEnvelope,
    within,
)
# Whole-model estimation (Session.estimate_model / plan_model / sweep_model
# return these; repro.workload imports NumPy only — jax stays lazy).
from repro.workload import (
    ModelReport,
    ModelSweepPlan,
    ModelSweepReport,
    OpRecord,
    PhaseReport,
)

TPU_V5E = hw.get("tpu_v5e").tpu_params()

__version__ = "0.9.0"

__all__ = [
    # the unified API
    "Design", "Session", "Space", "Estimate", "Report",
    "SweepPlan", "SweepReport", "AutotuneReport", "ValidateReport",
    "RooflineReport", "BACKENDS", "EXECUTORS",
    # the serving layer
    "Server", "ServerClosed", "ServerOverloaded", "RequestTimeout",
    # constrained + gradient-based search
    "ResourceEnvelope", "Constraint", "within", "OptimizeReport",
    # whole-model estimation (repro.workload)
    "ModelReport", "PhaseReport", "OpRecord",
    "ModelSweepPlan", "ModelSweepReport",
    # the hardware-spec layer
    "hw", "Hardware", "MemorySystem", "DramOrganization", "ClockDomain",
    # design vocabulary (paper Tables I-III)
    "Lsu", "LsuType", "make_global_access",
    "DramParams", "BspParams", "DDR4_1866", "DDR4_2666", "DRAM_CONFIGS",
    "STRATIX10_BSP",
    # TPU transplant hardware
    "TpuParams", "TPU_V5E", "AccessClass",
    "__version__",
]
