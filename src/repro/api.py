"""Unified ``Design``/``Session`` API: one design description, one pipeline.

The paper's value is a single analytical flow — describe a memory
architecture once, get a fast prediction — but the repo historically grew
five disjoint entry points (``model.estimate``, ``model_batch.estimate_batch``,
``sweep.sweep_grid``/``sweep_random``, ``predictor.predict``,
``validate.validate``) that each re-invented how a design point, hardware
parameters and calibration were specified.  This module consolidates them:

* :class:`Design` — a frozen, self-contained description of one design
  point: the LSU groups (paper Table II), optional per-design DRAM/BSP
  overrides, the vectorization factor, and optional compute-side metadata
  when the design was read off a compiled artifact.  Builder-style
  ``with_*`` helpers derive variants; ``from_hlo``/``from_kernel`` read a
  design straight out of a compiled XLA executable (the transplant of
  reading the HLS early report), ``microbench``/``from_app`` build the
  paper's SIV/Table IV designs.
* :class:`Space` — a declarative design *space*: the Cartesian grid or a
  random sample over the microbenchmark axes of :mod:`repro.core.sweep`.
* :class:`Session` — the evaluation context: hardware parameters (DRAM +
  BSP for the faithful FPGA model, :class:`~repro.core.hbm.TpuParams` for
  the TPU transplant), a calibration factor, and a compute backend
  (``scalar`` | ``numpy-batch`` | ``jax-jit``).  Every pipeline stage is a
  method: ``estimate``, ``sweep``, ``autotune``, ``validate``,
  ``roofline``, ``predict`` — and ``serve`` turns the session into a
  long-lived concurrent query service (:class:`repro.core.serving.Server`:
  micro-batched scoring, content-hash LRU result cache, p50/p99 stats).
* :class:`Estimate` and the :class:`Report` family — one shared result
  vocabulary across all of those stages (``rows()`` / ``to_csv()`` /
  ``summary()``), instead of today's per-module dataclasses.

All three backends run the *same* equations (the array core in
:mod:`repro.core.model_batch`) and agree element-wise to 1e-6; the jax-jit
backend evaluates under ``jax.jit`` with x64 enabled so results are
bit-comparable with NumPy (tests/test_api.py).

    >>> from repro import Design, Session, Space
    >>> sess = Session()                       # DDR4-1866, numpy-batch
    >>> est = sess.estimate(Design.microbench(LsuType.BC_ALIGNED, n_ga=4))
    >>> res = sess.sweep(Space.grid(n_ga=[1, 2, 4], simd=[1, 4, 16]))
    >>> res.top_k(3)
"""
from __future__ import annotations

import dataclasses
import math
from time import perf_counter as _perf_counter
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

from repro.core import apps as _apps
from repro.core import model as _model
from repro.core import model_batch as _mb
from repro.core import sweep as _sweep
from repro.core.fpga import BspParams, DramParams
from repro.core.stream import SweepPlan
from repro.core.hbm import TpuParams
from repro.core.lsu import Lsu, LsuType, make_global_access
from repro.hw import DEFAULT_BOARD, DEFAULT_CHIP, Hardware
from repro.hw import get as _hw_get

#: Supported Session compute backends, in increasing batch-friendliness.
BACKENDS = ("scalar", "numpy-batch", "jax-jit")

__all__ = [
    "BACKENDS", "EXECUTORS",
    "Design", "Space", "Session", "SweepPlan",
    "Estimate", "Report", "SweepReport", "AutotuneReport", "ValidateReport",
    "RooflineReport",
    # the serving layer (Session.serve) and its failure vocabulary
    "Server", "ServerClosed", "ServerOverloaded", "RequestTimeout",
]

#: Supported Session.sweep executors: the in-process chunk pipeline and the
#: coordinator/worker process pool (repro.core.distributed).
EXECUTORS = ("threads", "processes")

#: LSU types whose stride axis is live (mirrors apps.microbench semantics).
_STRIDE_TYPES = (LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED, LsuType.BC_CACHE)


# ---------------------------------------------------------------------------
# Design: one design point, described once
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Design:
    """A frozen description of one design point.

    ``lsus`` are the paper-Table-II load/store units the design instantiates
    (use the constructors below rather than writing them by hand).  ``dram``
    and ``bsp`` are optional per-design overrides of the session hardware;
    ``f`` is the vectorization factor entering Eq. 10.  ``flops`` is
    non-zero only for designs read off a compiled artifact
    (``from_hlo``/``from_kernel``) and feeds the compute term of
    ``Session.roofline``.
    """

    lsus: tuple[Lsu, ...]
    dram: DramParams | None = None
    bsp: BspParams | None = None
    f: int = 1
    name: str = ""
    flops: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "lsus", tuple(self.lsus))

    # -- constructors -------------------------------------------------------

    @classmethod
    def microbench(cls, lsu_type: LsuType, *, n_ga: int, simd: int = 16,
                   n_elems: int = 1 << 22, delta: int = 1,
                   elem_bytes: int = 4, include_write: bool = True,
                   val_constant: bool = False, name: str = "",
                   dram: DramParams | None = None,
                   bsp: BspParams | None = None) -> "Design":
        """The paper's SIV sum-reduction microbenchmark as a Design.

        The vectorization factor is the SIMD width, exactly as in the paper
        (``#ga`` reads + one write, write-ACK stores replicated ``simd``
        times, atomics one unit per GA).
        """
        lsus = _apps.microbench(
            lsu_type, n_ga=n_ga, simd=simd, n_elems=n_elems,
            delta=delta if lsu_type in _STRIDE_TYPES else 1,
            elem_bytes=elem_bytes, include_write=include_write,
            val_constant=val_constant)
        return cls(lsus=tuple(lsus), dram=dram, bsp=bsp, f=simd,
                   name=name or f"microbench-{lsu_type.value}-ga{n_ga}")

    @classmethod
    def from_app(cls, app: str, n_elems: int, *,
                 dram: DramParams | None = None,
                 bsp: BspParams | None = None) -> "Design":
        """One of the paper's Table IV applications (``repro.core.apps.APPS``)."""
        desc = _apps.APPS[app]
        return cls(lsus=tuple(desc.lsus(n_elems)), dram=dram, bsp=bsp,
                   f=desc.simd, name=app)

    @classmethod
    def from_classes(cls, bytes_by_class: Mapping[str, float], *,
                     access_bytes: int | None = None, flops: float = 0.0,
                     name: str = "") -> "Design":
        """Design from access-class byte totals (the HLO counter's output).

        Uses the same class -> LSU-type mapping the validation harness uses
        (stream -> aligned, strided -> non-aligned, gather/serialized ->
        write-ACK), preserving total traffic at ``access_bytes`` granularity.
        """
        from repro.core import validate as _validate

        lsus = _validate.lsus_from_classes(
            dict(bytes_by_class),
            access_bytes=access_bytes or _validate.ACCESS_BYTES)
        return cls(lsus=tuple(lsus), flops=flops, name=name)

    @classmethod
    def from_hlo(cls, hlo_text: str, *, access_bytes: int | None = None,
                 name: str = "") -> "Design":
        """Design read off compiled HLO text (``compiled.as_text()``).

        The transplant of reading the HLS early report: the trip-count-aware
        HLO counter classifies the executable's memory traffic, and each
        access class becomes one LSU group.
        """
        from repro.core import hlo_counter as _hc

        hc = _hc.analyze(hlo_text)
        return cls.from_classes(dict(hc.bytes_by_class),
                                access_bytes=access_bytes,
                                flops=float(hc.flops), name=name)

    @classmethod
    def from_kernel(cls, fn: Callable, *args, name: str = "",
                    access_bytes: int | None = None) -> "Design":
        """Design from a jax-jittable callable: lower + compile + analyze.

        ``fn`` may be a plain function (it is jitted here) or an already
        jitted/lowered one; ``args`` are example arguments or
        ``jax.ShapeDtypeStruct`` specs.  Requires jax.
        """
        import jax

        jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
        compiled = jitted.lower(*args).compile()
        return cls.from_hlo(compiled.as_text(), access_bytes=access_bytes,
                            name=name or getattr(fn, "__name__", "kernel"))

    # -- builder-style derivation ------------------------------------------

    def with_dram(self, dram: DramParams) -> "Design":
        return dataclasses.replace(self, dram=dram)

    def with_bsp(self, bsp: BspParams) -> "Design":
        return dataclasses.replace(self, bsp=bsp)

    def with_f(self, f: int) -> "Design":
        return dataclasses.replace(self, f=f)

    def with_name(self, name: str) -> "Design":
        return dataclasses.replace(self, name=name)

    def with_lsus(self, lsus: Iterable[Lsu]) -> "Design":
        """Replace the LSU list wholesale."""
        return dataclasses.replace(self, lsus=tuple(lsus))

    def with_access(self, lsu_type: LsuType, *, n_elems: int,
                    elem_bytes: int = 4, f: int | None = None,
                    delta: int = 1, is_write: bool = False,
                    val_constant: bool = False, name: str = "") -> "Design":
        """Append one source-level global access (expanded to its LSUs)."""
        extra = make_global_access(
            lsu_type, n_elems=n_elems, elem_bytes=elem_bytes,
            f=self.f if f is None else f, delta=delta, is_write=is_write,
            val_constant=val_constant, name=name)
        return dataclasses.replace(self, lsus=self.lsus + tuple(extra))

    # -- introspection ------------------------------------------------------

    @property
    def n_lsu(self) -> int:
        """Number of LSUs that issue DRAM traffic."""
        return sum(1 for l in self.lsus if l.lsu_type.is_global)

    @property
    def total_bytes(self) -> int:
        """Useful bytes the design moves (sum over global LSUs)."""
        return sum(l.total_bytes for l in self.lsus if l.lsu_type.is_global)

    @property
    def resource_bytes(self) -> int:
        """Total LSU interconnect width [B] — the sweep resource objective."""
        return sum(l.ls_width for l in self.lsus if l.lsu_type.is_global)


# ---------------------------------------------------------------------------
# Space: a declarative design space
# ---------------------------------------------------------------------------

#: Default streaming chunk: 64k points keeps the working set ~tens of MB
#: while amortizing per-chunk dispatch, and is one fixed jit shape.
DEFAULT_CHUNK = 1 << 16


@dataclasses.dataclass(frozen=True)
class Space:
    """A design space over the microbenchmark axes (``sweep.AXES``).

    ``Space.grid(**axes)`` is the full Cartesian product; ``Space.random(n,
    seed=..., **axes)`` samples ``n`` points (2-tuples of numbers =
    inclusive integer ranges).  Axes left unset default to the session's
    hardware and the sweep-engine defaults at evaluation time.

    ``Space.grid(...).stream()`` marks the space for bounded-memory
    streaming evaluation: points are enumerated lazily from integer ids and
    folded chunk-by-chunk into online reducers, so million-point grids
    sweep in O(chunk + front + k) memory (see ``Session.sweep``).
    """

    axes: Mapping[str, Any]
    n: int | None = None       # None -> full grid
    seed: int = 0
    chunk_size: int | None = None   # set by stream(); None -> materialize

    @classmethod
    def grid(cls, **axes) -> "Space":
        return cls(axes=dict(axes))

    @classmethod
    def random(cls, n: int, *, seed: int = 0, **axes) -> "Space":
        if n < 1:
            raise ValueError("a random space needs n >= 1 samples")
        return cls(axes=dict(axes), n=int(n), seed=int(seed))

    @property
    def is_grid(self) -> bool:
        return self.n is None

    def stream(self, chunk_size: int = DEFAULT_CHUNK) -> "Space":
        """This grid, marked for chunked streaming evaluation.

        Only grids stream: their points are pure index arithmetic on the
        point id, so no per-point state ever needs materializing.  (A
        random space would need all its draws held to be re-chunkable.)
        """
        if not self.is_grid:
            raise TypeError("streaming sweeps need a grid space; "
                            "Space.random materializes its draws")
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        return dataclasses.replace(self, chunk_size=int(chunk_size))

    def lists(self, *, dram: DramParams, bsp: BspParams) -> dict[str, list]:
        """Normalized per-axis value lists, defaulting the hardware axes."""
        axes = dict(self.axes)
        axes.setdefault("dram", dram)
        axes.setdefault("bsp", bsp)
        return _sweep._normalize_axes(axes)

    def points(self, *, dram: DramParams, bsp: BspParams, constraints=(),
               ) -> tuple[dict[str, np.ndarray], int, dict]:
        """Materialize per-point axis arrays, defaulting hardware axes.

        For a random space, ``constraints`` switches to seeded rejection
        sampling: every returned point is feasible, and an empty (or
        near-empty) feasible region raises instead of spinning or emitting
        infeasible points.  Grid spaces ignore ``constraints`` here — the
        sweep path masks the enumerated grid itself, so it can report the
        feasible/candidate split.
        """
        axes = dict(self.axes)
        axes.setdefault("dram", dram)
        axes.setdefault("bsp", bsp)
        if self.is_grid:
            return _sweep._grid_points(axes)
        return _sweep._random_points(self.n, self.seed, axes,
                                     constraints=tuple(constraints))


# ---------------------------------------------------------------------------
# The shared result family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Estimate:
    """One design point's model output — the family's scalar member.

    The same fields come out of every backend; ``per_lsu`` carries the
    readable per-LSU breakdown when the scalar backend produced it.
    """

    t_exe: float                  # Eq. 1 [s]
    t_ideal: float                # bandwidth floor [s]
    t_ovh: float                  # row-miss/ACK/atomic overhead [s]
    bound_ratio: float            # LHS of Eq. 3
    memory_bound: bool
    total_bytes: float
    n_lsu: int
    backend: str = "scalar"
    design: "Design | None" = None
    per_lsu: tuple = ()
    cached: bool = False          # True when served from a Server's LRU

    @property
    def effective_bandwidth(self) -> float:
        """Useful bytes / predicted time [B/s]."""
        return self.total_bytes / self.t_exe if self.t_exe > 0 else math.inf

    def row(self) -> dict:
        return {
            "design": self.design.name if self.design else "",
            "t_exe_ms": self.t_exe * 1e3,
            "t_ideal_ms": self.t_ideal * 1e3,
            "t_ovh_ms": self.t_ovh * 1e3,
            "bound_ratio": self.bound_ratio,
            "memory_bound": bool(self.memory_bound),
            "eff_bw_gbs": self.effective_bandwidth / 1e9,
            "total_bytes": self.total_bytes,
            "backend": self.backend,
        }


def _estimate_row(est: "_mb.BatchEstimate", i: int, *, backend: str,
                  scale: float = 1.0,
                  design: "Design | None" = None) -> Estimate:
    """Row ``i`` of a BatchEstimate as an :class:`Estimate` (the one place
    that knows the field-by-field extraction)."""
    return Estimate(
        t_exe=float(np.asarray(est.t_exe)[i]) * scale,
        t_ideal=float(np.asarray(est.t_ideal)[i]) * scale,
        t_ovh=float(np.asarray(est.t_ovh)[i]) * scale,
        bound_ratio=float(np.asarray(est.bound_ratio)[i]),
        memory_bound=bool(np.asarray(est.memory_bound)[i]),
        total_bytes=float(np.asarray(est.total_bytes)[i]),
        n_lsu=int(np.asarray(est.n_lsu)[i]),
        backend=backend, design=design)


class Report:
    """Mixin of the shared report protocol: ``rows`` / ``to_csv`` / ``summary``.

    Every Session method that scores more than one thing returns a Report
    subclass, so downstream tooling (benchmarks, CI artifacts, notebooks)
    consumes one shape regardless of which pipeline stage produced it.
    """

    kind: str = "report"

    def rows(self) -> list[dict]:  # pragma: no cover — abstract
        raise NotImplementedError

    def to_csv(self) -> str:
        rows = self.rows()
        if not rows:
            return ""
        import csv
        import io

        fields = list(rows[0].keys())
        seen = set(fields)
        for r in rows[1:]:         # failure rows may carry extra keys
            fields += [k for k in r if k not in seen]
            seen.update(r)
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=fields, restval="")
        w.writeheader()
        for r in rows:
            w.writerow(r)
        return buf.getvalue()

    def summary(self) -> dict:
        return {"kind": self.kind, "rows": len(self.rows())}


@dataclasses.dataclass(frozen=True)
class SweepReport(_sweep.SweepResult, Report):
    """Scored design space (a :class:`~repro.core.sweep.SweepResult` that is
    also a :class:`Report`), tagged with the backend that scored it.

    A *streaming* sweep returns the same class backed by reducer state: the
    held arrays (``points``/``estimate``/``resource``) cover only the
    surviving points (Pareto front + top-k), ``point_ids`` maps them back
    to global point ids, ``stats`` carries the exact whole-space summary,
    and ``pareto()`` / ``top_k()`` / ``rows()`` answer from that state —
    ``rows()`` is restricted to survivors by construction.
    """

    backend: str = "numpy-batch"
    # -- streaming state (None on a materialized sweep) --------------------
    n_total: int | None = None        # points swept (held arrays are fewer)
    stats: Mapping[str, Any] | None = None   # StatsReducer.summary()
    point_ids: np.ndarray | None = None      # global id of each held row
    front_idx: np.ndarray | None = None      # held-row indices of the front
    front_objectives: tuple | None = None    # the reducer's objective names
    topk_idx: np.ndarray | None = None       # held-row indices, best first
    topk_key: str | None = None
    reducers: tuple | None = None     # the folded reducer instances —
    # custom Reducer subclasses read their accumulated state back here
    # -- constraint telemetry (None on an unconstrained sweep) -------------
    n_candidates: int | None = None   # points enumerated before feasibility
    # -- per-stage timing (None unless swept with profile=True) ------------
    profile: Mapping[str, Any] | None = None
    kind = "sweep"

    @property
    def is_streaming(self) -> bool:
        return self.n_total is not None

    @property
    def n_points(self) -> int:
        """Points swept (for a streaming report: the whole space, not the
        survivors — ``len(report.resource)`` counts the held rows)."""
        return self.n_total if self.n_total is not None \
            else int(len(self.resource))

    def pareto(self, objectives: Sequence[Any] | None = None) -> np.ndarray:
        if self.is_streaming:
            if self.front_idx is None:
                raise ValueError(
                    "a streaming report holds only the reducer's front; "
                    "re-sweep with reducers=[ParetoReducer(objectives=...)]")
            # A non-default reducer front must be requested explicitly, the
            # same way top_k validates topk_key, so a custom-objective
            # front is never mistaken for the default t_exe/resource one.
            wanted = tuple(objectives) if objectives is not None \
                else ("t_exe", "resource")
            if wanted != self.front_objectives:
                raise ValueError(
                    f"streaming report holds the front over "
                    f"{self.front_objectives}; re-sweep with "
                    f"reducers=[ParetoReducer(objectives={wanted!r})] or "
                    f"call pareto({list(self.front_objectives)!r})")
            return np.asarray(self.front_idx, dtype=np.int64)
        return super().pareto(objectives)

    def top_k(self, k: int = 10, key: str = "t_exe") -> list[dict]:
        if self.is_streaming:
            if self.topk_idx is None or key != self.topk_key:
                raise ValueError(
                    f"streaming report kept top-k by {self.topk_key!r}; "
                    f"re-sweep with reducers=[TopKReducer(k, {key!r})]")
            # A reducer that kept the whole space answers any k, like the
            # materialized path; only a truncated selection caps k.
            if k > len(self.topk_idx) and len(self.topk_idx) < self.n_points:
                raise ValueError(
                    f"streaming report kept only the top {len(self.topk_idx)}"
                    f"; re-sweep with reducers=[TopKReducer(k={k})]")
            return self.rows(self.topk_idx[:k])
        return super().top_k(k, key)

    def estimates(self, indices: Sequence[int] | None = None,
                  ) -> list[Estimate]:
        """Per-point :class:`Estimate` objects (default: all held points)."""
        if indices is None:
            indices = range(len(self.resource))
        return [_estimate_row(self.estimate, int(i), backend=self.backend)
                for i in indices]

    def best(self) -> Estimate:
        """The fastest design point of the space.

        For a streaming report this is cross-checked against the exact
        whole-space minimum the stats reducer tracked: if the survivors the
        configured reducers kept do not include that point (e.g. a custom
        front with no ``t_exe`` objective and no top-k), this raises rather
        than returning a confidently wrong row.  The default reducers
        always keep it.
        """
        if self.n_points == 0:
            if self.n_candidates:
                raise ValueError(
                    f"constraints eliminated every point: 0 of "
                    f"{self.n_candidates} candidates feasible; relax the "
                    f"constraints or widen the space")
            raise ValueError("the swept space is empty (n_points == 0); "
                             "there is no best design point")
        if self.is_streaming and len(self.resource) == 0:
            raise ValueError(
                "streaming report holds no survivor rows (stats-only "
                f"reducers; t_exe_min={self.stats['t_exe_min']!r} at point "
                f"id {self.stats['t_exe_min_id']}); re-sweep with "
                "reducers=[TopKReducer(1), ...] to keep the best row")
        i = int(np.argmin(self.t_exe))
        if self.is_streaming and self.stats is not None \
                and float(np.asarray(self.t_exe)[i]) != self.stats["t_exe_min"]:
            raise ValueError(
                "streaming report's survivors do not include the fastest "
                f"point (held min {float(np.asarray(self.t_exe)[i])!r} vs "
                f"whole-space min {self.stats['t_exe_min']!r} at point id "
                f"{self.stats['t_exe_min_id']}); re-sweep with "
                "reducers=[TopKReducer(1), ...] to keep it")
        return self.estimates([i])[0]

    def summary(self) -> dict:
        if self.is_streaming:
            out = {
                "kind": self.kind, "backend": self.backend,
                "n_points": int(self.stats["n_points"]),
                "memory_bound_points": int(self.stats["memory_bound_points"]),
                "pareto_points": int(len(self.front_idx)
                                     if self.front_idx is not None else 0),
                "t_exe_min_ms": float(self.stats["t_exe_min"]) * 1e3,
            }
        else:
            out = {
                "kind": self.kind, "backend": self.backend,
                "n_points": self.n_points,
                "memory_bound_points": int(
                    np.asarray(self.memory_bound).sum()),
                "pareto_points": int(len(self.pareto())
                                     if self.n_points else 0),
                "t_exe_min_ms": (float(np.min(self.t_exe)) * 1e3
                                 if self.n_points else math.inf),
            }
        if self.n_candidates is not None:
            # the feasible/total split of a constrained sweep
            out["n_candidates"] = int(self.n_candidates)
            out["n_feasible"] = out["n_points"]
        if self.profile is not None:
            out["profile"] = dict(self.profile)
        return out


def _stream_report(outcome, tables: Mapping[str, list], *,
                   backend: str,
                   n_candidates: int | None = None,
                   profile: Mapping[str, Any] | None = None) -> SweepReport:
    """Fold a :class:`repro.core.stream.StreamOutcome` into a SweepReport.

    Survivors = union of the Pareto reducer's front and the top-k rows,
    deduplicated by point id and held in ascending id order; the front and
    top-k index into those held rows.  For a constrained sweep
    (``n_candidates`` set) the reducers only ever saw feasible rows, so the
    report's ``n_total`` is the stats reducer's exact feasible count, not
    the enumerated grid size.
    """
    from repro.core import stream as _stream

    front = next((r for r in outcome.reducers
                  if isinstance(r, _stream.ParetoReducer)), None)
    topk = next((r for r in outcome.reducers
                 if isinstance(r, _stream.TopKReducer)), None)
    stats = next(r for r in outcome.reducers
                 if isinstance(r, _stream.StatsReducer))

    pieces = [r.cols for r in (front, topk)
              if r is not None and r.cols is not None]
    if pieces:
        merged = {k: np.concatenate([p[k] for p in pieces])
                  for k in pieces[0]}
        ids, first = np.unique(np.asarray(merged["id"], dtype=np.int64),
                               return_index=True)
        merged = {k: np.asarray(v)[first] for k, v in merged.items()}
    else:   # stats-only reducers: nothing held beyond the summary
        ids = np.empty(0, dtype=np.int64)
        merged = {k: np.empty(0) for k in
                  (("id",) + _sweep.AXES + _stream.ESTIMATE_COLUMNS
                   + ("resource",))}

    points: dict[str, np.ndarray] = {}
    for name in _sweep.AXES:
        col = merged[name]
        if name in _sweep._CATEGORICAL:
            points[name] = _sweep._object_array(tables[name])[
                np.asarray(col, dtype=np.int64)] if len(col) \
                else _sweep._object_array([])
        else:
            points[name] = np.asarray(col)
    est = _mb.BatchEstimate(
        t_exe=np.asarray(merged["t_exe"], dtype=np.float64),
        t_ideal=np.asarray(merged["t_ideal"], dtype=np.float64),
        t_ovh=np.asarray(merged["t_ovh"], dtype=np.float64),
        bound_ratio=np.asarray(merged["bound_ratio"], dtype=np.float64),
        memory_bound=np.asarray(merged["memory_bound"], dtype=bool),
        total_bytes=np.asarray(merged["total_bytes"], dtype=np.float64),
        n_lsu=np.asarray(merged["n_lsu"], dtype=np.int64),
        groups={})
    return SweepReport(
        points=points, estimate=est,
        resource=np.asarray(merged["resource"], dtype=np.float64),
        backend=backend,
        n_total=(outcome.n_points if n_candidates is None
                 else int(stats.n_points)),
        n_candidates=n_candidates, stats=stats.summary(),
        point_ids=ids,
        front_idx=(np.searchsorted(ids, front.ids)
                   if front is not None else None),
        front_objectives=front.objectives if front is not None else None,
        topk_idx=(np.searchsorted(ids, topk.ids)
                  if topk is not None else None),
        topk_key=topk.key if topk is not None else None,
        reducers=outcome.reducers, profile=profile)


class AutotuneReport(Report):
    """Ranked autotune results as a Report (wraps ``AutotuneResults``)."""

    kind = "autotune"

    def __init__(self, results):
        self.results = list(results)
        self.failures = list(getattr(results, "failures", []))

    def __iter__(self):
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i):
        return self.results[i]

    @property
    def best(self):
        return self.results[0] if self.results else None

    def rows(self) -> list[dict]:
        return ([t.summary() for t in self.results]
                + [f.summary() for f in self.failures])

    def summary(self) -> dict:
        return {"kind": self.kind, "candidates": len(self.results),
                "failures": len(self.failures),
                "best": self.best.candidate.name if self.best else None}


class ValidateReport(Report):
    """Measured-vs-predicted validation as a Report.

    Wraps :class:`repro.core.validate.ValidationReport`, exposing its fields
    (``results``, ``failures``, ``dram``, ``measured_bw``,
    ``calibration_factor``) unchanged.
    """

    kind = "validate"

    def __init__(self, report):
        self.raw = report
        self.results = report.results
        self.failures = report.failures
        self.dram = report.dram
        self.measured_bw = report.measured_bw
        self.calibration_factor = report.calibration_factor

    @property
    def max_err_pct(self) -> float:
        return self.raw.max_err_pct

    def rows(self) -> list[dict]:
        return self.raw.rows()

    def summary(self) -> dict:
        return {"kind": self.kind, "kernels": len(self.results),
                "failures": len(self.failures),
                "measured_bw_gbs": self.measured_bw / 1e9,
                "calibration_factor": self.calibration_factor,
                "max_err_pct": self.max_err_pct}


@dataclasses.dataclass(frozen=True)
class RooflineReport(Report):
    """Roofline placement of one design: memory vs compute terms."""

    design: Design
    estimate: Estimate
    t_memory: float               # the Eqs. 1-10 memory time [s]
    t_compute: float              # flops / peak_flops (0 when flops unknown)
    ridge_flops_per_byte: float   # the hw ridge point
    arithmetic_intensity: float   # flops / useful bytes
    peak_bw: float                # hw peak memory bandwidth [B/s]
    kind = "roofline"

    @property
    def t_exe(self) -> float:
        """Roofline time: the slower of the two resources."""
        return max(self.t_memory, self.t_compute)

    @property
    def bottleneck(self) -> str:
        return "memory" if self.t_memory >= self.t_compute else "compute"

    @property
    def memory_bound(self) -> bool:
        return self.bottleneck == "memory"

    def rows(self) -> list[dict]:
        return [{
            "design": self.design.name,
            "t_memory_ms": self.t_memory * 1e3,
            "t_compute_ms": self.t_compute * 1e3,
            "bottleneck": self.bottleneck,
            "arithmetic_intensity": self.arithmetic_intensity,
            "ridge_flops_per_byte": self.ridge_flops_per_byte,
            "eff_bw_gbs": self.estimate.effective_bandwidth / 1e9,
            "peak_bw_gbs": self.peak_bw / 1e9,
            "bound_ratio": self.estimate.bound_ratio,
        }]


# ---------------------------------------------------------------------------
# Session: hardware + calibration + backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Session:
    """Evaluation context every pipeline stage runs in.

    * ``hardware`` — an optional :class:`repro.hw.Hardware` spec (usually
      ``repro.hw.get(name)``); when set, the three legacy views below and
      the calibration factor all derive from it (``with_hardware``);
    * ``dram``/``bsp`` — the faithful FPGA-model hardware (paper Table III),
      used unless a :class:`Design` carries its own override; default: the
      registry's ``stratix10_ddr4_1866`` board;
    * ``hw`` — the TPU-transplant parameters (autotune/predict/roofline
      compute term); default: the registry's ``tpu_v5e`` chip;
    * ``backend`` — how estimates are computed: ``scalar`` (readable
      reference loop), ``numpy-batch`` (vectorized array core, default) or
      ``jax-jit`` (the same core under ``jax.jit``, x64);
    * ``calibration_factor`` — a single measured/modeled scale fitted by
      ``validate`` (1.0 = uncalibrated); all estimated times are multiplied
      by it, so a session calibrated on a stream anchor predicts in
      host-measured seconds.
    """

    dram: DramParams | None = None
    bsp: BspParams | None = None
    hw: TpuParams | None = None
    backend: str = "numpy-batch"
    calibration_factor: float | None = None
    hardware: Hardware | None = None

    def __post_init__(self):
        spec = self.hardware
        if self.dram is None:
            object.__setattr__(self, "dram", spec.dram_params() if spec
                               else _hw_get(DEFAULT_BOARD).dram_params())
        if self.bsp is None:
            object.__setattr__(self, "bsp", spec.bsp_params() if spec
                               else _hw_get(DEFAULT_BOARD).bsp_params())
        if self.hw is None:
            object.__setattr__(self, "hw", spec.tpu_params() if spec
                               else _hw_get(DEFAULT_CHIP).tpu_params())
        if self.calibration_factor is None:
            object.__setattr__(self, "calibration_factor",
                               float(spec.host_factor) if spec else 1.0)
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; pick one of {BACKENDS}")
        if not (self.calibration_factor > 0
                and math.isfinite(self.calibration_factor)):
            raise ValueError("calibration_factor must be finite and > 0")

    # -- derivation ---------------------------------------------------------

    def with_backend(self, backend: str) -> "Session":
        return dataclasses.replace(self, backend=backend)

    def with_dram(self, dram: DramParams) -> "Session":
        # diverging from the spec: the hardware field no longer describes
        # this session, so drop it (autotune cache keys, simulator
        # interleave and future derivations must not read a stale spec).
        return dataclasses.replace(self, dram=dram, hardware=None)

    def with_hardware(self, hardware: Hardware) -> "Session":
        """Session re-anchored on one :class:`repro.hw.Hardware` spec.

        Every view the pipeline consumes — the FPGA-model ``dram``/``bsp``,
        the TPU-transplant ``hw``, and the calibration factor — derives from
        the spec, so all three backends score designs against the same
        serializable description: ``Session().with_hardware(hw.get("tpu_v4"))``.
        """
        return dataclasses.replace(
            self, hardware=hardware,
            dram=hardware.dram_params(), bsp=hardware.bsp_params(),
            hw=hardware.tpu_params(),
            calibration_factor=float(hardware.host_factor))

    def with_calibration(self, report: "ValidateReport") -> "Session":
        """Session re-anchored on a validation report's fitted bandwidth and
        host factor — subsequent estimates predict measured seconds.  Use
        ``with_hardware(Hardware.from_calibration(report))`` to make the
        same re-anchoring persistent (``to_json``)."""
        return dataclasses.replace(
            self, dram=report.dram, hardware=None,
            calibration_factor=float(report.calibration_factor))

    def _hw_for(self, design: Design) -> tuple[DramParams, BspParams]:
        return design.dram or self.dram, design.bsp or self.bsp

    # -- estimate -----------------------------------------------------------

    def estimate(self, design: Design) -> Estimate:
        """Eqs. 1-10 for one design, on this session's backend."""
        dram, bsp = self._hw_for(design)
        if self.backend == "scalar":
            ke = _model._estimate(list(design.lsus), dram, bsp, f=design.f)
            c = self.calibration_factor
            return Estimate(
                t_exe=ke.t_exe * c, t_ideal=ke.t_ideal * c,
                t_ovh=ke.t_ovh * c, bound_ratio=ke.bound_ratio,
                memory_bound=ke.memory_bound,
                total_bytes=float(ke.total_bytes), n_lsu=len(ke.per_lsu),
                backend=self.backend, design=design, per_lsu=ke.per_lsu)
        return self.estimate_many([design])[0]

    def estimate_many(self, designs: Sequence[Design]) -> list[Estimate]:
        """Score many heterogeneous designs in one batched pass."""
        if not designs:
            return []
        if self.backend == "scalar":
            return [self.estimate(d) for d in designs]
        est = self._estimator()(self._batch_for(designs))
        return self._rows_from(est, designs)

    def _batch_for(self, designs: Sequence[Design]) -> _mb.GroupBatch:
        """One GroupBatch over heterogeneous designs (session hw defaults
        applied) — shared by ``estimate_many`` and the serving batcher."""
        hw = [self._hw_for(d) for d in designs]
        return _mb.GroupBatch.from_kernels(
            [list(d.lsus) for d in designs],
            [h[0] for h in hw], [h[1] for h in hw],
            f=[d.f for d in designs])

    def _rows_from(self, est: _mb.BatchEstimate,
                   designs: Sequence[Design]) -> list[Estimate]:
        """Batch rows back out as calibrated per-design Estimates."""
        return [_estimate_row(est, i, backend=self.backend,
                              scale=self.calibration_factor,
                              design=designs[i])
                for i in range(len(designs))]

    # -- sweep --------------------------------------------------------------

    @staticmethod
    def _as_space(space: "Space | Mapping[str, Any] | None",
                  axes: Mapping[str, Any]) -> "Space":
        """Normalize the (space | mapping | keyword axes) calling forms."""
        if space is None:
            return Space.grid(**axes)
        if axes:
            raise TypeError("pass either a Space/mapping or keyword axes, "
                            "not both")
        if isinstance(space, Mapping):
            return Space.grid(**space)
        return space

    def plan(self, space: "Space | Mapping[str, Any] | None" = None, *,
             chunk_size: int | None = None, constraints=(),
             **axes) -> SweepPlan:
        """A frozen, picklable :class:`SweepPlan` for streaming this space.

        The plan is the data-only description of what ``sweep`` would
        stream — normalized axis lists (session hardware defaulted in),
        backend, calibration factor, chunk size and feasibility
        ``constraints`` — and rebuilds its chunk evaluator in any process
        (``plan.evaluator()``), which is how the ``executor="processes"``
        coordinator ships work to spawn-based workers.  ``plan.to_json()``
        round-trips it through text (custom callable constraints pickle
        but do not JSON-encode).  Only grid spaces plan: a random space
        materializes its draws.
        """
        space = self._as_space(space, axes)
        if not space.is_grid:
            raise TypeError("streaming sweeps need a grid space; "
                            "Space.random materializes its draws")
        chunk = chunk_size if chunk_size is not None else space.chunk_size
        chunk = int(chunk) if chunk is not None else DEFAULT_CHUNK
        if self.backend == "jax-jit":
            from repro import compat as _compat

            ndev = _compat.local_device_count()
            if ndev > 1:
                # fixed shapes must tile the device mesh exactly
                chunk = -(-chunk // ndev) * ndev
        return SweepPlan(
            lists=space.lists(dram=self.dram, bsp=self.bsp),
            backend=self.backend,
            calibration_factor=self.calibration_factor,
            chunk_size=chunk,
            constraints=constraints or ())

    def sweep(self, space: "Space | Mapping[str, Any] | None" = None, *,
              chunk_size: int | None = None, reducers=None,
              workers: int | None = None, executor: str = "threads",
              constraints=(), profile: bool = False,
              **axes) -> SweepReport:
        """Score a whole design space through this session's backend.

        Accepts a :class:`Space`, a plain axes mapping (treated as a grid),
        or keyword axes directly: ``sess.sweep(n_ga=[1, 2], simd=[4, 16])``.

        Passing ``chunk_size`` (or a ``Space.grid(...).stream()`` space, or
        explicit ``reducers``) switches to **bounded-memory streaming**:
        points are enumerated lazily, evaluated in fixed-shape chunks (the
        jax-jit estimator compiles exactly once per chunk shape and shards
        chunks across local devices when there are several), and folded
        into online reducers — by default a running Pareto front, a
        ``top_k(10)`` selection and exact summary stats — so a 10M-point
        grid sweeps in O(chunk + front + k) memory.  ``reducers`` takes
        :mod:`repro.core.stream` reducer instances to change what is kept.

        ``executor`` picks how streaming chunks are driven:

        * ``"threads"`` (default) — the in-process pipeline; ``workers``
          sizes the chunk thread pool on the numpy-batch backend (the
          jax-jit backend already shards chunks across devices, and the
          scalar reference loop is GIL-bound — both reject ``workers > 1``
          here);
        * ``"processes"`` — the coordinator/worker process pool
          (:mod:`repro.core.distributed`): the grid is partitioned into
          chunk-aligned id ranges, ``workers`` spawn-based processes each
          rebuild the evaluator from the picklable :class:`SweepPlan`,
          stragglers are re-issued, and the merged report is bit-equal to
          the single-process run on every backend.

        ``constraints`` (a :class:`repro.search.Constraint`, a
        :class:`repro.search.ResourceEnvelope`, a ``callable(cols) ->
        bool mask``, or a sequence of those) restricts the sweep to the
        feasible region: grid points are feasibility-masked *before*
        scoring (on the streaming path, chunk by chunk — infeasible
        points are never evaluated), random spaces rejection-sample, and
        the report's ``summary()`` carries the feasible/candidate split.
        Results are bit-equal to post-filtering the unconstrained sweep.

        ``profile=True`` records a per-stage wall-time breakdown
        (``enumerate``/``transfer``/``score``/``reduce`` seconds, plus the
        pipeline path taken) on ``report.profile`` and in
        ``report.summary()["profile"]`` — the numbers that make a
        points/sec regression attributable to a stage.  Profiling
        serializes the chunk pipeline (per-stage walls need sync points),
        so profiled throughput is a lower bound on the unprofiled run.
        """
        space = self._as_space(space, axes)
        if constraints:
            from repro.search.constraints import normalize_constraints

            constraints = normalize_constraints(constraints)
        else:
            constraints = ()
        if executor not in EXECUTORS:
            raise ValueError(
                f"unknown executor {executor!r}: pick 'threads' (in-process "
                f"chunk pipeline) or 'processes' (coordinator/worker "
                f"process pool)")
        if workers is not None and workers < 1:
            raise ValueError("workers must be >= 1")
        if executor == "threads" and workers is not None and workers > 1:
            if self.backend == "jax-jit":
                raise ValueError(
                    "workers > 1 under executor='threads' does not apply to "
                    "the jax-jit backend (it already shards chunks across "
                    "local devices); use executor='processes' to fan out "
                    "across process workers")
            if self.backend == "scalar":
                raise ValueError(
                    "workers > 1 under executor='threads' cannot speed up "
                    "the scalar backend (the reference loop is GIL-bound); "
                    "use executor='processes' to fan out across process "
                    "workers")
        chunk = chunk_size if chunk_size is not None else space.chunk_size
        if chunk is None and (reducers is not None or workers is not None
                              or executor == "processes"):
            chunk = DEFAULT_CHUNK      # these options all imply streaming
        if chunk is not None:
            if not space.is_grid:
                raise TypeError("streaming sweeps need a grid space; "
                                "Space.random materializes its draws")
            return self._sweep_stream(space, int(chunk), reducers, workers,
                                      executor, constraints, profile)
        prof = {"path": "materialized"} if profile else None
        t0 = _perf_counter() if profile else 0.0
        points, n, cats = space.points(dram=self.dram, bsp=self.bsp,
                                       constraints=constraints)
        if profile:
            prof["enumerate_s"] = _perf_counter() - t0
        n_candidates = None
        if constraints and space.is_grid:
            # Mask the enumerated grid before anything is scored; scoring
            # is per-point independent, so this is bit-equal to scoring
            # everything and filtering after.
            from repro.search.constraints import (
                columns_from_parts,
                feasibility_mask,
            )

            mask = feasibility_mask(
                constraints, columns_from_parts(points, cats, n))
            n_candidates = n
            points = {k: np.asarray(v)[mask] for k, v in points.items()}
            cats = {k: (t, np.asarray(idx)[mask])
                    for k, (t, idx) in cats.items()}
            n = int(np.count_nonzero(mask))
            if n == 0:
                return self._empty_report(cats, n_candidates)
        t0 = _perf_counter() if profile else 0.0
        if self.backend == "scalar":
            result = self._sweep_scalar(points, n, cats)
        else:
            result = _sweep._build(points, n, cats,
                                   estimator=self._estimator())
        if profile:
            prof["score_s"] = _perf_counter() - t0
        est = result.estimate
        if self.calibration_factor != 1.0:
            # The session factor belongs to the *session's* hardware; points
            # fully overridden by a hardware-axis spec already carry that
            # spec's own persisted host_factor and must not be scaled twice.
            hw_col = result.points.get("hardware")
            own = (np.ones(result.n_points, dtype=bool) if hw_col is None
                   else np.asarray([h is None for h in hw_col]))
            c = np.where(own, self.calibration_factor, 1.0)
            est = dataclasses.replace(
                est, t_exe=np.asarray(est.t_exe) * c,
                t_ideal=np.asarray(est.t_ideal) * c,
                t_ovh=np.asarray(est.t_ovh) * c)
        return SweepReport(points=result.points, estimate=est,
                           resource=result.resource, backend=self.backend,
                           n_candidates=n_candidates, profile=prof)

    def _empty_report(self, cats: dict,
                      n_candidates: int | None) -> SweepReport:
        """A zero-row materialized report (constraints ate every point)."""
        points = {name: (_sweep._object_array([])
                         if name in _sweep._CATEGORICAL else np.empty(0))
                  for name in _sweep.AXES}
        est = _mb.BatchEstimate(
            t_exe=np.empty(0), t_ideal=np.empty(0), t_ovh=np.empty(0),
            bound_ratio=np.empty(0),
            memory_bound=np.empty(0, dtype=bool),
            total_bytes=np.empty(0), n_lsu=np.empty(0, dtype=np.int64),
            groups={})
        return SweepReport(points=points, estimate=est,
                           resource=np.empty(0), backend=self.backend,
                           n_candidates=n_candidates)

    def _sweep_scalar(self, points: dict, n: int, cats: dict,
                      ) -> _sweep.SweepResult:
        """Reference scalar loop (moved to ``sweep._score_scalar`` so the
        picklable :class:`SweepPlan` can rebuild it without a session)."""
        return _sweep._score_scalar(points, n, cats)

    # -- streaming sweep ----------------------------------------------------

    def _sweep_stream(self, space: "Space", chunk_size: int, reducers,
                      workers: int | None, executor: str = "threads",
                      constraints: tuple = (),
                      profile: bool = False) -> SweepReport:
        """Chunked, reducer-folded evaluation of a grid space.

        A thin consumer of :class:`SweepPlan`: the plan carries the
        normalized axes + backend + calibration + chunk size, its
        ``evaluator()`` scores chunks (same ``_score`` core and calibration
        as the materialized path), and the reducers fold them — in this
        process (``threads``) or across the coordinator/worker pool
        (``processes``).  Peak memory is O(chunk + front + k); survivor
        rows (front + top-k) are the only points materialized.

        On the jax-jit backend an unconstrained sweep with the standard
        reducers takes the **device-resident fast path**
        (:mod:`repro.core.device_stream`): enumeration, Eqs. 1-10 scoring
        and the reducer folds fuse into one jit-compiled chunk step, with
        reducer state pulled to the host once at the end — bit-equal to
        this host pipeline, which remains the fallback (custom reducers,
        constraints, multi-device sharding, capacity overflow).
        """
        import copy

        from repro.core import stream as _stream

        plan = self.plan(space, chunk_size=chunk_size,
                         constraints=constraints)
        if reducers is None:
            reducers = _stream.default_reducers()
        else:
            # Reducers accumulate state in place; folding a second sweep
            # into instances that already hold the first one's points would
            # silently mix the spaces, so each sweep folds into copies.
            reducers = tuple(copy.deepcopy(r) for r in reducers)
        if not any(isinstance(r, _stream.StatsReducer) for r in reducers):
            reducers += (_stream.StatsReducer(),)

        prof: dict | None = {} if profile else None
        t0 = _perf_counter() if profile else 0.0
        outcome = None
        if executor == "processes":
            from repro.core import distributed as _dist

            outcome = _dist.run_distributed(plan, reducers, workers=workers)
            if prof is not None:
                # per-stage walls live in the worker processes; only the
                # end-to-end wall is observable here
                prof["path"] = "distributed"
        else:
            if self.backend == "jax-jit" and not plan.constraints:
                from repro.core import device_stream as _dev

                outcome = _dev.try_outcome(plan, reducers, profile=prof)
            if outcome is None:
                if prof:
                    prof.clear()     # drop a failed device attempt's stages
                w = workers
                if w is None and self.backend == "numpy-batch":
                    import os

                    w = min(4, os.cpu_count() or 1)
                if prof is not None:
                    prof["path"] = "host-stream"
                    # stage walls need a serial pipeline; see sweep(profile=)
                    outcome = _stream.run_stream(
                        plan.n, plan.chunk_size,
                        plan.evaluator(stage_times=prof), reducers,
                        stage_times=prof)
                else:
                    outcome = _stream.run_stream(
                        plan.n, plan.chunk_size, plan.evaluator(), reducers,
                        workers=w if self.backend == "numpy-batch" else None)
        if prof is not None:
            prof["total_s"] = _perf_counter() - t0
        return _stream_report(
            outcome, plan.tables(), backend=self.backend,
            n_candidates=plan.n if plan.constraints else None,
            profile=prof)

    # -- optimizer-driven search -------------------------------------------

    def optimize(self, space: "Space | Mapping[str, Any] | None" = None, *,
                 objective="t_exe", constraints=(), seed: int = 0,
                 max_evals: int | None = None, n_starts: int = 2,
                 steps: int = 16, screen: int | None = None,
                 chunk_size: int | None = None, **axes):
        """Search a grid space for the best design *without* enumerating it.

        ``objective`` is an estimate/resource column to minimize (default
        ``"t_exe"``), or a pair of columns — e.g. ``("t_exe",
        "resource")`` — to approximate the 2-objective Pareto front.
        ``constraints`` restricts the search to the feasible region
        (same forms as ``sweep``); ``max_evals`` bounds how many grid
        points may be scored (default ``max(1024, n // 128)`` — under 1%
        of any large grid).

        The strategy leans on the model being differentiable end to end:
        a seeded feasible screen picks starting points; the integer axes
        are relaxed to continuous and multi-start AdamW descends through
        the jax-differentiable estimator (one lane per categorical
        combination, envelope caps as smooth penalties); each continuous
        optimum is then refined on its *discrete* neighborhood — and, in
        Pareto mode, a Pareto local search walks ±1-step neighbors of the
        running front — all through the same streaming evaluator a full
        sweep would use, so every reported number is bit-comparable to
        the exhaustive grid.  Requires jax for the descent phase; without
        it the screen/refine phases still run.

        Returns an :class:`repro.search.OptimizeReport` carrying the best
        point, the evaluated front, per-phase trajectory and the
        evals-used telemetry backing the <1%-of-points claim.
        """
        from repro.search.optimize import run_optimize

        space = self._as_space(space, axes)
        return run_optimize(
            self, space, objective=objective, constraints=constraints,
            seed=seed, max_evals=max_evals, n_starts=n_starts,
            steps=steps, screen=screen, chunk_size=chunk_size)

    # -- backend plumbing ---------------------------------------------------

    def _estimator(self) -> Callable[[_mb.GroupBatch], _mb.BatchEstimate]:
        if self.backend == "jax-jit":
            return _jax_estimate_batch
        return _mb.estimate_batch

    # -- the rest of the pipeline ------------------------------------------

    def autotune(self, cfg, shape, mesh, candidates=None, *,
                 cache=True, gather_row_bytes: float = 512.0,
                 ) -> AutotuneReport:
        """Model-guided candidate ranking (lower+compile on CPU, no TPU).

        The session's hardware spec is part of every on-disk cache key, so
        rankings produced under one memory system are never silently reused
        under another.
        """
        from repro.core import autotune as _at

        return AutotuneReport(_at._autotune(
            cfg, shape, mesh, candidates, self.hardware or self.hw,
            cache=cache, gather_row_bytes=gather_row_bytes))

    def validate(self, cases=None, *, iters: int = 3, warmup: int = 1,
                 calibrate: bool = True) -> ValidateReport:
        """Measured-vs-predicted loop over the Pallas kernels.

        With ``calibrate=True`` (default) the stream anchor fits the
        effective bandwidth and a host factor, the paper's methodology.
        With ``calibrate=False`` predictions come from this session's own
        ``dram`` parameters alone — no measured wall-clock enters the
        prediction side, so repeated runs predict identically.
        """
        from repro.core import validate as _validate

        rep = _validate._validate(
            cases, iters=iters, warmup=warmup,
            dram=None if calibrate else self.dram, base=self.dram,
            fit_host_factor=calibrate)
        return ValidateReport(rep)

    def roofline(self, design: Design) -> RooflineReport:
        """Place one design on the roofline: Eqs. 1-10 memory time vs the
        compute floor (``flops / hw.peak_flops``; 0 when flops unknown)."""
        est = self.estimate(design)
        t_compute = design.flops / self.hw.peak_flops
        ai = (design.flops / est.total_bytes if est.total_bytes
              else math.inf if design.flops else 0.0)
        dram, _ = self._hw_for(design)
        return RooflineReport(
            design=design, estimate=est,
            t_memory=est.t_exe, t_compute=t_compute,
            ridge_flops_per_byte=self.hw.ridge_flops_per_byte,
            arithmetic_intensity=ai, peak_bw=dram.bw_mem)

    def predict(self, hlo_text: str, cost: dict | None = None, *,
                gather_row_bytes: float = 512.0):
        """TPU-transplant step prediction from compiled HLO text
        (:func:`repro.core.predictor.predict_step` under this session's hw)."""
        from repro.core import predictor as _pred

        return _pred.predict_step(hlo_text, cost, self.hw,
                                  gather_row_bytes=gather_row_bytes)

    # -- whole-model estimation (repro.workload) ----------------------------

    def _model_hlo_texts(self, model, args, *, phases, batch,
                         seq_len) -> tuple[str, dict[str, str]]:
        """(model name, phase -> compiled HLO text) for every input form
        ``estimate_model``/``plan_model`` accept: HLO text, a mapping of
        phase name -> HLO text, a model-zoo config (lowered via
        ``workload.steps``), or a jittable callable + example args."""
        if isinstance(model, str):
            return "hlo", {"step": model}
        if isinstance(model, Mapping):
            return "hlo", {str(k): str(v) for k, v in model.items()}
        if hasattr(model, "block_pattern"):     # models.config.ModelConfig
            from repro.workload import steps as _steps

            return model.name, {
                p: _steps.phase_hlo(model, p, batch=batch, seq_len=seq_len)
                for p in phases}
        if callable(model):
            import jax

            jitted = model if hasattr(model, "lower") else jax.jit(model)
            text = jitted.lower(*args).compile().as_text()
            return getattr(model, "__name__", "model"), {"step": text}
        raise TypeError(
            f"estimate_model wants HLO text, a mapping of phase -> HLO "
            f"text, a ModelConfig, or a jittable callable; got "
            f"{type(model).__name__}")

    def estimate_model(self, model, *args, phases=("train", "decode"),
                       batch: int = 1, seq_len: int = 128, name: str = "",
                       access_bytes: int | None = None,
                       fused: bool = True) -> "_workload.ModelReport":
        """End-to-end estimate of a whole compiled model step.

        Walks every materialized op of each phase's module
        (:func:`repro.workload.walk_module`), maps each op's access-class
        traffic onto LSU groups, scores all ops in **one** batched Eqs.
        1-10 pass on this session's backend, and composes a
        :class:`~repro.workload.ModelReport` — per-phase totals (defined
        as the sum of the per-op estimates), per-layer and per-op-class
        breakdowns, and the aggregate roofline position.

        ``model`` may be compiled HLO text, a ``{phase: hlo_text}``
        mapping, a model-zoo :class:`~repro.models.config.ModelConfig`
        (its ``phases`` are lowered here at ``batch`` x ``seq_len``; needs
        jax), or a jittable callable with example ``*args``.
        """
        from repro import workload as _wl

        mname, texts = self._model_hlo_texts(
            model, args, phases=phases, batch=batch, seq_len=seq_len)
        records = {p: _wl.walk_module(t, fused=fused)
                   for p, t in texts.items()}
        return _wl.compose_model(self, name or mname, records,
                                 access_bytes=access_bytes)

    def plan_model(self, model, *, phases=("decode",), batch=(1,),
                   seq_len=(128,), shards=(1,), hardware=(None,),
                   chunk_size: int = 256, access_bytes: int | None = None,
                   fused: bool = True,
                   name: str = "") -> "_workload.ModelSweepPlan":
        """A frozen, picklable whole-model sweep plan.

        Every distinct ``(phase, batch, seq_len)`` combination is lowered
        and walked **once here** (the only step that needs jax or the
        model code); the returned :class:`~repro.workload.ModelSweepPlan`
        is pure data — JSON/pickle it to any process and stream it there.
        ``hardware`` axis values may be specs, preset names, or ``None``
        (= this session's hardware).
        """
        from repro import workload as _wl
        from repro.core import validate as _validate

        phases = tuple(phases)
        batch = tuple(int(b) for b in batch)
        seq_len = tuple(int(s) for s in seq_len)
        tables: dict[str, tuple] = {}
        mname = name
        for b in batch:
            for s in seq_len:
                pname, texts = self._model_hlo_texts(
                    model, (), phases=phases, batch=b, seq_len=s)
                mname = mname or pname
                for p in phases:
                    if p not in texts:
                        raise ValueError(
                            f"phase {p!r} not in walked phases "
                            f"{list(texts)}")
                    recs = _wl.walk_module(texts[p], fused=fused)
                    tables[f"{p}|{b}|{s}"] = tuple(
                        {"classes": dict(r.bytes_by_class),
                         "flops": r.flops}
                        for r in recs if r.total_bytes > 0)
        pbytes = 0.0
        if hasattr(model, "block_pattern"):
            from repro.workload import steps as _steps

            pbytes = _steps.param_bytes(model)
        return _wl.ModelSweepPlan(
            model=mname or "model",
            lists={"phase": phases, "batch": batch, "seq_len": seq_len,
                   "shards": tuple(shards), "hardware": tuple(hardware)},
            tables=tables, param_bytes=pbytes,
            dram=self.dram, bsp=self.bsp, backend=self.backend,
            calibration_factor=float(self.calibration_factor),
            chunk_size=chunk_size,
            access_bytes=access_bytes or _validate.ACCESS_BYTES)

    def sweep_model(self, model=None, *, plan=None, phases=("decode",),
                    batch=(1,), seq_len=(128,), shards=(1,),
                    hardware=(None,), chunk_size: int | None = None,
                    reducers=None, k: int = 10,
                    access_bytes: int | None = None, fused: bool = True,
                    ) -> "_workload.ModelSweepReport":
        """Sweep model shape x sharding x hardware through the streaming
        engine.

        With ``chunk_size=None`` (default — model grids are small) the
        whole grid is evaluated in one materialized pass and the report
        holds every point; with a ``chunk_size`` the grid streams through
        ``run_stream`` into Pareto/top-k/stats reducers and the report
        holds the survivors — per-point values are bit-equal either way
        (tested).  Pass a prebuilt ``plan`` to skip lowering.
        """
        from repro import workload as _wl
        from repro.core import stream as _stream

        if plan is None:
            if model is None:
                raise ValueError("sweep_model needs a model or a plan")
            plan = self.plan_model(
                model, phases=phases, batch=batch, seq_len=seq_len,
                shards=shards, hardware=hardware,
                chunk_size=chunk_size or 256, access_bytes=access_bytes,
                fused=fused)
        elif chunk_size is not None:
            plan = dataclasses.replace(plan, chunk_size=chunk_size)

        if chunk_size is None:
            cols = plan.materialize()
            stats = _stream.StatsReducer()
            if len(cols["id"]):
                stats.update(cols)
            return _wl.ModelSweepReport(
                plan, cols, n_total=plan.n, stats=stats.summary(),
                streaming=False)

        reducers = tuple(reducers) if reducers is not None \
            else _stream.default_reducers(k)
        outcome = plan.run(reducers)
        front = next((r for r in outcome.reducers
                      if isinstance(r, _stream.ParetoReducer)), None)
        topk = next((r for r in outcome.reducers
                     if isinstance(r, _stream.TopKReducer)), None)
        stats = next((r for r in outcome.reducers
                      if isinstance(r, _stream.StatsReducer)), None)
        pieces = [r.cols for r in (front, topk)
                  if r is not None and r.cols is not None]
        if pieces:
            merged = {kk: np.concatenate([p[kk] for p in pieces])
                      for kk in pieces[0]}
            _, first = np.unique(
                np.asarray(merged["id"], dtype=np.int64),
                return_index=True)
            merged = {kk: np.asarray(v)[first] for kk, v in merged.items()}
        else:
            merged = {kk: np.empty(0) for kk in _wl.sweep.MODEL_COLUMNS}
        return _wl.ModelSweepReport(
            plan, merged, n_total=outcome.n_points,
            stats=stats.summary() if stats is not None else None,
            streaming=True, reducers=outcome.reducers)

    # -- serving ------------------------------------------------------------

    def serve(self, *, max_batch: int = 64, max_wait_ms: float = 1.0,
              cache_size: int = 4096, max_queue: int = 1024,
              timeout_ms: float | None = None) -> "Server":
        """This session as a long-lived concurrent query service.

        Returns a :class:`Server` whose ``estimate``/``submit``/``predict``
        calls are safe from any number of threads: a background batcher
        collects up to ``max_batch`` concurrent requests (lingering at most
        ``max_wait_ms`` for a partial batch), scores them in one batched
        pass — padded to fixed shapes on the jax-jit backend so the core
        compiles once per shape — and scatters results back to per-request
        futures, bit-equal to serial ``estimate`` calls.  A content-hash
        LRU of ``cache_size`` results sits in front (hits return
        immediately with ``Estimate.cached`` set); ``max_queue`` bounds the
        backlog (beyond it submissions fast-fail with
        :class:`ServerOverloaded`); ``timeout_ms`` is the default
        per-request deadline.  Close with ``server.close()`` or use it as a
        context manager; see ``server.stats()`` for hit/miss/latency
        telemetry and ``benchmarks/serve_bench.py`` for the p50/p99 bench.
        """
        from repro.core.serving import Server

        return Server(self, max_batch=max_batch, max_wait_ms=max_wait_ms,
                      cache_size=cache_size, max_queue=max_queue,
                      timeout_ms=timeout_ms)


# ---------------------------------------------------------------------------
# jax-jit backend
# ---------------------------------------------------------------------------

_JAX_FN = None


def _jax_estimate_batch(batch: _mb.GroupBatch,
                        sharding=None,
                        stage_times: dict | None = None) -> _mb.BatchEstimate:
    """The array core under ``jax.jit`` with x64 — numerically equal to the
    NumPy path (same ops, same dtype), returned as NumPy arrays.

    ``sharding`` (a ``NamedSharding`` from :func:`repro.compat.data_sharding`)
    splits every batch array's leading (group) axis across local devices;
    the jit-compiled core then runs SPMD with XLA inserting the one
    cross-device reduction the per-kernel segment sums need.  The function
    is compiled once per input shape, so fixed-shape streaming chunks reuse
    a single executable for the whole sweep.

    With ``stage_times``, the host->device upload and the device->host
    result pull are accumulated into ``stage_times["transfer_s"]`` (the
    compute between them lands in the caller's score bucket).
    """
    global _JAX_FN
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _mb.enable_jax()
    if _JAX_FN is None:
        def _run(b):
            est = _mb.estimate_batch(b, xp=jnp)
            return {"t_exe": est.t_exe, "t_ideal": est.t_ideal,
                    "t_ovh": est.t_ovh, "bound_ratio": est.bound_ratio,
                    "memory_bound": est.memory_bound,
                    "total_bytes": est.total_bytes, "n_lsu": est.n_lsu,
                    "groups": est.groups}
        _JAX_FN = jax.jit(_run)
    timed = stage_times is not None
    with enable_x64():
        t0 = _perf_counter() if timed else 0.0
        jb = _mb.GroupBatch(**{
            f.name: (batch.n_kernels if f.name == "n_kernels"
                     else jnp.asarray(getattr(batch, f.name)))
            for f in dataclasses.fields(_mb.GroupBatch)})
        if sharding is not None:
            jb = jax.device_put(jb, sharding)
        if timed:
            jax.block_until_ready(jb.count)
            stage_times["transfer_s"] = (stage_times.get("transfer_s", 0.0)
                                         + _perf_counter() - t0)
        dev = _JAX_FN(jb)
        if timed:
            jax.block_until_ready(dev)
            t0 = _perf_counter()
        out = jax.tree_util.tree_map(np.asarray, dev)
        if timed:
            stage_times["transfer_s"] += _perf_counter() - t0
    groups = out.pop("groups")
    return _mb.BatchEstimate(**out, groups=groups)


# ---------------------------------------------------------------------------
# serving layer (implementation in repro.core.serving; surface is
# Session.serve — imported last because serving's type hints point back here)
# ---------------------------------------------------------------------------

from repro.core.serving import (  # noqa: E402
    RequestTimeout,
    Server,
    ServerClosed,
    ServerOverloaded,
)
