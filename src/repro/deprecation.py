"""One shared deprecation channel for the pre-`Session` entry points.

PR 3 consolidated the five disjoint entry points (``model.estimate``,
``sweep.sweep_grid``/``sweep_random``, ``predictor.predict``,
``autotune.autotune``, ``validate.validate``) behind the unified
:class:`repro.Design` / :class:`repro.Session` API.  The old names keep
working for one release through shims that call this helper; internal code
routes through the underlying implementations directly so a
``-W error::DeprecationWarning`` run stays clean (the CI import-surface
check relies on that).
"""
from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str) -> None:
    """Emit the standard one-release deprecation warning for ``old``.

    ``stacklevel=3`` points the warning at the *caller* of the deprecated
    shim (helper -> shim -> caller), so users see their own line, not ours.
    """
    warnings.warn(
        f"{old} is deprecated and will be removed in the next release; "
        f"use {new} instead",
        DeprecationWarning,
        stacklevel=3,
    )
