"""One shared deprecation channel for legacy names.

PR 3 consolidated the five disjoint entry points (``model.estimate``,
``sweep.sweep_grid``/``sweep_random``, ``predictor.predict``,
``autotune.autotune``, ``validate.validate``) behind the unified
:class:`repro.Design` / :class:`repro.Session` API; those shims completed
their one-release cycle and are now removed.  The remaining users are the
PR-4 hardware constant aliases (``repro.core.fpga.DDR4_1866`` … ,
``repro.core.hbm.TPU_V5E``), which keep warning for one more release.
Internal code routes through :mod:`repro.hw` directly so a
``-W error::DeprecationWarning`` run stays clean (the CI import-surface
check relies on that).
"""
from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str) -> None:
    """Emit the standard one-release deprecation warning for ``old``.

    ``stacklevel=3`` points the warning at the *caller* of the deprecated
    shim (helper -> shim -> caller), so users see their own line, not ours.
    """
    warnings.warn(
        f"{old} is deprecated and will be removed in the next release; "
        f"use {new} instead",
        DeprecationWarning,
        stacklevel=3,
    )
