"""One shared deprecation channel for legacy names.

PR 3 consolidated the five disjoint entry points (``model.estimate``,
``sweep.sweep_grid``/``sweep_random``, ``predictor.predict``,
``autotune.autotune``, ``validate.validate``) behind the unified
:class:`repro.Design` / :class:`repro.Session` API, and PR 4 moved the
hardware constants (``repro.core.fpga.DDR4_1866`` …,
``repro.core.hbm.TPU_V5E``) into the :mod:`repro.hw` registry; both shim
generations completed their one-release cycle and are removed (0.5 and
0.6 respectively).  No deprecated name is currently exported — this
module stays as the one channel future deprecations must use, so a
``-W error::DeprecationWarning`` run stays clean by construction (the CI
import-surface check relies on that).
"""
from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str) -> None:
    """Emit the standard one-release deprecation warning for ``old``.

    ``stacklevel=3`` points the warning at the *caller* of the deprecated
    shim (helper -> shim -> caller), so users see their own line, not ours.
    """
    warnings.warn(
        f"{old} is deprecated and will be removed in the next release; "
        f"use {new} instead",
        DeprecationWarning,
        stacklevel=3,
    )
