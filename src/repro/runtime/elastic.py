"""Elastic rescale: resume a run on a different chip count.

Two ingredients already provided elsewhere make this nearly free:
checkpoints are mesh-independent (checkpoint/manager.py) and data is
step-addressable (data/pipeline.py).  This module adds the planner that maps
an available chip count to a valid mesh and the resharding restore.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager


def plan_mesh_shape(n_chips: int, *, model_parallel: int = 16,
                    pod_size: int = 256) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest usable (pod, data, model) mesh for ``n_chips`` available chips.

    Keeps the model axis fixed (sharding-rule compatibility) and scales the
    data axis; spills to a pod axis above ``pod_size`` chips.  Chips that do
    not fill a complete data row are left idle (returned shape may use fewer
    than ``n_chips``)."""
    model = min(model_parallel, n_chips)
    usable = (n_chips // model) * model
    if usable == 0:
        raise ValueError(f"need at least {model_parallel} chips")
    data_total = usable // model
    if usable <= pod_size:
        return (data_total, model), ("data", "model")
    pods = usable // pod_size
    data = pod_size // model
    return (pods, data, model), ("pod", "data", "model")


def resume_on_mesh(ckpt: CheckpointManager, like, mesh: Mesh, shardings,
                   *, step: int | None = None):
    """Restore a checkpoint written on any mesh onto ``mesh``.

    ``shardings`` is the pytree of NamedShardings for the new mesh (from
    launch/sharding.py rules); leaves are placed shard-by-shard."""
    return ckpt.restore(like, step=step, shardings=shardings)
