"""Fault tolerance for long multi-pod runs.

Pieces (wired together by ``launch/train.py``):

* ``PreemptionHandler`` — SIGTERM/SIGINT sets a flag; the loop checkpoints
  and exits cleanly at the next step boundary (maintenance-event survival).
* ``StepWatchdog``     — per-step wall-time tracking with a robust outlier
  rule (> ``factor`` x running median => straggler event).  On a real pod the
  callback would feed the coordinator's slow-host eviction / re-shard
  decision; here it logs and counts (tested by injecting delays).
* auto-resume          — ``CheckpointManager.latest_step`` + deterministic
  data (batch = f(seed, step, shard)) make a restart bit-exact without
  replaying the data stream.

Elastic rescale lives in ``runtime/elastic.py``: checkpoints are
mesh-independent, so a job restarted on fewer/more chips restores the same
logical state under new shardings.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable


class PreemptionHandler:
    """Convert SIGTERM/SIGINT into a cooperative should-stop flag."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = False
        self._installed = False
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            try:
                signal.signal(s, self._handler)
            except ValueError:
                pass  # not in main thread (tests)
        self._installed = True
        return self

    def _handler(self, signum, frame):
        del frame
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def trigger(self) -> None:  # for tests
        self._stop = True


class StepWatchdog:
    """Straggler detection from per-step wall times."""

    def __init__(self, *, factor: float = 3.0, window: int = 50,
                 warmup: int = 5,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.times: list[float] = []
        self.straggler_steps: list[int] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.monotonic()

    def end_step(self) -> float:
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        history = self.times[-self.window:]
        if len(history) >= self.warmup:
            med = statistics.median(history)
            if dt > self.factor * med:
                self.straggler_steps.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, dt, med)
        self.times.append(dt)
        return dt

    @property
    def median_step_time(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
