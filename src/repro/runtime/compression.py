"""Gradient compression for the data-parallel reduce.

Two codecs, applied leaf-wise before the cross-replica reduction and undone
after (configured via ``TrainConfig.grad_compression``):

* ``"bf16"`` — cast f32 grads to bf16 for the wire (2x collective bytes
  saved; the reduction itself stays f32 via XLA's accumulate-in-f32).
* ``"int8"`` — per-leaf symmetric int8 with an f32 scale (4x wire savings;
  scale travels as one extra scalar per leaf).  An optional error-feedback
  buffer carries the quantization residual to the next step (1-bit-Adam
  style), preserving convergence.

Under GSPMD the cast happens *before* the gradient all-reduce/reduce-scatter
is inserted, so the collective moves the compressed representation — the
dry-run HLO shows the reduced collective bytes (EXPERIMENTS.md SPerf).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_grads(grads: Any, method: str | None,
                   error_buf: Any | None = None) -> tuple[Any, Any]:
    """Returns (wire_grads, new_error_buf)."""
    if not method or method == "none":
        return grads, error_buf
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), error_buf
    if method == "int8":
        def q(g, e):
            gf = g.astype(jnp.float32)
            if e is not None:
                gf = gf + e
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            qg = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            err = gf - qg.astype(jnp.float32) * scale
            return (qg, scale), err

        leaves, treedef = jax.tree.flatten(grads)
        eleaves = (jax.tree.leaves(error_buf) if error_buf is not None
                   else [None] * len(leaves))
        if len(eleaves) != len(leaves):
            eleaves = [None] * len(leaves)
        qs, errs = [], []
        for g, e in zip(leaves, eleaves):
            (qg, scale), err = q(g, e)
            qs.append((qg, scale))
            errs.append(err)
        return (jax.tree.unflatten(treedef, qs),
                jax.tree.unflatten(treedef, errs))
    raise ValueError(f"unknown compression {method!r}")


def decompress_grads(wire: Any, method: str | None, like: Any) -> Any:
    if not method or method == "none":
        return wire
    if method == "bf16":
        return jax.tree.map(lambda g, l: g.astype(l.dtype), wire, like)
    if method == "int8":
        def dq(t, l):
            qg, scale = t
            return (qg.astype(jnp.float32) * scale).astype(jnp.float32)
        leaves, treedef = jax.tree.flatten(like)
        wl = jax.tree.leaves(wire, is_leaf=lambda t: isinstance(t, tuple))
        return jax.tree.unflatten(treedef,
                                  [dq(t, l) for t, l in zip(wl, leaves)])
    raise ValueError(f"unknown compression {method!r}")
