from repro.runtime.fault_tolerance import PreemptionHandler, StepWatchdog
from repro.runtime.compression import compress_grads, decompress_grads
