"""Version-adaptive jax/Pallas compatibility layer.

Every jax API this repo uses that has drifted across released versions is
centralized here, so kernels, models, launch code, and test subprocess
snippets all import the *same* resolution instead of scattering per-file
``try/except ImportError`` shims:

* ``tpu_compiler_params(**kw)`` — ``pltpu.CompilerParams`` (new name) vs
  ``pltpu.TPUCompilerParams`` (jax 0.4.x), with unknown-field dropping so a
  kwarg added in a newer jax does not break an older one.
* ``prefetch_scalar_grid_spec(**kw)`` — ``pltpu.PrefetchScalarGridSpec``
  under whichever module layout this jax ships.
* ``make_mesh(shape, axes)`` — ``jax.sharding.AxisType`` landed in jax 0.5;
  older versions build implicitly-Auto meshes without the kwarg.
* ``optimization_barrier(x)`` — jax < 0.5 has no differentiation rule for
  the ``optimization_barrier`` primitive; this wrapper substitutes a
  ``custom_jvp`` identity-tangent barrier there so remat'd training still
  differentiates (the barrier only pins scheduling, it is mathematically
  the identity).
* ``default_interpret(flag)`` — one place deciding when Pallas kernels run
  in interpret mode (everywhere except a real TPU backend).
* ``local_device_count()`` / ``data_sharding(n)`` — device discovery and a
  1-D leading-axis ``NamedSharding`` (built through ``make_mesh`` so the
  AxisType drift stays here); the streaming sweep engine shards each
  fixed-shape chunk batch with it.
* ``enable_compilation_cache(dir)`` — jax's persistent compilation cache
  under whichever config spelling this jax ships; the device-resident
  streaming step (:mod:`repro.core.device_stream`) is recompiled per
  (chunk size, reducer config) and every cache hit saves a full XLA
  compile in fresh processes (benchmarks, distributed workers).

The module imports jax but never touches device state at import time, so it
is safe to import before ``XLA_FLAGS`` tricks (dry-run, subprocess tests).
"""
from __future__ import annotations

import dataclasses
import functools

import jax

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())

try:  # pure-python import; present on all backends
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:  # pragma: no cover - ancient jax without pallas
    _pltpu = None

try:  # AxisType landed in jax 0.5; older jax means implicitly-Auto axes.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


# ---------------------------------------------------------------------------
# Pallas TPU
# ---------------------------------------------------------------------------

def tpu_compiler_params_cls():
    """The TPU compiler-params class under whichever name this jax ships."""
    if _pltpu is None:  # pragma: no cover
        return None
    return (getattr(_pltpu, "CompilerParams", None)
            or getattr(_pltpu, "TPUCompilerParams", None))


def tpu_compiler_params(**kwargs):
    """Build TPU ``compiler_params`` for ``pl.pallas_call`` portably.

    Unknown fields are dropped rather than raising, so a parameter that only
    exists in newer jax degrades to the compiler default on older jax.
    Returns ``None`` (pallas_call accepts it) when no params class exists.
    """
    cls = tpu_compiler_params_cls()
    if cls is None:  # pragma: no cover
        return None
    try:
        return cls(**kwargs)
    except TypeError:
        if dataclasses.is_dataclass(cls):
            known = {f.name for f in dataclasses.fields(cls)}
            return cls(**{k: v for k, v in kwargs.items() if k in known})
        raise


def prefetch_scalar_grid_spec(**kwargs):
    """``pltpu.PrefetchScalarGridSpec`` across module layouts."""
    if _pltpu is None or not hasattr(_pltpu, "PrefetchScalarGridSpec"):
        raise NotImplementedError(
            "this jax has no PrefetchScalarGridSpec; scalar-prefetch kernels "
            "need jax >= 0.4.20")
    return _pltpu.PrefetchScalarGridSpec(**kwargs)


def default_interpret(interpret: bool | None = None, *,
                      backend: str | None = None) -> bool:
    """Resolve a kernel wrapper's ``interpret`` flag.

    Explicit True/False wins; ``None`` means "interpret everywhere except a
    real TPU backend" — the single policy all ops.py wrappers share.
    """
    if interpret is not None:
        return interpret
    return (backend or jax.default_backend()) != "tpu"


# ---------------------------------------------------------------------------
# Meshes
# ---------------------------------------------------------------------------

def make_mesh(shape, axes, *, explicit: bool = False):
    """``jax.make_mesh`` with AxisType when available, without it otherwise."""
    if AxisType is not None:
        kind = AxisType.Explicit if explicit else AxisType.Auto
        return jax.make_mesh(shape, axes, axis_types=(kind,) * len(axes))
    return jax.make_mesh(shape, axes)


def local_device_count(backend: str | None = None) -> int:
    """Visible local device count; 1 when the backend cannot initialize.

    The streaming sweep engine uses this to decide whether chunks are worth
    sharding — a RuntimeError (e.g. a TPU backend requested on a CPU host)
    must degrade to single-device, not crash a sweep.
    """
    try:
        return jax.local_device_count(backend)
    except RuntimeError:
        return 1


def data_sharding(n: int | None = None):
    """``NamedSharding`` splitting a leading axis across ``n`` local devices.

    Built on a 1-D ``("data",)`` mesh through :func:`make_mesh`, so the
    AxisType drift is handled in one place.  This is the sharding the
    streaming sweep applies to each fixed-shape chunk batch (the leading
    axis is the LSU-group dimension, ``2 * chunk_size`` entries).
    """
    from jax.sharding import NamedSharding, PartitionSpec

    n = int(n if n is not None else local_device_count())
    mesh = make_mesh((n,), ("data",))
    return NamedSharding(mesh, PartitionSpec("data"))


# ---------------------------------------------------------------------------
# persistent compilation cache
# ---------------------------------------------------------------------------

_COMPILATION_CACHE_ON = False


def enable_compilation_cache(cache_dir: str | None = None) -> bool:
    """Turn on jax's persistent (on-disk) compilation cache. Idempotent.

    ``cache_dir`` defaults to ``$JAX_COMPILATION_CACHE_DIR`` or
    ``~/.cache/repro/jax_cache``.  The min-compile-time / min-entry-size
    thresholds are lowered where this jax supports them so even fast
    compiles (the per-chunk-size streaming step) are cached.  Returns False
    — never raises — when this jax has no usable cache config or the
    directory cannot be created, so callers can treat the cache as a pure
    optimization.
    """
    global _COMPILATION_CACHE_ON
    if _COMPILATION_CACHE_ON:
        return True
    import os
    path = (cache_dir
            or os.environ.get("JAX_COMPILATION_CACHE_DIR")
            or os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "jax_cache"))
    try:
        os.makedirs(path, exist_ok=True)
    except OSError:  # pragma: no cover - unwritable home
        return False
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
    except (AttributeError, ValueError):  # pragma: no cover - ancient jax
        try:
            from jax.experimental.compilation_cache import (
                compilation_cache as _cc)
            _cc.set_cache_dir(str(path))
        except Exception:
            return False
    for flag, value in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                        ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(flag, value)
        except (AttributeError, ValueError):  # pragma: no cover - old jax
            pass
    _COMPILATION_CACHE_ON = True
    return True


# ---------------------------------------------------------------------------
# optimization_barrier
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def barrier_is_differentiable() -> bool:
    """Whether this jax ships a differentiation rule for the barrier."""
    try:
        jax.grad(lambda x: jax.lax.optimization_barrier(x) * 1.0)(0.0)
        return True
    except NotImplementedError:
        return False


@jax.custom_vjp
def _barrier_custom(x):
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier_custom(x), None


def _barrier_bwd(_, g):
    # The barrier is the identity; barrier the cotangent too so the backward
    # pass keeps the same scheduling pin as the forward (custom_vjp rather
    # than custom_jvp: the tangent-side barrier would need the primitive's
    # transpose rule, which old jax also lacks).
    return (jax.lax.optimization_barrier(g),)


_barrier_custom.defvjp(_barrier_fwd, _barrier_bwd)


def optimization_barrier(x):
    """Differentiable ``jax.lax.optimization_barrier`` on every jax version."""
    if barrier_is_differentiable():
        return jax.lax.optimization_barrier(x)
    return _barrier_custom(x)
