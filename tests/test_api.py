"""Unified Design/Session API: builders, backend equivalence, deprecation
shims, satellite bug-fix regressions, and the validate smoke."""
import dataclasses
import warnings

import numpy as np
import pytest

import repro
from repro import Design, Estimate, Session, Space
from repro.core import DDR4_1866, DDR4_2666, LsuType, STRATIX10_BSP
from repro.core.apps import microbench
from repro.core.fpga import BspParams

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]

#: ~1k-point grid shared by the backend-equivalence tests (the acceptance
#: criterion's "shared 1k-point design grid").
GRID = dict(
    lsu_type=ALL_TYPES,
    n_ga=[1, 2, 4],
    simd=[1, 4, 16],
    n_elems=[1 << 14, 1 << 16],
    delta=[1, 2, 7],
    include_write=[False, True],
    dram=[DDR4_1866, DDR4_2666],
)   # 4*3*3*2*3*2*2 = 864 points


class TestDesign:
    def test_microbench_matches_apps(self):
        d = Design.microbench(LsuType.BC_WRITE_ACK, n_ga=2, simd=4,
                              n_elems=1 << 12)
        ref = microbench(LsuType.BC_WRITE_ACK, n_ga=2, simd=4,
                         n_elems=1 << 12)
        assert list(d.lsus) == ref
        assert d.f == 4
        assert d.n_lsu == len(ref)

    def test_microbench_normalizes_inert_stride(self):
        """Stride is inert for write-ACK/atomic — same design either way."""
        a = Design.microbench(LsuType.ATOMIC_PIPELINED, n_ga=1, delta=1,
                              n_elems=1 << 12)
        b = Design.microbench(LsuType.ATOMIC_PIPELINED, n_ga=1, delta=7,
                              n_elems=1 << 12)
        assert a.lsus == b.lsus

    def test_with_helpers_are_pure(self):
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=1)
        d2 = d.with_dram(DDR4_2666).with_f(4).with_name("x")
        assert (d.dram, d.f, d.name.startswith("microbench")) == \
            (None, 16, True)
        assert (d2.dram, d2.f, d2.name) == (DDR4_2666, 4, "x")
        assert d2.lsus == d.lsus
        with pytest.raises(dataclasses.FrozenInstanceError):
            d.f = 2

    def test_with_access_appends(self):
        d = Design(lsus=()).with_access(
            LsuType.BC_ALIGNED, n_elems=1 << 10, f=4)
        d = d.with_access(LsuType.ATOMIC_PIPELINED, n_elems=1 << 8)
        assert [l.lsu_type for l in d.lsus] == [LsuType.BC_ALIGNED,
                                                LsuType.ATOMIC_PIPELINED]
        assert d.total_bytes > 0 and d.resource_bytes > 0

    def test_from_app(self):
        d = Design.from_app("vectoradd", 1 << 16)
        assert d.name == "vectoradd" and d.n_lsu >= 2

    def test_from_classes_uses_validate_mapping(self):
        d = Design.from_classes({"stream": 1 << 20, "gather": 1 << 12},
                                flops=123.0, name="hlo")
        types = {l.name: l.lsu_type for l in d.lsus}
        assert types["stream"] is LsuType.BC_ALIGNED
        assert types["gather"] is LsuType.BC_WRITE_ACK
        assert d.flops == 123.0
        assert d.total_bytes == pytest.approx((1 << 20) + (1 << 12), rel=1e-3)

    def test_from_kernel_reads_compiled_traffic(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp

        d = Design.from_kernel(
            lambda a, b: (a + b).sum(),
            jax.ShapeDtypeStruct((1 << 14,), jnp.float32),
            jax.ShapeDtypeStruct((1 << 14,), jnp.float32))
        assert d.n_lsu >= 1 and d.total_bytes > 0 and d.flops > 0
        est = Session().estimate(d)
        assert est.t_exe > 0 and np.isfinite(est.t_exe)


class TestSessionEstimate:
    def test_backend_dispatch_equivalent(self):
        d = Design.microbench(LsuType.BC_NON_ALIGNED, n_ga=3, simd=16,
                              n_elems=1 << 16, delta=7)
        ests = {b: Session(backend=b).estimate(d)
                for b in ("scalar", "numpy-batch")}
        for e in ests.values():
            assert isinstance(e, Estimate)
        assert ests["scalar"].t_exe == pytest.approx(
            ests["numpy-batch"].t_exe, rel=1e-9)
        assert ests["scalar"].memory_bound == ests["numpy-batch"].memory_bound
        # the scalar backend carries the readable per-LSU breakdown
        assert len(ests["scalar"].per_lsu) == d.n_lsu

    def test_design_hardware_overrides_session(self):
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, n_elems=1 << 16)
        base = Session(dram=DDR4_1866).estimate(d).t_exe
        over = Session(dram=DDR4_1866).estimate(d.with_dram(DDR4_2666)).t_exe
        faster = Session(dram=DDR4_2666).estimate(d).t_exe
        assert over == pytest.approx(faster, rel=1e-12)
        assert over < base

    def test_estimate_many_matches_single(self):
        designs = [Design.microbench(t, n_ga=2, n_elems=1 << 14)
                   for t in ALL_TYPES]
        sess = Session()
        many = sess.estimate_many(designs)
        for d, e in zip(designs, many):
            assert e.t_exe == pytest.approx(sess.estimate(d).t_exe, rel=1e-12)

    def test_calibration_factor_scales_times(self):
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, n_elems=1 << 14)
        raw = Session().estimate(d)
        cal = dataclasses.replace(Session(), calibration_factor=2.0).estimate(d)
        assert cal.t_exe == pytest.approx(2.0 * raw.t_exe, rel=1e-12)
        assert cal.bound_ratio == raw.bound_ratio   # classification unscaled

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            Session(backend="cuda")


class TestBackendEquivalence:
    """Acceptance: all three backends element-wise equal (<= 1e-6) through
    Session.sweep on the shared grid."""

    def test_scalar_vs_batch(self):
        sp = Space.grid(**GRID)
        ref = Session(backend="numpy-batch").sweep(sp)
        got = Session(backend="scalar").sweep(sp)
        assert ref.n_points == got.n_points >= 800
        np.testing.assert_allclose(got.t_exe, ref.t_exe, rtol=1e-6, atol=0.0)
        np.testing.assert_allclose(np.asarray(got.estimate.bound_ratio),
                                   np.asarray(ref.estimate.bound_ratio),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got.memory_bound),
                                      np.asarray(ref.memory_bound))
        np.testing.assert_allclose(got.resource, ref.resource, rtol=1e-12)

    def test_jax_jit_vs_batch(self):
        pytest.importorskip("jax")
        sp = Space.grid(**GRID)
        ref = Session(backend="numpy-batch").sweep(sp)
        got = Session(backend="jax-jit").sweep(sp)
        np.testing.assert_allclose(got.t_exe, ref.t_exe, rtol=1e-6, atol=0.0)
        np.testing.assert_allclose(np.asarray(got.estimate.total_bytes),
                                   np.asarray(ref.estimate.total_bytes),
                                   rtol=1e-9)
        np.testing.assert_array_equal(np.asarray(got.memory_bound),
                                      np.asarray(ref.memory_bound))

    def test_jax_jit_single_estimate(self):
        pytest.importorskip("jax")
        d = Design.microbench(LsuType.BC_WRITE_ACK, n_ga=2, simd=4,
                              n_elems=1 << 14)
        a = Session(backend="numpy-batch").estimate(d)
        b = Session(backend="jax-jit").estimate(d)
        assert b.t_exe == pytest.approx(a.t_exe, rel=1e-6)


class TestSweepReport:
    def test_report_protocol(self):
        res = Session().sweep(Space.grid(lsu_type=ALL_TYPES, n_ga=[1, 2, 4],
                                         n_elems=[1 << 14]))
        assert res.kind == "sweep"
        rows = res.rows()
        assert len(rows) == res.n_points
        csv_text = res.to_csv()
        assert csv_text.splitlines()[0].startswith("lsu_type")
        s = res.summary()
        assert s["n_points"] == res.n_points and s["backend"] == "numpy-batch"
        best = res.best()
        assert best.t_exe == pytest.approx(float(np.min(res.t_exe)))

    def test_random_space(self):
        res = Session().sweep(Space.random(
            64, seed=7, lsu_type=ALL_TYPES, n_ga=(1, 8),
            simd=[1, 2, 4, 8, 16], n_elems=(1 << 12, 1 << 16)))
        assert res.n_points == 64
        assert np.all(np.asarray(res.t_exe) > 0)

    def test_sweep_kwargs_shorthand(self):
        a = Session().sweep(Space.grid(n_ga=[1, 2], n_elems=[1 << 14]))
        b = Session().sweep(n_ga=[1, 2], n_elems=[1 << 14])
        np.testing.assert_allclose(a.t_exe, b.t_exe, rtol=0)
        with pytest.raises(TypeError):
            Session().sweep(Space.grid(n_ga=[1]), n_ga=[2])


class TestStreamingSurface:
    """API semantics of streaming sweeps (bit-equality lives in
    tests/test_stream.py)."""

    def test_space_stream_marks_grid(self):
        sp = Space.grid(n_ga=[1, 2]).stream(chunk_size=64)
        assert sp.chunk_size == 64
        assert Space.grid(n_ga=[1]).chunk_size is None
        with pytest.raises(ValueError):
            Space.grid(n_ga=[1]).stream(chunk_size=0)
        with pytest.raises(TypeError):
            Space.random(4, n_ga=(1, 2)).stream()

    def test_stream_report_protocol(self):
        sp = Space.grid(lsu_type=ALL_TYPES, n_ga=[1, 2, 4],
                        n_elems=[1 << 14]).stream(chunk_size=5)
        res = Session().sweep(sp)
        assert res.is_streaming and res.kind == "sweep"
        assert res.n_points == 12           # the whole space...
        assert len(res.resource) <= 12      # ...but only survivors held
        assert len(res.rows()) == len(res.resource)
        assert res.to_csv().splitlines()[0].startswith("lsu_type")
        s = res.summary()
        assert s["n_points"] == 12 and s["backend"] == "numpy-batch"
        best = res.best()
        assert best.t_exe == pytest.approx(float(np.min(res.t_exe)))

    def test_reducers_imply_streaming(self):
        from repro.core.stream import StatsReducer, TopKReducer

        res = Session().sweep(Space.grid(n_ga=[1, 2], n_elems=[1 << 14]),
                              reducers=[TopKReducer(1), StatsReducer()])
        assert res.is_streaming and len(res.resource) == 1

    def test_stream_report_guards(self):
        # 12 points > the default TopKReducer(10): the selection truncates
        res = Session().sweep(Space.grid(n_ga=list(range(1, 13)),
                                         n_elems=[1 << 14]), chunk_size=5)
        with pytest.raises(ValueError, match="front"):
            res.pareto(["t_exe", "total_bytes"])
        with pytest.raises(ValueError, match="top"):
            res.top_k(10_000)
        with pytest.raises(ValueError, match="top-k by"):
            res.top_k(1, key="resource")
        # ...but a reducer that kept the whole space answers any k, like
        # the materialized path
        small = Session().sweep(Space.grid(n_ga=[1, 2], n_elems=[1 << 14]),
                                chunk_size=1)
        assert len(small.top_k(10_000)) == 2
        # and pareto() without a ParetoReducer raises the helpful error
        from repro.core.stream import StatsReducer, TopKReducer

        nofront = Session().sweep(
            Space.grid(n_ga=[1, 2], n_elems=[1 << 14]),
            reducers=[TopKReducer(5), StatsReducer()])
        with pytest.raises(ValueError, match="front"):
            nofront.pareto()

    def test_random_space_cannot_stream(self):
        with pytest.raises(TypeError, match="grid"):
            Session().sweep(Space.random(8, n_ga=(1, 2)), chunk_size=4)


class TestSatelliteFixes:
    def test_random_n_elems_rounds_to_own_simd(self):
        """Per-point rounding keeps samples in range even when the LCM of the
        sampled simd values would leave it (the PR 1 debt)."""
        res = Session().sweep(Space.random(
            256, seed=3, simd=[3, 5], n_elems=(30, 60)))
        ne = np.asarray(res.points["n_elems"], dtype=np.int64)
        simd = np.asarray(res.points["simd"], dtype=np.int64)
        assert np.all(ne % simd == 0)
        # lcm(3,5)=15 rounding would forbid e.g. 33; per-point must keep all
        # samples inside the requested range (every multiple of 3 or 5 in
        # [30, 60] is reachable).
        assert np.all((ne >= 30) & (ne <= 60))
        assert len(np.unique(ne)) > len(np.unique((ne // 15) * 15))

    def test_random_tuple_of_categoricals_samples_both(self):
        """A 2-tuple of LsuType values is a value list, not a numeric range
        (the old detection only looked at the first element)."""
        res = Session().sweep(Space.random(
            128, seed=5,
            lsu_type=(LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK),
            n_ga=(1, 4), n_elems=(1 << 10, 1 << 12)))
        types = set(np.asarray(res.points["lsu_type"]).tolist())
        assert types == {LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK}
        ga = np.asarray(res.points["n_ga"], dtype=np.int64)
        assert ga.min() >= 1 and ga.max() <= 4      # ranges still ranges

    def test_random_tuple_of_bools_is_a_value_list(self):
        """(False, True) samples the two values — booleans are categorical,
        never an integer range."""
        res = Session().sweep(Space.random(
            64, seed=9, include_write=(False, True), n_elems=(1 << 10, 1 << 12)))
        iw = res.points["include_write"]
        assert set(np.asarray(iw).tolist()) <= {False, True}

    def test_atomic_include_write_is_inert(self):
        """include_write must not create phantom distinct atomic designs."""
        res = Session().sweep(Space.grid(
            lsu_type=[LsuType.ATOMIC_PIPELINED], n_ga=[1, 2],
            n_elems=[1 << 12], include_write=[False, True]))
        iw = np.asarray(res.points["include_write"], dtype=bool)
        assert not iw.any()          # normalized: atomics ARE the write
        t = np.asarray(res.t_exe).reshape(2, 2)   # [n_ga, include_write]
        np.testing.assert_array_equal(t[:, 0], t[:, 1])

    def test_pareto_front_unchanged_by_rewrite(self):
        """The O(F) front keeps the exact brute-force semantics."""
        from repro.core.sweep import pareto_front

        rng = np.random.default_rng(11)
        vals = rng.random((300, 2))
        vals[rng.integers(0, 300, 30)] = vals[rng.integers(0, 300, 30)]
        front = set(pareto_front(vals).tolist())
        dominated = {
            j for j in range(len(vals)) for i in range(len(vals))
            if i != j and np.all(vals[i] <= vals[j])
            and np.any(vals[i] < vals[j])
        }
        assert front == set(range(len(vals))) - dominated


class TestRemovedEntryPoints:
    """The PR-3 deprecation shims completed their cycle and are gone; the
    PR-4 hardware aliases (tested in test_hw.py) remain for one release."""

    def test_shims_are_removed(self):
        from repro.core import autotune, model, predictor, sweep, validate

        for mod, name in ((model, "estimate"), (sweep, "sweep_grid"),
                          (sweep, "sweep_random"), (predictor, "predict"),
                          (autotune, "autotune"), (validate, "validate")):
            assert not hasattr(mod, name), f"{mod.__name__}.{name} lingers"

    def test_repro_core_no_longer_reexports_estimate(self):
        import repro.core as core

        assert not hasattr(core, "estimate")
        assert not hasattr(core, "sweep_grid")

    def test_import_surface_is_warning_free(self):
        """`import repro` + the curated names never trigger the shims."""
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            import importlib

            importlib.reload(repro)
            assert repro.Session and repro.Design and repro.Space
            for name in repro.__all__:
                assert getattr(repro, name) is not None


class TestSessionValidate:
    def test_validate_smoke_cpu_interpret(self):
        """Session.validate closes the measured-vs-predicted loop on the two
        cheapest membench kernels in CPU interpret mode."""
        jax = pytest.importorskip("jax")
        from repro.core import validate as V

        cases = [c for c in V.default_cases()
                 if c.name in ("membench_aligned", "membench_strided")]
        rep = Session().validate(cases, iters=1, warmup=1)
        assert rep.kind == "validate"
        assert len(rep.results) >= 1, rep.failures
        for r in rep.results:
            assert np.isfinite(r.err_pct) and r.measured_s > 0
        assert rep.calibration_factor > 0
        # report protocol: rows/to_csv/summary all work
        assert len(rep.rows()) == len(rep.results)
        assert rep.to_csv().startswith("kernel")
        assert rep.summary()["kernels"] == len(rep.results)
        # a session calibrated on the report predicts in measured seconds
        sess = Session().with_calibration(rep)
        assert sess.calibration_factor == pytest.approx(
            rep.calibration_factor)

    def test_validate_uncalibrated_predicts_from_model_alone(self):
        """calibrate=False: no measured wall-clock enters the prediction
        side — the session dram scores raw and the host factor stays 1."""
        pytest.importorskip("jax")
        from repro.core import validate as V

        cases = [c for c in V.default_cases()
                 if c.name == "membench_aligned"]
        sess = Session()
        rep = sess.validate(cases, iters=1, warmup=1, calibrate=False)
        assert rep.calibration_factor == 1.0
        assert rep.dram == sess.dram
        if rep.results:   # prediction = the raw model on the session dram,
            r = rep.results[0]          # independent of this run's timings
            assert np.isfinite(r.predicted_s) and r.predicted_s > 0

    def test_roofline_report(self):
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, n_elems=1 << 14)
        rl = Session().roofline(d)
        assert rl.bottleneck == "memory" and rl.memory_bound
        assert rl.t_exe == pytest.approx(rl.t_memory)
        assert rl.rows()[0]["eff_bw_gbs"] > 0
