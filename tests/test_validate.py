"""Measured-vs-predicted harness: unit tests for the mapping/calibration
pieces plus the end-to-end regression that validation produces finite errors
for at least 3 kernels on CPU interpret mode."""
import dataclasses

import numpy as np
import pytest

from repro.core import DDR4_1866
from repro.core.lsu import LsuType
from repro.core import validate as V


class TestUnits:
    def test_lsus_from_classes_mapping(self):
        lsus = V.lsus_from_classes(
            {"stream": 1 << 20, "strided": 1 << 16, "gather": 1 << 12})
        types = {l.name: l.lsu_type for l in lsus}
        assert types["stream"] is LsuType.BC_ALIGNED
        assert types["strided"] is LsuType.BC_NON_ALIGNED
        assert types["gather"] is LsuType.BC_WRITE_ACK
        # traffic preserved at access granularity
        total = sum(l.ls_acc * l.ls_bytes for l in lsus)
        assert total == pytest.approx((1 << 20) + (1 << 16) + (1 << 12),
                                      rel=1e-3)

    def test_lsus_from_classes_skips_empty(self):
        assert V.lsus_from_classes({"stream": 0.0}) == []

    def test_calibrate_dram_hits_target_bandwidth(self):
        d = V.calibrate_dram(40e9)
        assert d.bw_mem == pytest.approx(40e9)
        assert d.t_rcd == DDR4_1866.t_rcd   # datasheet timings untouched

    def test_time_callable_positive(self):
        import jax.numpy as jnp

        t = V.time_callable(lambda x: x + 1, (jnp.ones(8),), iters=2,
                            warmup=1)
        assert np.isfinite(t) and t > 0


class TestHarness:
    def test_failed_case_becomes_record_not_exception(self):
        def boom():
            raise RuntimeError("no kernel here")

        rep = V._validate([V.ValidationCase("broken", boom)], iters=1)
        assert rep.results == []
        assert len(rep.failures) == 1
        assert "no kernel here" in rep.failures[0]["error"]

    @pytest.mark.slow
    def test_finite_errors_for_at_least_three_kernels(self):
        """The acceptance regression: the loop closes end to end on CPU."""
        cases = [c for c in V.default_cases()
                 if c.name in ("membench_aligned", "membench_strided",
                               "rglru_scan", "decode_attention")]
        rep = V._validate(cases, iters=2, warmup=1)
        assert len(rep.results) >= 3, rep.failures
        for r in rep.results:
            assert np.isfinite(r.err_pct), r
            assert np.isfinite(r.measured_s) and r.measured_s > 0
            assert np.isfinite(r.predicted_s) and r.predicted_s > 0
            assert r.bytes_moved > 0
        # calibration anchors the stream case (error ~0 by construction)
        anchor = [r for r in rep.results if r.name == "membench_aligned"]
        assert anchor and anchor[0].err_pct < 1e-6
        assert rep.calibration_factor > 0
        # rows are CSV-able (paper_tables contract)
        rows = rep.rows()
        assert all(set(rows[0]) == set(r) for r in rows)
