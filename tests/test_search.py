"""repro.search: resource envelopes, the constraint algebra, feasibility-
masked streaming (bit-equal to post-filtering), constrained random
sampling, and the gradient-based Session.optimize."""
import json

import numpy as np
import pytest

from repro import Session, Space
from repro.core import DDR4_1866, DDR4_2666, LsuType
from repro.core.stream import StatsReducer
from repro.search import (
    BoundConstraint,
    LambdaConstraint,
    ResourceEnvelope,
    usage_from_axes,
    usage_of_design,
    within,
)
from repro.search.constraints import (
    columns_from_lists,
    constraint_from_json,
    constraint_to_json,
    feasibility_mask,
    normalize_constraints,
)

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]

GRID = dict(
    lsu_type=ALL_TYPES,
    n_ga=[1, 2, 4],
    simd=[1, 4, 16],
    n_elems=[1 << 14, 1 << 16],
    delta=[1, 2, 7],
    include_write=[False, True],
    dram=[DDR4_1866, DDR4_2666],
)

ENV = ResourceEnvelope(lsu_ports=6, interconnect_bytes=64)


# ---------------------------------------------------------------------------
# envelopes
# ---------------------------------------------------------------------------


def test_envelope_round_trip():
    env = ResourceEnvelope(lsu_ports=128, interconnect_bytes=4096,
                           buffer_bytes=30e6)
    again = ResourceEnvelope.from_json(env.to_json())
    assert again == env
    assert again.dram_channels is None
    assert env.caps() == {"lsu_ports": 128.0, "interconnect_bytes": 4096.0,
                          "buffer_bytes": 30e6}


def test_envelope_rejects_negative_and_newer_schema():
    with pytest.raises(ValueError, match="must be >= 0"):
        ResourceEnvelope(lsu_ports=-1)
    with pytest.raises(ValueError, match="newer"):
        ResourceEnvelope.from_dict({"schema": 99, "lsu_ports": 4})


def test_envelope_rides_on_hardware():
    from repro import hw

    board = hw.get("stratix10_ddr4_1866")
    assert board.envelope is not None
    again = hw.Hardware.from_json(board.to_json())
    assert again.envelope == board.envelope


def test_usage_of_design_matches_vectorized():
    from repro import Design
    from repro.core.stream import GridEnumerator

    for t in ALL_TYPES:
        for n_ga, simd, iw in [(1, 1, False), (4, 16, True), (2, 4, True)]:
            d = Design.microbench(t, n_ga=n_ga, simd=simd, n_elems=1 << 14,
                                  include_write=iw)
            scalar = usage_of_design(d)
            lists = Session().plan(Space.grid(
                lsu_type=[t], n_ga=[n_ga], simd=[simd], n_elems=[1 << 14],
                include_write=[iw])).lists
            enum = GridEnumerator({k: list(v) for k, v in lists.items()})
            cols = columns_from_lists(lists,
                                      enum.codes(np.zeros(1, np.int64)))
            for col in ("lsu_ports", "interconnect_bytes", "dram_channels",
                        "buffer_bytes"):
                assert scalar[col] == pytest.approx(float(cols[col][0])), \
                    (t, n_ga, simd, iw, col)


# ---------------------------------------------------------------------------
# feasibility-masked sweeps are bit-equal to post-filtering
# ---------------------------------------------------------------------------


def _post_filtered_reference(constraints):
    """Unconstrained materialized sweep, filtered after the fact."""
    rep = Session().sweep(Space.grid(**GRID))
    lists = Session().plan(Space.grid(**GRID)).lists
    n = rep.n_points
    from repro.core.stream import GridEnumerator

    enum = GridEnumerator({k: list(v) for k, v in lists.items()})
    ids = np.arange(n, dtype=np.int64)
    cols = columns_from_lists(lists, enum.codes(ids))
    mask = feasibility_mask(normalize_constraints(constraints), cols)
    return rep, mask


@pytest.mark.parametrize("backend", ["numpy-batch", "scalar", "jax-jit"])
def test_masked_sweep_bit_equal_to_post_filter(backend):
    if backend == "jax-jit":
        pytest.importorskip("jax")
    ref, mask = _post_filtered_reference([ENV])
    sess = Session(backend=backend) if backend != "numpy-batch" else Session()
    got = sess.sweep(Space.grid(**GRID), constraints=[ENV])
    assert got.n_candidates == ref.n_points
    assert got.n_points == int(mask.sum())
    ref_t = np.asarray(ref.estimate.t_exe)[mask]
    np.testing.assert_array_equal(np.asarray(got.estimate.t_exe), ref_t)
    np.testing.assert_array_equal(got.resource, ref.resource[mask])


def test_masked_streaming_matches_materialized_constrained():
    ref, mask = _post_filtered_reference([ENV])
    st = Session().sweep(Space.grid(**GRID), chunk_size=97,
                         constraints=[ENV])
    assert st.stats["n_points"] == int(mask.sum())
    ref_t = np.asarray(ref.estimate.t_exe)[mask]
    assert st.stats["t_exe_min"] == ref_t.min()
    # the exact-sum reducer folds per-chunk partial sums, so the total
    # agrees to float64 round-off (per-point values are bit-equal above)
    assert st.stats["t_exe_sum"] == pytest.approx(ref_t.sum(), rel=1e-12)
    assert st.summary()["n_candidates"] == ref.n_points


def test_masked_sweep_property_random_constraints():
    """Property: any bound constraint masks bit-equal to post-filtering.

    Uses hypothesis when installed; falls back to a seeded sample of the
    same strategy space otherwise.
    """
    lists = Session().plan(Space.grid(**GRID)).lists
    from repro.core.stream import GridEnumerator

    enum = GridEnumerator({k: list(v) for k, v in lists.items()})
    ids = np.arange(enum.n, dtype=np.int64)
    cols = columns_from_lists(lists, enum.codes(ids))
    ref = Session().sweep(Space.grid(**GRID))
    ref_t = np.asarray(ref.estimate.t_exe)

    def check(column, bound, chunk):
        c = BoundConstraint(column, bound)
        mask = feasibility_mask((c,), cols)
        got = Session().sweep(Space.grid(**GRID), chunk_size=chunk,
                              constraints=c)
        assert got.stats["n_points"] == int(mask.sum())
        if mask.any():
            assert got.stats["t_exe_min"] == ref_t[mask].min()

    columns = ("lsu_ports", "interconnect_bytes", "buffer_bytes",
               "n_ga", "simd")
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=20, deadline=None)
        @given(column=st.sampled_from(columns),
               bound=st.floats(0, 5000, allow_nan=False),
               chunk=st.integers(1, 300))
        def prop(column, bound, chunk):
            check(column, bound, chunk)

        prop()
    except ImportError:
        rng = np.random.default_rng(7)
        for _ in range(12):
            check(columns[rng.integers(len(columns))],
                  float(rng.uniform(0, 5000)), int(rng.integers(1, 300)))


def test_lambda_constraint_and_conjunction():
    c = within(ENV) & LambdaConstraint(lambda cols: cols["n_ga"] >= 2)
    got = Session().sweep(Space.grid(**GRID), constraints=c)
    assert got.n_points > 0
    assert np.asarray(got.points["n_ga"], dtype=np.int64).min() >= 2
    # custom callables are explicitly not JSON-serializable
    with pytest.raises(TypeError):
        constraint_to_json(c)


def test_constraint_json_round_trip():
    c = within(ENV) & BoundConstraint("n_ga", 2, op=">=")
    again = constraint_from_json(json.loads(json.dumps(constraint_to_json(c))))
    lists = Session().plan(Space.grid(**GRID)).lists
    from repro.core.stream import GridEnumerator

    enum = GridEnumerator({k: list(v) for k, v in lists.items()})
    ids = np.arange(enum.n, dtype=np.int64)
    cols = columns_from_lists(lists, enum.codes(ids))
    np.testing.assert_array_equal(c.mask(cols), again.mask(cols))


def test_plan_json_round_trip_with_constraints():
    plan = Session().plan(Space.grid(**GRID), chunk_size=128,
                          constraints=[ENV])
    from repro.core.stream import SweepPlan

    again = SweepPlan.from_json(plan.to_json())
    assert again.constraints == plan.constraints
    ids = np.arange(plan.n, dtype=np.int64)
    np.testing.assert_array_equal(again.feasible_mask(ids),
                                  plan.feasible_mask(ids))


# ---------------------------------------------------------------------------
# empty feasible regions fail loudly
# ---------------------------------------------------------------------------

IMPOSSIBLE = ResourceEnvelope(lsu_ports=0)


def test_constrained_sweep_empty_region_errors_on_best():
    got = Session().sweep(Space.grid(**GRID), constraints=[IMPOSSIBLE])
    assert got.n_points == 0
    s = got.summary()
    assert s["n_feasible"] == 0 and s["n_candidates"] == 864
    with pytest.raises(ValueError, match="constraints eliminated every"):
        got.best()


def test_random_space_rejection_sampling():
    sp = Space.random(64, seed=3, **GRID)
    sess = Session()
    rep = sess.sweep(sp, constraints=[ENV])
    assert rep.n_points == 64            # rejection refills to n
    # every drawn point satisfies the envelope
    from repro.core import sweep as _sweep
    from repro.search.constraints import columns_from_parts

    cats = {a: _sweep._factorize(rep.points[a]) for a in _sweep._CATEGORICAL}
    gc = columns_from_parts({a: np.asarray(rep.points[a])
                             for a in _sweep._NUMERIC}, cats, 64)
    assert feasibility_mask(normalize_constraints([ENV]), gc).all()
    # deterministic under the same seed
    rep2 = Session().sweep(Space.random(64, seed=3, **GRID),
                           constraints=[ENV])
    np.testing.assert_array_equal(np.asarray(rep.estimate.t_exe),
                                  np.asarray(rep2.estimate.t_exe))


def test_random_space_empty_region_errors():
    with pytest.raises(ValueError, match="feasible region"):
        Session().sweep(Space.random(16, seed=0, **GRID),
                        constraints=[IMPOSSIBLE])


def test_optimize_empty_region_errors():
    with pytest.raises(ValueError, match="eliminated every|no feasible"):
        Session().optimize(GRID, constraints=[IMPOSSIBLE])


# ---------------------------------------------------------------------------
# the optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.optim.adamw import (OptimizerConfig, adamw_init,
                                   adamw_update)

    cfg = OptimizerConfig(lr=0.2, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=1e6)
    target = jnp.asarray([3.0, -2.0])
    params = {"x": jnp.zeros(2)}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum((p["x"] - target) ** 2)
    vg = jax.value_and_grad(loss)
    for _ in range(200):
        val, g = vg(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_optimize_small_grid_is_exhaustive_and_exact():
    rep = Session().optimize(GRID)
    full = Session().sweep(Space.grid(**GRID))
    assert rep.n_grid_evals == full.n_points
    assert rep.best.t_exe == float(np.asarray(full.estimate.t_exe).min())
    assert rep.trajectory[0]["phase"] == "exhaustive"
    assert rep.summary()["best_id"] == rep.best_id


BIG = dict(
    lsu_type=ALL_TYPES,
    n_ga=[1, 2, 3, 4, 6, 8, 12, 16],
    simd=[1, 2, 4, 8, 16],
    n_elems=[1 << 10, 1 << 12, 1 << 14, 1 << 16],
    delta=[1, 2, 3, 4, 5, 6, 7, 8],
    elem_bytes=[4, 8],
    include_write=[False, True],
    val_constant=[False, True],
)   # 40960 points


def test_optimize_matches_full_grid_under_budget():
    pytest.importorskip("jax")
    sess = Session()
    rep = sess.optimize(BIG, max_evals=2000, seed=0)
    st = sess.sweep(BIG, chunk_size=8192,
                    reducers=(StatsReducer(),))
    assert rep.n_evals <= 2000
    assert rep.n_grid_evals < 0.05 * rep.n_total
    assert rep.best.t_exe == st.stats["t_exe_min"]
    phases = [t["phase"] for t in rep.trajectory]
    assert phases[0] == "screen" and "descend" in phases


def test_optimize_constrained_matches_constrained_grid():
    pytest.importorskip("jax")
    env = ResourceEnvelope(lsu_ports=4, interconnect_bytes=64)
    sess = Session()
    rep = sess.optimize(BIG, constraints=[env], max_evals=2000, seed=1)
    st = sess.sweep(BIG, chunk_size=8192, constraints=[env],
                    reducers=(StatsReducer(),))
    assert rep.best.t_exe == st.stats["t_exe_min"]
    # every point the optimizer ever scored was feasible
    usage = rep.best_config
    assert float(usage["n_ga"]) <= 4


def test_optimize_pareto_front_recall():
    pytest.importorskip("jax")
    sess = Session()
    rep = sess.optimize(BIG, objective=("t_exe", "resource"),
                        max_evals=3000, seed=0)
    full = sess.sweep(BIG, chunk_size=8192)
    fr = full.pareto()
    ref = {(float(np.asarray(full.estimate.t_exe)[i]),
            float(full.resource[i])) for i in fr}
    got = {(float(rep.front["t_exe"][i]), float(rep.front["resource"][i]))
           for i in range(rep.n_front)}
    assert len(ref & got) / len(ref) >= 0.95
    assert rep.evals_fraction < 0.1


def test_optimize_rejects_bad_objective():
    with pytest.raises(ValueError, match="unknown objective"):
        Session().optimize(GRID, objective="latency")
    with pytest.raises(ValueError, match="one column or a pair"):
        Session().optimize(GRID, objective=("t_exe", "resource", "t_ovh"))
