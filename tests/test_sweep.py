"""Sweep-engine tests: batched == scalar element-wise, Pareto invariants,
the >= 20x exploration-scale speedup, and the batched autotune scorer."""
import numpy as np
import pytest

from repro import Session, Space
from repro.core import DDR4_1866, DDR4_2666, Lsu, LsuType, STRATIX10_BSP
from repro.core import model as M
from repro.core import model_batch as MB
from repro.core.apps import microbench
from repro.core.fpga import BspParams
from repro.core.model import _estimate as estimate   # the scalar reference
from repro.core.sweep import _pareto_scan, pareto_front

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]
STRIDE_TYPES = (LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED, LsuType.BC_CACHE)


def scalar_point(P, i):
    """Score design point ``i`` of a SweepResult through the scalar path."""
    t = P["lsu_type"][i]
    lsus = microbench(
        t,
        n_ga=int(P["n_ga"][i]),
        simd=int(P["simd"][i]),
        n_elems=int(P["n_elems"][i]),
        delta=int(P["delta"][i]) if t in STRIDE_TYPES else 1,
        elem_bytes=int(P["elem_bytes"][i]),
        include_write=bool(P["include_write"][i]),
        val_constant=bool(P["val_constant"][i]),
    )
    return estimate(lsus, P["dram"][i], P["bsp"][i], f=int(P["simd"][i]))


class TestBatchedMatchesScalar:
    def test_grid_elementwise(self):
        """Mixed-type grid: t_exe, bound ratio and classification all agree
        with the scalar estimate path at every point."""
        res = Session().sweep(
            lsu_type=ALL_TYPES,
            n_ga=[1, 2, 4],
            simd=[1, 4, 16],
            n_elems=[1 << 14, 1 << 16],
            delta=[1, 2, 6, 7],            # both sides of the Eq. 8 knee
            include_write=[False, True],
            val_constant=[False, True],
            dram=[DDR4_1866, DDR4_2666],
            bsp=[STRATIX10_BSP, BspParams(burst_cnt=5, max_th=64)],
        )
        est = res.estimate
        for i in range(res.n_points):
            e = scalar_point(res.points, i)
            assert res.t_exe[i] == pytest.approx(e.t_exe, rel=1e-6), i
            assert float(est.bound_ratio[i]) == pytest.approx(
                e.bound_ratio, rel=1e-9), i
            assert bool(est.memory_bound[i]) == e.memory_bound, i
            assert float(est.total_bytes[i]) == e.total_bytes, i

    def test_random_sweep_property(self):
        """Randomized design points (the property test): batched == scalar."""
        res = Session().sweep(Space.random(
            512, seed=1234,
            lsu_type=ALL_TYPES,
            n_ga=(1, 8),
            simd=[1, 2, 4, 8, 16],
            n_elems=(1 << 12, 1 << 20),
            delta=(1, 9),
            include_write=[False, True],
            val_constant=[False, True],
            dram=[DDR4_1866, DDR4_2666],
        ))
        scalar = np.array([scalar_point(res.points, i).t_exe
                           for i in range(res.n_points)])
        np.testing.assert_allclose(res.t_exe, scalar, rtol=1e-6)

    def test_group_counts_match_expanded_lsus(self):
        """A group of `count` identical LSUs == the same LSUs listed out."""
        lsus = microbench(LsuType.BC_ALIGNED, n_ga=4, simd=8, n_elems=1 << 16)
        batch = MB.GroupBatch.from_kernels([lsus], DDR4_1866, STRATIX10_BSP)
        grouped = MB.GroupBatch(
            kernel=np.array([0]), n_kernels=1,
            count=np.array([len(lsus)]),
            lsu_type=batch.lsu_type[:1], ls_width=batch.ls_width[:1],
            ls_acc=batch.ls_acc[:1], ls_bytes=batch.ls_bytes[:1],
            delta=batch.delta[:1], val_constant=batch.val_constant[:1],
            f=batch.f[:1], dq=batch.dq[:1], bl=batch.bl[:1],
            f_mem=batch.f_mem[:1], t_rcd=batch.t_rcd[:1],
            t_rp=batch.t_rp[:1], t_wr=batch.t_wr[:1],
            burst_cnt=batch.burst_cnt[:1], max_th=batch.max_th[:1])
        a = MB.estimate_batch(batch)
        b = MB.estimate_batch(grouped)
        assert float(a.t_exe[0]) == pytest.approx(float(b.t_exe[0]), rel=1e-12)
        assert int(a.n_lsu[0]) == int(b.n_lsu[0]) == len(lsus)

    def test_scalar_reference_lsu_timing_matches_array_core(self):
        """model.lsu_timing (readable scalar reference) == model_batch."""
        cases = [
            Lsu(LsuType.BC_ALIGNED, ls_width=64, ls_acc=4096, ls_bytes=64),
            Lsu(LsuType.BC_NON_ALIGNED, ls_width=64, ls_acc=4096,
                ls_bytes=64, delta=7),
            Lsu(LsuType.BC_WRITE_ACK, ls_width=4, ls_acc=4096, ls_bytes=4,
                is_write=True),
            Lsu(LsuType.ATOMIC_PIPELINED, ls_width=4, ls_acc=4096,
                ls_bytes=4, is_write=True, val_constant=True),
        ]
        for n_lsu in (1, 3):
            for lsu in cases:
                ref = M.lsu_timing(lsu, DDR4_1866, STRATIX10_BSP,
                                   n_lsu=n_lsu, f=8)
                batch = MB.GroupBatch.from_kernels(
                    [[lsu] * n_lsu], DDR4_1866, STRATIX10_BSP, f=8)
                got = MB.estimate_batch(batch).groups
                assert float(got["t_ideal"][0]) == pytest.approx(
                    ref.t_ideal, rel=1e-12)
                assert float(got["t_ovh"][0]) == pytest.approx(
                    ref.t_ovh, rel=1e-12, abs=1e-18)
                assert float(got["burst_size"][0]) == pytest.approx(
                    ref.burst_size, rel=1e-12)

    def test_jax_jit_path(self):
        """The array core is a pytree and runs under jax.jit unchanged."""
        jax = pytest.importorskip("jax")
        import dataclasses

        import jax.numpy as jnp

        batch = MB.GroupBatch.from_kernels(
            [microbench(LsuType.BC_ALIGNED, n_ga=2),
             microbench(LsuType.ATOMIC_PIPELINED, n_ga=2, n_elems=1 << 12)],
            DDR4_1866, STRATIX10_BSP)
        ref = MB.estimate_batch(batch)
        jbatch = MB.GroupBatch(**{
            f.name: (jnp.asarray(getattr(batch, f.name))
                     if f.name != "n_kernels" else batch.n_kernels)
            for f in dataclasses.fields(MB.GroupBatch)})
        assert MB.enable_jax()      # pytree registration is lazy, not at import
        fn = jax.jit(lambda b: MB.estimate_batch(b, xp=jnp).t_exe)
        np.testing.assert_allclose(np.asarray(fn(jbatch)), ref.t_exe,
                                   rtol=1e-6)

    def test_empty_and_onchip_kernels(self):
        """Kernels with no global LSUs estimate to zero, like the scalar path."""
        onchip = Lsu(LsuType.PIPELINED, ls_width=4, ls_acc=16, ls_bytes=4)
        batch = MB.GroupBatch.from_kernels(
            [[], [onchip], microbench(LsuType.BC_ALIGNED, n_ga=1)],
            DDR4_1866, STRATIX10_BSP)
        est = MB.estimate_batch(batch)
        assert est.t_exe[0] == 0.0 and est.t_exe[1] == 0.0
        assert est.t_exe[2] > 0.0
        assert not bool(est.memory_bound[0])


class TestPareto:
    def test_order_invariant(self):
        rng = np.random.default_rng(7)
        vals = rng.random((400, 2))
        vals[rng.integers(0, 400, 40)] = vals[rng.integers(0, 400, 40)]  # dups
        base = pareto_front(vals)
        base_set = {tuple(vals[i]) for i in base}
        for seed in range(5):
            perm = np.random.default_rng(seed).permutation(len(vals))
            idx = pareto_front(vals[perm])
            assert {tuple(vals[perm][i]) for i in idx} == base_set

    def test_front_is_nondominated_and_complete(self):
        rng = np.random.default_rng(3)
        vals = rng.random((200, 3))
        front = set(pareto_front(vals).tolist())
        dominated = {
            j
            for j in range(len(vals))
            for i in range(len(vals))
            if i != j and np.all(vals[i] <= vals[j]) and np.any(vals[i] < vals[j])
        }
        assert front == set(range(len(vals))) - dominated

    def test_2d_fast_path_matches_scan(self):
        """The vectorized 2-objective front == the lexsort+scan reference,
        including duplicated rows and heavy first-objective ties."""
        rng = np.random.default_rng(11)
        vals = rng.random((2000, 2))
        vals[rng.integers(0, 2000, 200)] = vals[rng.integers(0, 2000, 200)]
        vals[:500, 0] = np.round(vals[:500, 0], 1)     # big v0 tie groups
        np.testing.assert_array_equal(pareto_front(vals), _pareto_scan(vals))
        # degenerate shapes
        one = np.array([[0.5, 0.5]])
        np.testing.assert_array_equal(pareto_front(one), [0])
        same = np.ones((7, 2))
        np.testing.assert_array_equal(pareto_front(same), np.arange(7))

    def test_sweep_pareto_objectives(self):
        res = Session().sweep(lsu_type=ALL_TYPES, n_ga=[1, 2, 4],
                              simd=[1, 4, 16])
        front = res.pareto()
        assert len(front) >= 1
        # every front point must be non-dominated in (t_exe, resource)
        vals = np.stack([res.t_exe, res.resource], axis=1)
        for i in front:
            dom = np.all(vals <= vals[i], axis=1) & np.any(vals < vals[i], axis=1)
            assert not dom.any()


class TestExplorationScale:
    def test_10k_points_20x_faster_than_scalar(self):
        """Acceptance: >= 10k designs, >= 20x over the scalar loop, rtol 1e-6."""
        from benchmarks.sweep_bench import FULL_AXES, scalar_loop
        import time

        t_batch = float("inf")      # min-of-3 damps scheduler noise
        for _ in range(3):
            t0 = time.perf_counter()
            res = Session().sweep(**FULL_AXES)
            t_batch = min(t_batch, time.perf_counter() - t0)
        assert res.n_points >= 10_000

        t0 = time.perf_counter()
        scalar = scalar_loop(res)
        t_scalar = time.perf_counter() - t0

        np.testing.assert_allclose(res.t_exe, scalar, rtol=1e-6)
        assert t_scalar / t_batch >= 20.0, (t_scalar, t_batch)


class TestBatchedAutotuneScorer:
    def test_rank_records_matches_scalar_predictor(self):
        """The batched ranker reproduces the step predictor's roofline terms."""
        from repro.core import autotune as AT
        from repro.core import hbm as _hbm
        from repro import TPU_V5E
        from repro.core.hbm import AccessClass, Traffic
        from repro.core import predictor as _pred

        rng = np.random.default_rng(5)
        records = []
        for _ in range(32):
            records.append({
                "flops": float(rng.uniform(1e9, 1e15)),
                "bytes_by_class": {
                    "stream": float(rng.uniform(0, 1e12)),
                    "strided": float(rng.uniform(0, 1e10)),
                    "gather": float(rng.uniform(0, 1e9)),
                    "serialized": float(rng.choice([0.0, 1e6])),
                },
                "collective_wire_bytes": float(rng.uniform(0, 1e10)),
                "collective_operand_bytes": 0.0,
                "collective_by_kind": {},
                "n_collectives": float(rng.integers(0, 64)),
            })
        scores = AT.rank_records(records, TPU_V5E)
        for i, rec in enumerate(records):
            comps = [Traffic(_pred._CLASS_BY_NAME[k], v,
                             row_bytes=512.0, name=k)
                     for k, v in sorted(rec["bytes_by_class"].items())]
            t_mem = _hbm.memory_time(comps, TPU_V5E)
            assert scores["t_memory"][i] == pytest.approx(t_mem, rel=1e-9)
            assert scores["t_compute"][i] == pytest.approx(
                rec["flops"] / TPU_V5E.peak_flops, rel=1e-12)
        order = scores["order"]
        assert (np.diff(scores["t_step"][order]) >= 0).all()

    def test_cache_roundtrip(self, tmp_path):
        from repro.core.cache import HloAnalysisCache, config_hash

        cache = HloAnalysisCache(tmp_path)
        key = config_hash({"cfg": {"d_model": 512}, "mesh": (2, 2)})
        assert cache.get(key) is None
        rec = {"flops": 1.5e12, "bytes_by_class": {"stream": 3.0}}
        cache.put(key, rec)
        assert cache.get(key) == rec
        assert key in cache and len(cache) == 1
        # same content -> same key; different content -> different key
        assert key == config_hash({"cfg": {"d_model": 512}, "mesh": (2, 2)})
        assert key != config_hash({"cfg": {"d_model": 513}, "mesh": (2, 2)})
        assert cache.clear() == 1 and len(cache) == 0
