"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import gqa_decode
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.membench import ops as MB
from repro.kernels.membench import ref as MBR
from repro.kernels.rglru.ops import scan as rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,D,causal,window,softcap",
    [
        (2, 64, 64, 4, 2, 32, True, None, 0.0),
        (1, 128, 128, 8, 8, 64, True, None, 0.0),
        (2, 96, 96, 4, 1, 32, True, 32, 0.0),      # MQA + sliding window
        (2, 48, 48, 4, 4, 32, False, None, 0.0),   # encoder
        (1, 64, 64, 2, 2, 32, True, None, 20.0),   # grok softcap
        (1, 100, 100, 6, 2, 16, True, None, 0.0),  # non-multiple seq
    ],
)
def test_flash_attention(B, Sq, Skv, Hq, Hkv, D, causal, window, softcap,
                         dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = mha(q, k, v, causal=causal, window=window, softcap=softcap,
              block_q=32, block_kv=16)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,kv_len", [
    (2, 96, 8, 2, 32, 96),
    (2, 96, 8, 2, 32, 17),
    (1, 64, 4, 4, 64, 1),
    (3, 80, 16, 2, 16, 40),
])
def test_decode_attention(B, S, Hq, Hkv, D, kv_len, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = gqa_decode(q, kc, vc, jnp.asarray(kv_len), block_s=32)
    ref = decode_attention_ref(
        q[:, 0].reshape(B, Hkv, Hq // Hkv, D), kc, vc, kv_len
    ).reshape(B, 1, Hq, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 64, 96, 16, 32),
    (1, 128, 64, 64, 64),
    (3, 96, 128, 32, 128),
])
def test_rglru_scan(B, S, W, bs, bw, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.6, 0.999).astype(dtype)
    b = jax.random.normal(ks[1], (B, S, W), dtype)
    out = rglru_scan(a, b, block_s=bs, block_w=bw)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


class TestMembench:
    @pytest.mark.parametrize("n_ga", [1, 2, 4])
    @pytest.mark.parametrize("block", [512, 2048])
    def test_aligned(self, n_ga, block):
        n = 1 << 14
        xs = [jax.random.normal(jax.random.PRNGKey(i), (n,), jnp.float32)
              for i in range(n_ga)]
        out = MB.aligned_sum(tuple(xs), block=block)
        np.testing.assert_allclose(out, MBR.aligned_sum_ref(xs), rtol=1e-6)

    @pytest.mark.parametrize("delta", [1, 2, 4])
    def test_strided(self, delta):
        n, block = 1 << 14, 512
        xs = [jax.random.normal(jax.random.PRNGKey(i), (n,), jnp.float32)
              for i in range(2)]
        out = MB.strided_sum(tuple(xs), delta=delta, block=block)
        ref = MBR.strided_sum_ref(xs, delta=delta, block=block)
        np.testing.assert_allclose(out, ref, rtol=1e-6)

    def test_gather(self):
        n, block = 1 << 14, 512
        xs = [jax.random.normal(jax.random.PRNGKey(i), (n,), jnp.float32)
              for i in range(3)]
        idx = jax.random.randint(jax.random.PRNGKey(9), (16,), 0, n // block)
        out = MB.gather_sum(tuple(xs), idx, block=block)
        ref = MBR.gather_sum_ref(xs, idx, block=block)
        np.testing.assert_allclose(out, ref, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,dh,chunk", [
    (2, 64, 3, 16, 16),
    (1, 96, 2, 32, 32),
    (2, 32, 4, 8, 32),     # chunk > S -> single chunk
])
def test_mlstm_chunk_kernel(B, S, H, dh, chunk, dtype):
    from repro.kernels.mlstm_chunk.ops import chunked_mlstm
    from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = (jax.random.normal(ks[1], (B, S, H, dh)) / dh ** 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, dh), dtype)
    li = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    out = chunked_mlstm(q, k, v, li, lf, chunk=chunk)
    ref = mlstm_chunk_ref(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        li.transpose(0, 2, 1), lf.transpose(0, 2, 1)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **_tol(dtype))


def test_mlstm_model_pallas_path_matches_xla():
    """cfg.use_pallas routes mLSTM through the chunk kernel; outputs match
    the XLA chunked implementation."""
    import dataclasses
    from repro.configs import ARCHS, reduced_config
    from repro.models import xlstm as XL
    cfg = dataclasses.replace(reduced_config(ARCHS["xlstm-1.3b"]),
                              dtype="float32")
    p = XL.mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model),
                          jnp.float32)
    a = XL.mlstm_forward(p, cfg, x)
    b = XL.mlstm_forward(p, dataclasses.replace(cfg, use_pallas=True), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
