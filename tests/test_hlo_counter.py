"""Trip-count-aware HLO analyzer: validated against XLA's cost_analysis on
scan-free modules and against unrolled ground truth on scan modules."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo as HLO
from repro.core import hlo_counter as HC


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestAgainstXla:
    def test_scan_free_flops_and_bytes(self):
        def f(x, w1, w2):
            return jnp.tanh(x @ w1) @ w2

        specs = [jax.ShapeDtypeStruct(s, jnp.float32)
                 for s in [(64, 256), (256, 512), (512, 128)]]
        c = _compile(f, *specs)
        xla = HLO.cost_analysis_stats(c)
        mine = HC.analyze(c.as_text(), fused=False)
        assert mine.flops == pytest.approx(xla["flops"], rel=0.05)
        assert mine.total_bytes == pytest.approx(xla["bytes_accessed"], rel=0.1)
        # fused (TPU) traffic model must be <= the unfused count and still
        # include the dot operands
        fm = HC.analyze(c.as_text())
        assert 0 < fm.total_bytes <= mine.total_bytes

    def test_scan_multiplies_by_trip_count(self):
        def body(x, w):
            return jnp.tanh(x @ w), None

        def scan(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        def unrolled(x, ws):
            for i in range(12):
                x, _ = body(x, ws[i])
            return x

        x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
        truth = HLO.cost_analysis_stats(_compile(unrolled, x, ws))
        mine = HC.analyze(_compile(scan, x, ws).as_text(), fused=False)
        assert mine.flops == pytest.approx(truth["flops"], rel=0.05)
        assert mine.total_bytes == pytest.approx(truth["bytes_accessed"],
                                                 rel=0.15)

    def test_nested_scan(self):
        def inner(c, x):
            return c * x, None

        def outer(c, xs):
            def step(c, x):
                c2, _ = jax.lax.scan(inner, c, x)
                return c2, None
            return jax.lax.scan(step, c, xs)[0]

        c0 = jax.ShapeDtypeStruct((64,), jnp.float32)
        xs = jax.ShapeDtypeStruct((5, 7, 64), jnp.float32)
        mine = HC.analyze(_compile(outer, c0, xs).as_text())
        # 5*7 = 35 multiplies of 64 elements
        assert mine.flops == pytest.approx(35 * 64, rel=0.3)


class TestClassification:
    def test_gather_classified(self):
        def f(emb, idx):
            return emb[idx].sum()

        emb = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
        idx = jax.ShapeDtypeStruct((128,), jnp.int32)
        mine = HC.analyze(_compile(f, emb, idx).as_text())
        assert mine.bytes_by_class.get("gather", 0) > 0

    def test_sort_classified_strided(self):
        def f(x):
            return jnp.sort(x)

        x = jax.ShapeDtypeStruct((4096,), jnp.float32)
        mine = HC.analyze(_compile(f, x).as_text())
        assert mine.bytes_by_class.get("strided", 0) > 0


class TestCollectives:
    def _mesh(self):
        from repro.compat import make_mesh
        return make_mesh((len(jax.devices()),), ("d",))

    def test_psum_collective_counted(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = self._mesh()
        n = len(jax.devices())
        if n < 2:
            pytest.skip("needs >1 device")

    def test_group_size_parsing(self):
        line = ("%ar = f32[256]{0} all-reduce(%x), channel_id=1, "
                "replica_groups=[2,4]<=[8], to_apply=%sum")
        ops = HLO.parse_collectives(line)
        assert len(ops) == 1 and ops[0].group_size == 4
        line2 = ("%ag = f32[256]{0} all-gather(%x), channel_id=1, "
                 "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}")
        ops2 = HLO.parse_collectives(line2)
        assert ops2[0].group_size == 8
        assert ops2[0].operand_bytes == pytest.approx(1024 / 8)
        assert ops2[0].wire_bytes == pytest.approx(1024 * 7 / 8)

    def test_shape_bytes(self):
        assert HLO.shape_bytes("bf16[2,16,4096]{2,1,0}") == 2 * 16 * 4096 * 2
        assert HLO.shape_bytes("(f32[8]{0}, s32[4]{0})") == 32 + 16
        assert HLO.shape_bytes("pred[]") == 1


class TestDegenerateModules:
    """Satellite hardening: constant-folded / empty modules must analyze to
    an empty cost, never raise."""

    def test_no_entry_returns_empty_cost(self):
        cost = HC.analyze("not hlo at all")
        assert cost.total_bytes == 0 and cost.flops == 0
        assert any("no ENTRY" in w for w in cost.warnings)

    def test_entry_with_zero_materialized_instructions(self):
        # A fully constant-folded step: the entry body holds only a
        # constant and its ROOT tuple — no materialized traffic.
        text = "\n".join([
            "HloModule folded",
            "",
            "ENTRY %main () -> (f32[]) {",
            "  %c = f32[] constant(42)",
            "  ROOT %t = (f32[]) tuple(%c)",
            "}",
        ])
        cost = HC.analyze(text)
        assert cost.total_bytes == 0
        assert dict(cost.bytes_by_class) == {}

    def test_walker_empty_on_degenerate_module(self):
        from repro.workload import walk_module
        assert walk_module("not hlo at all") == []


class TestScaled:
    """Satellite fix: scaled(0.0) must drop class keys, not keep stale
    zero-valued entries (LSU groups are keyed off class *names*)."""

    def test_scaled_zero_drops_classes(self):
        c = HC.HloCost()
        c.bytes_by_class["gather"] = 512.0
        c.collective_by_kind["all-reduce"] = 64.0
        c.flops = 100.0
        z = c.scaled(0.0)
        assert dict(z.bytes_by_class) == {}
        assert dict(z.collective_by_kind) == {}
        assert z.flops == 0.0 and z.total_bytes == 0.0

    def test_add_after_zero_scaling(self):
        a = HC.HloCost()
        a.bytes_by_class["stream"] = 100.0
        b = a.scaled(0.0)
        b.add(a.scaled(2.0))
        assert dict(b.bytes_by_class) == {"stream": 200.0}
        # defaultdict behavior intact after the scaled(0) path
        assert b.bytes_by_class["gather"] == 0.0

    def test_scaled_nonzero_unchanged(self):
        a = HC.HloCost()
        a.bytes_by_class["strided"] = 10.0
        s = a.scaled(3.0)
        assert dict(s.bytes_by_class) == {"strided": 30.0}
