"""Predictor + roofline unit tests (TPU adaptation layer)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo as HLO
from repro import TPU_V5E
from repro.core.hbm import AccessClass, Traffic, memory_time, traffic_time
from repro.core.predictor import predict_step as predict
from repro.core.roofline import RooflineCell, build_cell


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestHbmModel:
    def test_stream_near_peak(self):
        t = Traffic(AccessClass.STREAM, 819e9)  # 1 second of peak traffic
        ideal, ovh = traffic_time(t, TPU_V5E)
        assert ideal == pytest.approx(1.0)
        assert ovh / ideal < 0.1                 # K_stream ~ 0.92

    def test_gather_small_rows_much_slower(self):
        nbytes = 1e9
        stream = sum(traffic_time(Traffic(AccessClass.STREAM, nbytes)))
        gather64 = sum(traffic_time(Traffic(AccessClass.GATHER, nbytes,
                                            row_bytes=64)))
        gather4k = sum(traffic_time(Traffic(AccessClass.GATHER, nbytes,
                                            row_bytes=4096)))
        assert gather64 > 4 * stream             # 64B rows waste 7/8 of each txn
        assert gather4k < 2 * stream             # big rows ~ streaming
        assert gather64 > gather4k

    def test_eq1_additivity(self):
        comps = [Traffic(AccessClass.STREAM, 1e9),
                 Traffic(AccessClass.GATHER, 1e8, row_bytes=128)]
        total = memory_time(comps)
        assert total == pytest.approx(
            sum(sum(traffic_time(c)) for c in comps))


class TestPredictor:
    def test_matmul_is_compute_bound(self):
        # 4096^3: AI ~ 680 FLOP/B, well above the v5e ridge (~241) even with
        # the CPU module's bf16->f32 legalization doubling the traffic.
        m = jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16)
        c = _compiled(lambda a: a @ a, m)
        pred = predict(c.as_text(), HLO.cost_analysis_stats(c))
        assert pred.bottleneck == "compute"
        assert pred.flops == pytest.approx(2 * 4096 ** 3, rel=0.05)

    def test_elementwise_is_memory_bound(self):
        x = jax.ShapeDtypeStruct((1 << 22,), jnp.float32)
        c = _compiled(lambda a, b: a + b, x, x)
        pred = predict(c.as_text(), HLO.cost_analysis_stats(c))
        assert pred.bottleneck == "memory"
        assert pred.arithmetic_intensity < 1.0

    def test_gather_classified(self):
        emb = jax.ShapeDtypeStruct((1 << 16, 256), jnp.float32)
        idx = jax.ShapeDtypeStruct((1 << 14,), jnp.int32)
        c = _compiled(lambda e, i: e[i].sum(), emb, idx)
        pred = predict(c.as_text(), HLO.cost_analysis_stats(c))
        names = {t.name for t in pred.memory_components}
        assert "gather" in names


class TestRooflineCell:
    def _cell(self, **kw):
        base = dict(arch="a", shape="s", mesh="m", chips=256,
                    flops_per_chip=1e12, bytes_per_chip=1e9,
                    collective_operand_bytes=1e8, collective_wire_bytes=1e8,
                    n_collectives=4, model_flops_global=2e14,
                    t_compute=1e12 / 197e12, t_memory_naive=1e9 / 819e9,
                    t_memory_refined=1.5e9 / 819e9,
                    t_collective=1e8 / 200e9)
        base.update(kw)
        return RooflineCell(**base)

    def test_dominant_and_fraction(self):
        c = self._cell()
        assert c.dominant == "compute"
        assert 0 < c.roofline_fraction <= 1.0
        # useful ratio: 2e14 / (1e12*256) = 0.78
        assert c.useful_flops_ratio == pytest.approx(0.78, abs=0.01)

    def test_memory_dominant(self):
        c = self._cell(t_compute=1e-6)
        assert c.dominant == "memory"

    def test_build_cell_from_text(self):
        m = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        compiled = _compiled(lambda a: jnp.tanh(a @ a), m)
        cell = build_cell(arch="t", shape="s", mesh="1x1", chips=1,
                          hlo_text=compiled.as_text(),
                          cost=HLO.cost_analysis_stats(compiled),
                          model_flops_global=2 * 512 ** 3)
        assert cell.flops_per_chip == pytest.approx(2 * 512 ** 3, rel=0.05)
        assert cell.useful_flops_ratio == pytest.approx(1.0, rel=0.05)
        assert cell.t_step > 0
