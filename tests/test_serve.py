"""The micro-batched serving layer (Session.serve / Server).

Acceptance hammer: N threads of concurrent estimates are **bit-equal** to
serial ``Session.estimate`` whatever batch each request lands in, on both
array backends.  Plus: fixed-shape padding equality, cache-hit semantics,
in-flight coalescing, timeout/overload/drain/close lifecycle, and the
seeded batch-composition-independence determinism sweep.
"""
import importlib.util
import itertools
import threading
import time

import numpy as np
import pytest

import repro
from repro import Design, Session
from repro.core import model_batch as mb
from repro.core.cache import LruCache
from repro.core.lsu import LsuType
from repro.core.serving import (
    RequestTimeout,
    Server,
    ServerClosed,
    ServerOverloaded,
    _next_pow2,
    pad_group_batch,
)

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]


def _pool(n: int) -> list[Design]:
    """``n`` distinct designs spanning every LSU type and stride."""
    combos = itertools.cycle(
        (t, g, s, d) for t in ALL_TYPES for g in (1, 2, 3, 4)
        for s in (1, 4, 16) for d in (1, 3, 7))
    return [Design.microbench(t, n_ga=g, simd=s, delta=d,
                              n_elems=1 << (12 + i % 4),
                              name=f"pool-{i}")
            for i, (t, g, s, d) in zip(range(n), combos)]


def _eq(a: repro.Estimate, b: repro.Estimate) -> None:
    """Bit-equality of the numeric surface (not `design`/`cached` metadata)."""
    assert a.t_exe == b.t_exe
    assert a.t_ideal == b.t_ideal
    assert a.t_ovh == b.t_ovh
    assert a.bound_ratio == b.bound_ratio
    assert a.memory_bound == b.memory_bound
    assert a.total_bytes == b.total_bytes
    assert a.n_lsu == b.n_lsu


BACKENDS = ["numpy-batch",
            pytest.param("jax-jit", marks=pytest.mark.skipif(
                importlib.util.find_spec("jax") is None,
                reason="jax not installed"))]


class TestHammer:
    """The acceptance criterion: concurrent == serial, bit for bit."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_concurrent_bit_equal_to_serial(self, backend):
        sess = Session(backend=backend)
        designs = _pool(48)
        serial = {d.name: sess.estimate(d) for d in designs}
        results: dict[int, list] = {}
        errors: list[BaseException] = []

        def client(tid: int) -> None:
            rng = np.random.default_rng(tid)
            order = rng.permutation(len(designs))
            out = []
            try:
                for i in order:
                    out.append(srv.estimate(designs[i]))
            except BaseException as exc:  # noqa: BLE001 — surface in main thread
                errors.append(exc)
            results[tid] = out

        # cache off: every request must go through the batcher (coalescing
        # still allowed — a coalesced future is a batcher-scored row too)
        with sess.serve(max_batch=16, max_wait_ms=0.5, cache_size=0) as srv:
            threads = [threading.Thread(target=client, args=(t,))
                       for t in range(8)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            stats = srv.stats()
        assert not errors
        n_results = 0
        for out in results.values():
            for est in out:
                _eq(est, serial[est.design.name])
                n_results += 1
        assert n_results == 8 * len(designs)
        assert stats["batches"] >= 1 and stats["error_rate"] == 0.0

    def test_result_carries_callers_design(self):
        """Coalesced or cached, `est.design` is the submitted object's name."""
        sess = Session()
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, name="mine")
        with sess.serve() as srv:
            assert srv.estimate(d).design.name == "mine"
            assert srv.estimate(d).design.name == "mine"   # cached path


class TestDeterminism:
    """Seeded sweep: per-design results are independent of which batch the
    design lands in, what its neighbours are, and where in the batch it
    sits — scored directly through `_score` for exact control of batch
    composition."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_batch_composition_independence(self, backend, seed):
        sess = Session(backend=backend)
        designs = _pool(24)
        serial = {d.name: sess.estimate(d) for d in designs}
        # max_batch bounds the padding target; direct _score chunks below
        # can be as large as the whole pool
        srv = sess.serve(max_batch=len(designs))
        try:
            rng = np.random.default_rng(seed)
            order = rng.permutation(len(designs))
            cuts = np.sort(rng.choice(
                np.arange(1, len(designs)), size=5, replace=False))
            for chunk in np.split(order, cuts):
                if not len(chunk):
                    continue
                batch = [designs[i] for i in chunk]
                for d, est in zip(batch, srv._score(batch)):
                    _eq(est, serial[d.name])
        finally:
            srv.close()


class TestPadding:
    """pad_group_batch: fixed shapes for jit, bit-equal real rows."""

    def _batch(self, designs):
        sess = Session()
        hw = [sess._hw_for(d) for d in designs]
        return mb.GroupBatch.from_kernels(
            [list(d.lsus) for d in designs],
            [h[0] for h in hw], [h[1] for h in hw],
            f=[d.f for d in designs])

    def test_padded_rows_bit_equal(self):
        designs = _pool(5)
        batch = self._batch(designs)
        m = len(np.asarray(batch.kernel))
        padded = pad_group_batch(batch, batch.n_kernels + 3, _next_pow2(m) * 2)
        ref = mb.estimate_batch(batch)
        got = mb.estimate_batch(padded)
        for fld in ("t_exe", "t_ideal", "t_ovh", "total_bytes"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, fld))[:batch.n_kernels],
                np.asarray(getattr(ref, fld)))

    def test_exact_shape_is_identity(self):
        batch = self._batch(_pool(3))
        m = len(np.asarray(batch.kernel))
        assert pad_group_batch(batch, batch.n_kernels, m) is batch

    def test_oversized_batch_rejected(self):
        batch = self._batch(_pool(4))
        with pytest.raises(ValueError, match="exceeds"):
            pad_group_batch(batch, batch.n_kernels - 1, 1 << 10)

    def test_next_pow2(self):
        assert [_next_pow2(n) for n in (1, 2, 3, 64, 65)] == \
            [1, 2, 4, 64, 128]


class TestCache:
    def test_hit_is_equal_and_marked(self):
        sess = Session()
        d = _pool(1)[0]
        with sess.serve() as srv:
            first = srv.estimate(d)
            second = srv.estimate(d)
            stats = srv.stats()
        assert first.cached is False
        assert second.cached is True
        _eq(second, first)
        _eq(first, sess.estimate(d))
        assert stats["cache"]["hits"] >= 1
        assert 0.0 < stats["cache_hit_rate"] <= 1.0

    def test_distinct_sessions_never_share_numbers(self):
        """The session salt keys hardware/calibration into the cache."""
        d = _pool(1)[0]
        a = Session().serve()
        b = Session().with_hardware(repro.hw.get("stratix10_ddr4_2666")).serve()
        try:
            ea, eb = a.estimate(d), b.estimate(d)
            assert ea.t_exe != eb.t_exe
            assert not eb.cached
        finally:
            a.close()
            b.close()

    def test_lru_evicts_in_insertion_order(self):
        c = LruCache(2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # refresh a
        c.put("c", 3)                   # evicts b
        assert c.get("b") is None
        assert c.get("a") == 1 and c.get("c") == 3
        s = c.stats()
        assert s["size"] == 2 and s["hits"] == 3 and s["misses"] == 1

    def test_zero_capacity_disables_caching(self):
        c = LruCache(0)
        c.put("a", 1)
        assert c.get("a") is None and c.stats()["size"] == 0

    def test_predict_memoizes(self):
        sess = Session()
        calls = []
        real = sess.predict

        def counting_predict(*a, **kw):
            calls.append(1)
            return real(*a, **kw)

        object.__setattr__(sess, "predict", counting_predict)  # frozen dc
        hlo = ("HloModule m\n\n"
               "ENTRY e (p.0: f32[1024,1024]) -> f32[1024,1024] {\n"
               "  %p.0 = f32[1024,1024]{1,0} parameter(0)\n"
               "  ROOT %n = f32[1024,1024]{1,0} negate(%p.0)\n"
               "}\n")
        with sess.serve() as srv:
            a = srv.predict(hlo)
            b = srv.predict(hlo)
        assert a is b                   # literally the cached object
        assert len(calls) == 1          # heavy parse ran once


class TestCoalescing:
    def test_identical_inflight_designs_share_one_future(self):
        sess = Session()
        d = _pool(1)[0]
        # long linger so all submits land while the first is still queued
        with sess.serve(max_batch=64, max_wait_ms=100.0, cache_size=0) as srv:
            futs = [srv.submit(d) for _ in range(16)]
            ests = [f.result(timeout=5) for f in futs]
            stats = srv.stats()
        assert len({id(f) for f in futs}) < 16
        assert stats["coalesced"] >= 1
        ref = sess.estimate(d)
        for est in ests:
            _eq(est, ref)


class TestTimeoutOverloadDrain:
    def test_blocking_estimate_times_out(self):
        sess = Session()
        # batcher lingers 500 ms on the first request -> 20 ms budget expires
        with sess.serve(max_batch=8, max_wait_ms=500.0, cache_size=0) as srv:
            with pytest.raises(RequestTimeout):
                srv.estimate(_pool(1)[0], timeout_ms=20)

    def test_expired_request_fails_before_scoring(self):
        sess = Session()
        designs = _pool(2)
        with sess.serve(max_batch=8, max_wait_ms=300.0, cache_size=0) as srv:
            ok = srv.submit(designs[0])                   # no deadline
            doomed = srv.submit(designs[1], timeout_ms=1)  # expires in queue
            assert ok.result(timeout=5).design.name == designs[0].name
            with pytest.raises(RequestTimeout):
                doomed.result(timeout=5)
            assert srv.stats()["expired"] == 1

    def test_overload_fast_fails(self):
        sess = Session()
        designs = _pool(4)
        srv = sess.serve(max_batch=1, max_wait_ms=0.0, cache_size=0,
                         max_queue=1)
        release = threading.Event()
        real_score = srv._score

        def slow_score(batch):
            release.wait(timeout=10)
            return real_score(batch)

        srv._score = slow_score
        try:
            busy = srv.submit(designs[0])
            for _ in range(1000):                   # batcher picked [0] up
                if srv._queue.empty():
                    break
                time.sleep(1e-3)
            queued = srv.submit(designs[1])         # fills the 1-slot queue
            with pytest.raises(ServerOverloaded):
                srv.submit(designs[2])
            assert srv.stats()["rejected_overload"] == 1
            release.set()
            busy.result(timeout=5)
            queued.result(timeout=5)
            # the rejected key was cleaned up: a retry succeeds
            assert srv.estimate(designs[2]).design.name == designs[2].name
        finally:
            release.set()
            srv.close()

    def test_drain_completes_everything(self):
        sess = Session()
        designs = _pool(20)
        srv = sess.serve(max_batch=4, max_wait_ms=5.0, cache_size=0)
        futs = [srv.submit(d) for d in designs]
        srv.drain(timeout_s=10)
        assert all(f.done() for f in futs)
        srv.close()
        assert srv.stats()["served"] == len(designs)


class TestLifecycle:
    def test_submit_after_close_raises(self):
        srv = Session().serve()
        srv.close()
        assert srv.closed
        with pytest.raises(ServerClosed):
            srv.submit(_pool(1)[0])
        srv.close()                     # idempotent

    def test_graceful_close_scores_queued_work(self):
        sess = Session()
        designs = _pool(10)
        srv = sess.serve(max_batch=4, max_wait_ms=50.0, cache_size=0)
        futs = [srv.submit(d) for d in designs]
        srv.close(drain=True)
        for d, f in zip(designs, futs):
            _eq(f.result(timeout=0), sess.estimate(d))

    def test_abrupt_close_fails_queued_work(self):
        sess = Session()
        srv = sess.serve(max_batch=64, max_wait_ms=500.0, cache_size=0)
        futs = [srv.submit(d) for d in _pool(6)]
        srv.close(drain=False)
        failed = 0
        for f in futs:
            try:
                f.result(timeout=5)
            except ServerClosed:
                failed += 1
        assert failed >= 1              # first batch may already be in flight

    def test_context_manager_exception_skips_drain(self):
        with pytest.raises(RuntimeError, match="boom"):
            with Session().serve(max_wait_ms=500.0, cache_size=0) as srv:
                srv.submit(_pool(1)[0])
                raise RuntimeError("boom")
        assert srv.closed

    def test_invalid_params_rejected(self):
        sess = Session()
        for kw in ({"max_batch": 0}, {"max_wait_ms": -1.0},
                   {"max_queue": 0}, {"timeout_ms": 0}):
            with pytest.raises(ValueError):
                sess.serve(**kw)


class TestStatsAndSurface:
    def test_stats_shape(self):
        sess = Session()
        with sess.serve() as srv:
            for d in _pool(8):
                srv.estimate(d)
            s = srv.stats()
        assert s["submitted"] == s["served"] == 8
        assert s["errors"] == 0 and s["error_rate"] == 0.0
        assert s["mean_batch"] >= 1.0
        lat = s["latency_ms"]
        assert lat["n"] == 8
        assert 0.0 < lat["p50"] <= lat["p99"]
        assert s["queue_depth"] == 0 and s["inflight"] == 0

    def test_public_surface(self):
        from repro import api

        for name in ("Server", "ServerClosed", "ServerOverloaded",
                     "RequestTimeout"):
            assert name in api.__all__
            assert getattr(repro, name) is getattr(api, name)
        assert isinstance(Session().serve(), Server) is True
        assert repro.Estimate(
            t_exe=1.0, t_ideal=1.0, t_ovh=0.0, bound_ratio=1.0,
            memory_bound=True, total_bytes=1.0, n_lsu=1).cached is False
