"""Whole-model estimation subsystem (repro.workload).

Covers the acceptance invariants: walker decomposition sums to the
aggregate analysis, composed phase totals equal the sum of per-op
Session.estimate calls (1e-6, all three backends), and model sweeps
stream bit-equal to materialized evaluation (any chunking, JSON
round-trip included).
"""
import json

import numpy as np
import pytest

import repro
from repro import hw
from repro import workload as wl
from repro.core import hlo_counter as HC
from repro.core import stream as ST

BACKENDS = ("scalar", "numpy-batch", "jax-jit")


@pytest.fixture(scope="module")
def toy_cfg():
    from repro.configs import ARCHS, reduced_config

    # 2-layer toy model (the ISSUE's composition target).
    name = sorted(ARCHS)[0]
    return reduced_config(ARCHS[name], layers_scale=2)


@pytest.fixture(scope="module")
def phase_texts(toy_cfg):
    from repro.workload import steps

    return {p: steps.phase_hlo(toy_cfg, p, batch=2, seq_len=32)
            for p in ("train", "decode")}


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------

class TestWalker:
    def test_records_sum_to_aggregate_analysis(self, phase_texts):
        for text in phase_texts.values():
            recs = wl.walk_module(text)
            agg = HC.analyze(text)
            assert sum(r.total_bytes for r in recs) == pytest.approx(
                agg.total_bytes, rel=1e-9)
            assert sum(r.flops for r in recs) == pytest.approx(
                agg.flops, rel=1e-9)
            by_class = {}
            for r in recs:
                for k, v in r.bytes_by_class.items():
                    by_class[k] = by_class.get(k, 0.0) + v
            for k, v in agg.bytes_by_class.items():
                assert by_class.get(k, 0.0) == pytest.approx(v, rel=1e-9)

    def test_scan_ops_carry_trip_multiplier(self, phase_texts, toy_cfg):
        recs = wl.walk_module(phase_texts["decode"])
        # the layer scan shows up as records with trips > 1
        assert any(r.trips > 1 for r in recs)
        assert all(r.trips >= 1 for r in recs)

    def test_op_classes_in_taxonomy(self, phase_texts):
        recs = wl.walk_module(phase_texts["train"])
        assert recs, "train step walked to zero records"
        assert {r.op_class for r in recs} <= set(wl.OP_CLASSES)
        assert any(r.op_class == "matmul" for r in recs)

    def test_paths_unique(self, phase_texts):
        recs = wl.walk_module(phase_texts["train"])
        # scoped paths give each record a stable identity for reports
        assert len({r.path for r in recs}) == len(recs)


# ---------------------------------------------------------------------------
# composition (the acceptance bit-equality)
# ---------------------------------------------------------------------------

class TestComposition:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_total_equals_sum_of_per_op_estimates(self, phase_texts,
                                                  backend):
        sess = repro.Session(backend=backend)
        rep = sess.estimate_model(phase_texts)
        assert rep.phase_names == ("train", "decode")
        for phase in rep.phases:
            assert phase.ops, f"{phase.name} composed zero scored ops"
            parts = sum(sess.estimate(op.design).t_exe for op in phase.ops)
            assert phase.t_total == pytest.approx(parts, rel=1e-6)
        assert rep.total_latency() == pytest.approx(
            sum(p.t_total for p in rep.phases), rel=1e-12)

    def test_backends_agree(self, phase_texts):
        totals = [repro.Session(backend=b).estimate_model(
            phase_texts).total_latency() for b in BACKENDS]
        for t in totals[1:]:
            assert t == pytest.approx(totals[0], rel=1e-6)

    def test_report_breakdowns(self, phase_texts):
        rep = repro.Session().estimate_model(phase_texts)
        ph = rep.phase("train")
        by_class = ph.by_class()
        assert sum(d["t_exe"] for d in by_class) == pytest.approx(
            ph.t_total, rel=1e-9)
        assert sum(d["share"] for d in by_class) == pytest.approx(1.0)
        layers = ph.by_layer()
        assert sum(d["t_exe"] for d in layers) == pytest.approx(
            ph.t_total, rel=1e-9)
        rows = rep.rows()
        assert rows and rep.to_csv().count("\n") == len(rows) + 1
        s = rep.summary()
        assert set(s["split"]) == {"train", "decode"}
        assert s["split"]["train"] + s["split"]["decode"] == pytest.approx(1)

    def test_config_input_path(self, toy_cfg):
        sess = repro.Session()
        rep = sess.estimate_model(toy_cfg, phases=("decode",), batch=1,
                                  seq_len=16)
        assert rep.name == toy_cfg.name
        assert rep.total_latency("decode") > 0

    def test_callable_input_path(self):
        import jax.numpy as jnp

        import jax

        def f(x, w):
            return jnp.tanh(x @ w)

        specs = [jax.ShapeDtypeStruct(s, jnp.float32)
                 for s in [(64, 128), (128, 128)]]
        rep = repro.Session().estimate_model(f, *specs)
        assert rep.phase_names == ("step",)
        assert rep.total_latency() > 0

    def test_bad_input_raises(self):
        with pytest.raises(TypeError):
            repro.Session().estimate_model(12345)

    def test_flops_only_ops_enter_compute_term(self, phase_texts):
        rep = repro.Session().estimate_model(phase_texts)
        ph = rep.phase("train")
        assert ph.t_compute > 0
        assert ph.flops > 0


# ---------------------------------------------------------------------------
# model sweeps
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def plan(phase_texts):
    return repro.Session().plan_model(
        phase_texts, phases=("train", "decode"), batch=(2,), seq_len=(32,),
        shards=(1, 2, 4), hardware=(None, "tpu_v5e"), chunk_size=4)


class TestModelSweep:
    def test_streaming_bit_equals_materialized(self, plan):
        full = plan.materialize()
        rep = repro.Session().sweep_model(plan=plan, chunk_size=4)
        assert rep.streaming and rep.n_points == plan.n
        ids = rep.cols["id"].astype(np.int64)
        for k in full:
            assert np.array_equal(np.asarray(full[k])[ids], rep.cols[k]), k

    def test_any_chunking_bit_equal(self, plan):
        full = plan.materialize()
        for cs in (1, 3, 7, plan.n):
            ev = ST.run_stream(plan.n, cs, plan.evaluator(),
                               [ST.StatsReducer()])
            stats = ev.reducers[0]
            assert stats.t_exe_sum == pytest.approx(
                float(np.sum(full["t_exe"])), rel=1e-12)
            assert int(stats.summary()["n_points"]) == plan.n

    def test_materialized_report_holds_all_points(self, plan):
        rep = repro.Session().sweep_model(plan=plan)
        assert not rep.streaming and len(rep) == plan.n
        best = rep.best()
        assert best["t_exe"] == pytest.approx(
            float(np.min(rep.cols["t_exe"])))
        assert best["phase"] in ("train", "decode")

    def test_json_round_trip_bit_equal(self, plan):
        plan2 = wl.ModelSweepPlan.from_json(plan.to_json())
        a, b = plan.materialize(), plan2.materialize()
        for k in a:
            assert np.array_equal(a[k], b[k]), k
        # canonical text is stable
        assert plan.to_json() == plan2.to_json()

    def test_plan_is_picklable(self, plan):
        import pickle

        plan2 = pickle.loads(pickle.dumps(plan))
        a, b = plan.materialize(), plan2.materialize()
        for k in a:
            assert np.array_equal(a[k], b[k]), k

    def test_hardware_axis_changes_scores(self, plan):
        cols = plan.materialize()
        base = cols["t_exe"][cols["hardware"] == 0]
        tpu = cols["t_exe"][cols["hardware"] == 1]
        assert not np.allclose(base, tpu)

    def test_shards_divide_traffic(self, plan):
        cols = plan.materialize()
        dec = (cols["phase"] == list(plan.lists["phase"]).index("decode"))
        one = cols["total_bytes"][dec & (cols["shards"] == 1)]
        four = cols["total_bytes"][dec & (cols["shards"] == 4)]
        # decode has no all-reduce term: traffic scales ~1/shards
        # (up to access-granularity rounding)
        assert np.all(four < one)

    def test_train_sharding_adds_allreduce(self, phase_texts, toy_cfg):
        from repro.workload import steps

        sess = repro.Session()
        p = sess.plan_model(toy_cfg, phases=("train",), batch=(2,),
                            seq_len=(16,), shards=(1, 8))
        kernels1, _ = p._point_kernels("train", 2, 16, 1)
        kernels8, _ = p._point_kernels("train", 2, 16, 8)
        assert len(kernels8) == len(kernels1) + 1
        assert p.param_bytes == steps.param_bytes(toy_cfg)

    def test_sweep_point_matches_estimate_model(self, phase_texts):
        # shards=1, hardware=None point must reproduce the composed total
        sess = repro.Session()
        p = sess.plan_model(phase_texts, phases=("decode",), batch=(2,),
                            seq_len=(32,))
        cols = p.materialize()
        assert len(cols["id"]) == 1
        total = sess.estimate_model(
            {"decode": phase_texts["decode"]}).total_latency()
        assert float(cols["t_exe"][0]) == pytest.approx(total, rel=1e-9)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_sweep_backends_agree(self, phase_texts, backend):
        sess = repro.Session(backend=backend)
        p = sess.plan_model(phase_texts, phases=("decode",), batch=(2,),
                            seq_len=(32,), shards=(1, 2))
        cols = p.materialize()
        ref = repro.Session(backend="scalar").plan_model(
            phase_texts, phases=("decode",), batch=(2,), seq_len=(32,),
            shards=(1, 2)).materialize()
        np.testing.assert_allclose(cols["t_exe"], ref["t_exe"], rtol=1e-6)

    def test_hardware_name_resolution(self, phase_texts):
        p = repro.Session().plan_model(
            phase_texts, phases=("decode",), batch=(2,), seq_len=(32,),
            hardware=("tpu_v4",))
        assert p.lists["hardware"][0] is hw.get("tpu_v4")
        with pytest.raises(KeyError):
            repro.Session().plan_model(
                phase_texts, phases=("decode",), batch=(2,), seq_len=(32,),
                hardware=("no_such_board",))

    def test_calibrated_session_scales_base_points(self, phase_texts):
        sess = repro.Session()
        cal = repro.Session(calibration_factor=2.0)
        a = sess.plan_model(phase_texts, phases=("decode",), batch=(2,),
                            seq_len=(32,)).materialize()
        b = cal.plan_model(phase_texts, phases=("decode",), batch=(2,),
                           seq_len=(32,)).materialize()
        assert float(b["t_exe"][0]) == pytest.approx(
            2.0 * float(a["t_exe"][0]), rel=1e-12)


class TestSessionSurface:
    def test_methods_are_session_level(self):
        # conventions: entry points live on Session, never module-level
        assert hasattr(repro.Session, "estimate_model")
        assert hasattr(repro.Session, "plan_model")
        assert hasattr(repro.Session, "sweep_model")
        assert not hasattr(wl, "estimate_model")

    def test_public_exports(self):
        for name in ("ModelReport", "PhaseReport", "OpRecord",
                     "ModelSweepPlan", "ModelSweepReport"):
            assert name in repro.__all__ and hasattr(repro, name)

    def test_sweep_model_requires_input(self):
        with pytest.raises(ValueError):
            repro.Session().sweep_model()
