"""Model-zoo tests: per-arch smoke (reduced config), math invariants
(blocked-vs-dense attention, chunked-vs-sequential mLSTM), decode-vs-forward
consistency, and MoE routing invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, list_archs, reduced_config
from repro.configs.shapes import SHAPES, cell_status, vision_patches
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import transformer as TF
from repro.models import xlstm as XL

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.frontend == "audio":
        return {"features": jax.random.normal(KEY, (B, S, cfg.frontend_dim),
                                              jnp.bfloat16),
                "labels": jnp.zeros((B, S), jnp.int32),
                "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "vision":
        p = vision_patches(S)
        return {"features": jax.random.normal(KEY, (B, p, cfg.frontend_dim),
                                              jnp.bfloat16),
                "tokens": jnp.zeros((B, S - p), jnp.int32),
                "labels": jnp.zeros((B, S - p), jnp.int32)}
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


@pytest.mark.parametrize("arch", list_archs())
class TestPerArchSmoke:
    def test_forward_and_train_step(self, arch):
        """One forward + one grad step on the reduced config: output shapes
        correct, loss finite, grads finite."""
        cfg = reduced_config(ARCHS[arch])
        params = TF.init_params(KEY, cfg)
        batch = _batch(cfg)

        @jax.jit
        def step(p, b):
            (loss, metrics), grads = jax.value_and_grad(
                TF.loss_fn, has_aux=True)(p, cfg, b)
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            return loss, gnorm

        loss, gnorm = step(params, batch)
        assert jnp.isfinite(loss), arch
        assert jnp.isfinite(gnorm) and gnorm > 0, arch

    def test_logits_shape_and_finite(self, arch):
        cfg = reduced_config(ARCHS[arch])
        params = TF.init_params(KEY, cfg)
        batch = _batch(cfg)
        x = TF.embed_inputs(params, cfg, tokens=batch.get("tokens"),
                            features=batch.get("features"))
        h, _ = TF.forward_hidden(params, cfg, x)
        logits = TF.logits_fn(params, cfg, h)
        assert logits.shape[-1] == cfg.padded_vocab
        assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())

    def test_decode_matches_forward_f32(self, arch):
        """Teacher-forced decode logits == full-forward logits in f32."""
        cfg = reduced_config(ARCHS[arch])
        if not cfg.is_decoder or cfg.frontend == "vision":
            pytest.skip("no pure-token decode path")
        cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
        params = TF.init_params(KEY, cfg)
        B, S = 2, 16
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        x = TF.embed_inputs(params, cfg, tokens=toks)
        h, _ = TF.forward_hidden(params, cfg, x)
        full = TF.logits_fn(params, cfg, h)
        caches = TF.init_caches(cfg, B, S)
        outs = []
        for i in range(S):
            lg, caches = TF.decode_step(params, cfg, toks[:, i:i + 1],
                                        caches, jnp.asarray(i, jnp.int32))
            outs.append(lg)
        dec = jnp.stack(outs, 1)
        rel = float(jnp.abs(dec - full).max() / jnp.abs(full).max())
        assert rel < 1e-4, (arch, rel)


class TestAttentionMath:
    @pytest.mark.parametrize("kwargs", [
        dict(causal=True), dict(causal=False),
        dict(causal=True, window=7), dict(causal=True, softcap=10.0),
    ])
    def test_blocked_equals_dense(self, kwargs):
        B, S, Hq, Hkv, D = 2, 50, 8, 2, 16
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
        a = L.blocked_attention(q, k, v, block_q=16, block_kv=8, **kwargs)
        b = L.dense_attention(q, k, v, **kwargs)
        np.testing.assert_allclose(a, b, atol=3e-6)

    def test_decode_offset_masking(self):
        """dense_attention with kv_len masks future cache slots."""
        B, S, H, D = 1, 12, 2, 8
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
        out5 = L.dense_attention(q, k, v, causal=False, kv_len=5)
        k2 = k.at[:, 5:].set(999.0)       # garbage beyond kv_len
        v2 = v.at[:, 5:].set(999.0)
        out5b = L.dense_attention(q, k2, v2, causal=False, kv_len=5)
        np.testing.assert_allclose(out5, out5b, atol=1e-6)


class TestXlstmMath:
    def test_chunked_equals_sequential(self):
        cfg = reduced_config(ARCHS["xlstm-1.3b"])
        p = XL.mlstm_init(KEY, cfg)
        x = jax.random.normal(KEY, (2, 24, cfg.d_model), jnp.float32)
        a = XL.mlstm_forward(p, cfg, x)
        b = XL.mlstm_sequential(p, cfg, x)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)

    def test_chunk_size_invariance(self):
        cfg = reduced_config(ARCHS["xlstm-1.3b"])
        p = XL.mlstm_init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 32, cfg.d_model), jnp.float32)
        outs = []
        for c in (4, 8, 16, 32):
            outs.append(XL.mlstm_forward(
                p, dataclasses.replace(cfg, chunk_size=c), cfg_x := cfg and x))
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0], np.float32),
                                       np.asarray(o, np.float32),
                                       rtol=1e-3, atol=1e-4)


class TestMoE:
    def _cfg(self, **kw):
        base = reduced_config(ARCHS["qwen3-moe-235b-a22b"])
        return dataclasses.replace(base, dtype="float32", **kw)

    def test_batch_vs_single_token_consistent(self):
        cfg = self._cfg(capacity_factor=8.0)
        p = MOE.init(KEY, cfg)
        x = jax.random.normal(KEY, (2, 6, cfg.d_model), jnp.float32)
        full, _ = MOE.forward(p, cfg, x)
        singles = jnp.concatenate(
            [MOE.forward(p, cfg, x[:, i:i + 1])[0] for i in range(6)], axis=1)
        np.testing.assert_allclose(full, singles, atol=1e-6)

    def test_capacity_drops_tokens(self):
        """With a tiny capacity factor some tokens must be dropped, and the
        output of dropped tokens is smaller in norm (partial combine)."""
        big, _ = MOE.forward(MOE.init(KEY, self._cfg(capacity_factor=8.0)),
                             self._cfg(capacity_factor=8.0),
                             jnp.ones((1, 64, 64), jnp.float32))
        del big
        cfg_small = self._cfg(capacity_factor=0.25)
        p = MOE.init(KEY, cfg_small)
        x = jax.random.normal(KEY, (1, 64, cfg_small.d_model), jnp.float32)
        out_small, aux = MOE.forward(p, cfg_small, x)
        assert jnp.isfinite(out_small).all()
        assert jnp.isfinite(aux)

    def test_weights_renormalized(self):
        """Top-k router weights sum to 1 per token (checked via a probe:
        identical expert weights => output == input-projection regardless of
        routing)."""
        cfg = self._cfg(capacity_factor=8.0)
        p = MOE.init(KEY, cfg)
        # make all experts identical
        for k in ("wi", "wg", "wo"):
            p[k] = jnp.broadcast_to(p[k][:1], p[k].shape)
        x = jax.random.normal(KEY, (1, 8, cfg.d_model), jnp.float32)
        out, _ = MOE.forward(p, cfg, x)
        # reference: single-expert FFN
        h = x @ p["wi"][0]
        g = jax.nn.silu(x @ p["wg"][0])
        ref = (g * h) @ p["wo"][0]
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)

    def test_aux_loss_uniform_router_is_one(self):
        """Switch aux loss == 1.0 for a perfectly uniform router."""
        cfg = self._cfg(capacity_factor=8.0)
        p = MOE.init(KEY, cfg)
        p["router"]["w"] = jnp.zeros_like(p["router"]["w"])
        x = jax.random.normal(KEY, (1, 256, cfg.d_model), jnp.float32)
        _, aux = MOE.forward(p, cfg, x)
        assert aux == pytest.approx(1.0, rel=0.05)


class TestSkipRules:
    def test_cell_status_covers_40_cells(self):
        total = skipped = 0
        for arch in list_archs():
            for s in SHAPES.values():
                total += 1
                ok, reason = cell_status(ARCHS[arch], s)
                if not ok:
                    skipped += 1
                    assert reason
        assert total == 40
        # 7 full-attention long_500k skips + hubert decode/long
        assert skipped == 9

    def test_subquadratic_archs_run_long(self):
        for arch in ("recurrentgemma-9b", "xlstm-1.3b"):
            ok, _ = cell_status(ARCHS[arch], SHAPES["long_500k"])
            assert ok, arch
