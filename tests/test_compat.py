"""Unit tests for the version-adaptive compat layer.

The old/new jax namespaces are simulated by monkeypatching, so both branches
of every shim are exercised regardless of which jax is installed.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


@dataclasses.dataclass
class _FakeParams:
    dimension_semantics: tuple = ()


class TestCompilerParams:
    def test_real_jax_builds_params(self):
        p = compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"))
        assert p is not None
        assert tuple(p.dimension_semantics) == ("parallel", "arbitrary")

    def test_new_namespace(self, monkeypatch):
        fake = types.SimpleNamespace(CompilerParams=_FakeParams)
        monkeypatch.setattr(compat, "_pltpu", fake)
        p = compat.tpu_compiler_params(dimension_semantics=("parallel",))
        assert isinstance(p, _FakeParams)

    def test_old_namespace(self, monkeypatch):
        fake = types.SimpleNamespace(TPUCompilerParams=_FakeParams)
        monkeypatch.setattr(compat, "_pltpu", fake)
        p = compat.tpu_compiler_params(dimension_semantics=("parallel",))
        assert isinstance(p, _FakeParams)

    def test_unknown_fields_dropped(self, monkeypatch):
        fake = types.SimpleNamespace(CompilerParams=_FakeParams)
        monkeypatch.setattr(compat, "_pltpu", fake)
        p = compat.tpu_compiler_params(dimension_semantics=("parallel",),
                                       field_from_the_future=123)
        assert isinstance(p, _FakeParams)
        assert not hasattr(p, "field_from_the_future")


class TestPrefetchGridSpec:
    def test_missing_raises_not_implemented(self, monkeypatch):
        monkeypatch.setattr(compat, "_pltpu", types.SimpleNamespace())
        with pytest.raises(NotImplementedError):
            compat.prefetch_scalar_grid_spec(num_scalar_prefetch=1, grid=(1,))


class TestMakeMesh:
    def test_builds_mesh_on_installed_jax(self):
        mesh = compat.make_mesh((len(jax.devices()),), ("d",))
        assert tuple(mesh.axis_names) == ("d",)

    def test_old_jax_branch_omits_axis_types(self, monkeypatch):
        calls = {}

        def fake_make_mesh(shape, axes, **kw):
            calls.update(kw)
            return "mesh"

        monkeypatch.setattr(compat, "AxisType", None)
        monkeypatch.setattr(compat.jax, "make_mesh", fake_make_mesh)
        assert compat.make_mesh((2,), ("d",)) == "mesh"
        assert "axis_types" not in calls

    def test_new_jax_branch_passes_axis_types(self, monkeypatch):
        calls = {}

        class FakeAxisType:
            Auto = "auto"
            Explicit = "explicit"

        def fake_make_mesh(shape, axes, axis_types=None):
            calls["axis_types"] = axis_types
            return "mesh"

        monkeypatch.setattr(compat, "AxisType", FakeAxisType)
        monkeypatch.setattr(compat.jax, "make_mesh", fake_make_mesh)
        assert compat.make_mesh((2, 2), ("a", "b")) == "mesh"
        assert calls["axis_types"] == ("auto", "auto")
        compat.make_mesh((2,), ("a",), explicit=True)
        assert calls["axis_types"] == ("explicit",)


class TestDefaultInterpret:
    def test_explicit_flag_wins(self):
        assert compat.default_interpret(True, backend="tpu") is True
        assert compat.default_interpret(False, backend="cpu") is False

    def test_backend_policy(self):
        assert compat.default_interpret(backend="tpu") is False
        assert compat.default_interpret(backend="cpu") is True
        assert compat.default_interpret(backend="gpu") is True


class TestOptimizationBarrier:
    def test_identity_forward(self):
        x = jnp.arange(6.0).reshape(2, 3)
        np.testing.assert_array_equal(
            np.asarray(compat.optimization_barrier(x)), np.asarray(x))

    def test_differentiates_on_this_jax(self):
        g = jax.grad(lambda x: (compat.optimization_barrier(x) ** 2).sum())(
            jnp.ones(4))
        np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones(4))

    def test_custom_jvp_fallback_path(self, monkeypatch):
        """Force the no-native-rule branch and check grad still works."""
        monkeypatch.setattr(compat, "barrier_is_differentiable", lambda: False)
        g = jax.grad(lambda x: (compat.optimization_barrier(x) * 3.0).sum())(
            jnp.ones(3))
        np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones(3))

    def test_under_checkpoint_and_scan(self, monkeypatch):
        monkeypatch.setattr(compat, "barrier_is_differentiable", lambda: False)

        def f(x):
            def body(c, _):
                return compat.optimization_barrier(c) * 1.5, None
            body = jax.checkpoint(body, prevent_cse=False)
            y, _ = jax.lax.scan(body, x, None, length=3)
            return y.sum()

        g = jax.grad(f)(jnp.ones(2))
        np.testing.assert_allclose(np.asarray(g), 1.5 ** 3 * np.ones(2),
                                   rtol=1e-6)


class TestAutotuneFailureHandling:
    def _patch(self, monkeypatch, errors):
        """Make analyze_candidate raise per-candidate errors (or succeed)."""
        from repro.core import autotune as AT

        def fake_analyze(cfg, shape, mesh, candidate, cache=None, hw=None):
            err = errors.get(candidate.name)
            if err is not None:
                raise err
            return {"flops": 1.0, "bytes_by_class": {"stream": 1e6},
                    "collective_wire_bytes": 0.0,
                    "collective_operand_bytes": 0.0,
                    "collective_by_kind": {}, "n_collectives": 0.0,
                    "memory_bytes": None, "xla_cost": {},
                    "compile_s": 0.0, "cached": False}

        monkeypatch.setattr(AT, "analyze_candidate", fake_analyze)
        return AT

    def test_all_same_error_reraises(self, monkeypatch):
        AT = self._patch(monkeypatch, {
            "a": NotImplementedError("no rule for optimization_barrier"),
            "b": NotImplementedError("no rule for optimization_barrier")})
        cands = [AT.Candidate("a", {}, {}), AT.Candidate("b", {}, {})]
        with pytest.raises(RuntimeError, match="not candidate-specific"):
            AT._autotune(None, None, None, cands, cache=False)

    def test_partial_failure_recorded(self, monkeypatch):
        AT = self._patch(monkeypatch,
                         {"bad": ValueError("candidate-specific boom")})
        cands = [AT.Candidate("ok", {}, {}), AT.Candidate("bad", {}, {})]
        res = AT._autotune(None, None, None, cands, cache=False)
        assert len(res) == 1 and res[0].candidate.name == "ok"
        assert len(res.failures) == 1
        assert res.failures[0].summary()["name"] == "bad"
        assert res.failures[0].error_type == "ValueError"

    def test_distinct_errors_return_empty_with_failures(self, monkeypatch):
        AT = self._patch(monkeypatch, {"a": ValueError("x"),
                                       "b": TypeError("y")})
        cands = [AT.Candidate("a", {}, {}), AT.Candidate("b", {}, {})]
        res = AT._autotune(None, None, None, cands, cache=False)
        assert list(res) == []
        assert {f.error_type for f in res.failures} == {"ValueError",
                                                        "TypeError"}
