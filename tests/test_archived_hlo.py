"""Regression: the trip-aware analyzer on an archived production module.

Guards the HLO text parsing (tuple-type comments, while-condition formats,
fusion caps) against silent breakage — analyzing a real 256-chip compiled
module from results/dryrun/ when present."""
import glob
import gzip
import os

import pytest

from repro.core import hlo_counter as HC

ARCHIVE = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@pytest.mark.parametrize("pattern", ["qwen2-7b__train_4k__16x16",
                                     "xlstm-1.3b__prefill_32k__16x16"])
def test_archived_module_analysis(pattern):
    paths = glob.glob(os.path.join(ARCHIVE, pattern + ".hlo.gz"))
    if not paths:
        pytest.skip("no archived dry-run modules (run repro.launch.dryrun)")
    with gzip.open(paths[0], "rt") as f:
        text = f.read()
    an = HC.Analyzer(text)
    # the module must contain recognized while loops with trips > 1
    trips = []
    for comp in an.comps.values():
        for ins in comp.instrs:
            if ins.opcode == "while":
                cond = HC._called(ins.rest, "condition")
                if cond in an.comps:
                    trips.append(HC._while_trips(an.comps[cond]))
    assert trips and max(trips) > 1, "while trip parsing regressed"
    cost = an.entry_cost()
    assert cost.flops > 1e12           # layer scan actually multiplied
    assert cost.total_bytes > 1e9
    assert cost.n_collectives > 0
