"""Substrate tests: optimizer, data determinism, checkpointing, fault
tolerance, gradient compression."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, reduced_config
from repro.data.pipeline import DataConfig, MemmapDataset, SyntheticDataset
from repro.optim import OptimizerConfig, adamw_init, adamw_update, lr_schedule
from repro.runtime import PreemptionHandler, StepWatchdog
from repro.runtime.compression import compress_grads, decompress_grads


class TestOptimizer:
    def test_adamw_converges_on_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              total_steps=200, min_lr_ratio=1.0)
        target = jnp.asarray([[1.5, -2.0], [0.5, 3.0]])
        params = {"w": jnp.zeros((2, 2))}
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": params["w"] - target}
            params, state, _ = adamw_update(grads, state, params, cfg)
        np.testing.assert_allclose(params["w"], target, atol=0.05)

    def test_clipping_bounds_update(self):
        cfg = OptimizerConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                              warmup_steps=0)
        params = {"w": jnp.zeros((4,))}
        state = adamw_init(params, cfg)
        huge = {"w": jnp.full((4,), 1e9)}
        new, _, m = adamw_update(huge, state, params, cfg)
        assert float(m["grad_norm"]) > 1e8
        assert float(jnp.abs(new["w"]).max()) < 10.0

    def test_bf16_state_dtype(self):
        cfg = OptimizerConfig(state_dtype="bfloat16")
        params = {"w": jnp.zeros((8, 8), jnp.float32)}
        state = adamw_init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        grads = {"w": jnp.ones((8, 8))}
        _, state2, _ = adamw_update(grads, state, params, cfg)
        assert state2["m"]["w"].dtype == jnp.bfloat16

    def test_schedule_warmup_and_decay(self):
        cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
        assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-3)


class TestData:
    def test_synthetic_deterministic_by_step(self):
        cfg = reduced_config(ARCHS["stablelm-3b"])
        d = SyntheticDataset(cfg, DataConfig(seq_len=16, batch_size=4, seed=7))
        b1 = d.get_batch(42)
        b2 = d.get_batch(42)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(b1["tokens"], d.get_batch(43)["tokens"])

    def test_shards_disjoint_streams(self):
        cfg = reduced_config(ARCHS["stablelm-3b"])
        mk = lambda s: SyntheticDataset(
            cfg, DataConfig(seq_len=16, batch_size=4, seed=7, n_shards=2,
                            shard=s))
        assert not np.array_equal(mk(0).get_batch(5)["tokens"],
                                  mk(1).get_batch(5)["tokens"])

    def test_labels_are_next_tokens(self):
        cfg = reduced_config(ARCHS["stablelm-3b"])
        d = SyntheticDataset(cfg, DataConfig(seq_len=16, batch_size=2))
        b = d.get_batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_memmap_dataset(self, tmp_path):
        cfg = reduced_config(ARCHS["stablelm-3b"])
        path = str(tmp_path / "tokens.bin")
        np.arange(10_000, dtype=np.uint16).tofile(path)
        d = MemmapDataset(cfg, DataConfig(seq_len=32, batch_size=4), path)
        b = d.get_batch(3)
        assert b["tokens"].shape == (4, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:] % cfg.vocab_size,
                                      b["labels"][:, :-1])
        np.testing.assert_array_equal(b["tokens"],
                                      d.get_batch(3)["tokens"])


class TestCheckpoint:
    def _tree(self, v=0.0):
        return {"a": jnp.full((4, 4), v), "b": [jnp.arange(3.0),
                                                jnp.asarray(7, jnp.int32)]}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = self._tree(1.5)
        mgr.save(10, tree)
        restored, step = mgr.restore(self._tree())
        assert step == 10
        np.testing.assert_array_equal(restored["a"], tree["a"])
        np.testing.assert_array_equal(restored["b"][0], tree["b"][0])

    def test_latest_and_cleanup(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(float(s)))
        assert mgr.all_steps() == [3, 4]
        restored, step = mgr.restore(self._tree())
        assert step == 4
        assert float(restored["a"][0, 0]) == 4.0

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, self._tree(2.0), blocking=False)
        mgr.wait()
        _, step = mgr.restore(self._tree())
        assert step == 5

    def test_atomicity_no_partial_dirs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        for name in os.listdir(tmp_path):
            assert not name.endswith(".tmp")


class TestFaultTolerance:
    def test_preemption_flag(self):
        h = PreemptionHandler()
        assert not h.should_stop
        h.trigger()
        assert h.should_stop

    def test_watchdog_flags_stragglers(self):
        events = []
        wd = StepWatchdog(factor=5.0, warmup=3,
                          on_straggler=lambda s, dt, med: events.append(s))
        for step in range(10):
            wd.start_step(step)
            if step == 7:
                time.sleep(0.12)
            else:
                time.sleep(0.002)
            wd.end_step()
        assert wd.straggler_steps == [7]
        assert events == [7]


class TestCompression:
    def _grads(self):
        k = jax.random.PRNGKey(0)
        return {"w": jax.random.normal(k, (64, 64)) * 0.01,
                "b": jax.random.normal(k, (64,))}

    def test_bf16_roundtrip(self):
        g = self._grads()
        wire, _ = compress_grads(g, "bf16")
        assert wire["w"].dtype == jnp.bfloat16
        back = decompress_grads(wire, "bf16", g)
        np.testing.assert_allclose(back["w"], g["w"], rtol=1e-2, atol=1e-4)

    def test_int8_roundtrip_with_error_feedback(self):
        g = self._grads()
        wire, err = compress_grads(g, "int8")
        qg, scale = jax.tree.leaves(wire, is_leaf=lambda t: isinstance(t, tuple))[0]
        assert qg.dtype == jnp.int8
        back = decompress_grads(wire, "int8", g)
        np.testing.assert_allclose(back["w"], g["w"], atol=float(scale) + 1e-6)
        # error feedback: residual equals exactly what quantization lost
        np.testing.assert_allclose(np.asarray(g["w"]) - np.asarray(back["w"]),
                                   np.asarray(err["w"]), atol=1e-7)
