"""The pluggable hardware-spec layer: registry, serialization, calibration
fold-back, legacy-constant removal, backend equivalence on a
(Design x Hardware) grid, the sweep hardware axis, and the cache-key
regression."""
import dataclasses
import warnings

import numpy as np
import pytest

import repro
from repro import Design, Session, Space, hw
from repro.core import validate as V
from repro.core.lsu import LsuType
from repro.hw import ClockDomain, DramOrganization, Hardware, MemorySystem

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]

PRESETS = ("stratix10_ddr4_1866", "stratix10_ddr4_2666", "tpu_v5e", "tpu_v4")


def _designs() -> list[Design]:
    """The shared (Design) half of the (Design x Hardware) grid."""
    return [Design.microbench(t, n_ga=g, simd=s, n_elems=1 << 14, delta=d)
            for t in ALL_TYPES for g in (1, 3) for s in (1, 4)
            for d in (1, 7)]


def _synthetic_report(factor: float = 1.7) -> V.ValidationReport:
    """A deterministic ValidationReport (no jax, no wall clock)."""

    def kv(name, measured, predicted):
        return V.KernelValidation(
            name=name, backend="cpu", interpret=True,
            measured_s=measured, predicted_s=predicted,
            bytes_moved=1e6, flops=0.0,
            err_pct=abs(predicted - measured) / measured * 100.0,
            memory_bound=True)

    measured_bw = 5e9
    return V.ValidationReport(
        results=[kv("membench_aligned", 1.0, 1.0),
                 kv("membench_strided", 1.0, 0.8),
                 kv("membench_gather", 2.0, 1.0)],
        failures=[], dram=V.calibrate_dram(measured_bw),
        measured_bw=measured_bw, calibration_factor=factor)


class TestRegistry:
    def test_presets_resolve(self):
        for name in PRESETS:
            spec = hw.get(name)
            assert isinstance(spec, Hardware) and spec.name == name
        assert set(PRESETS) <= set(hw.names())

    def test_unknown_name_lists_known(self):
        with pytest.raises(KeyError, match="tpu_v5e"):
            hw.get("nonexistent-board")

    def test_register_and_overwrite(self):
        custom = hw.get("tpu_v5e").with_name("test-custom") \
            .with_efficiencies(k_gather=0.5)
        try:
            assert hw.register(custom) is custom
            assert hw.get("test-custom").mem.k_gather == pytest.approx(0.5)
            with pytest.raises(ValueError, match="already registered"):
                hw.register(custom)
            hw.register(custom.with_host_factor(2.0), overwrite=True)
            assert hw.get("test-custom").host_factor == 2.0
        finally:
            hw.unregister("test-custom")

    def test_register_rejects_non_hardware(self):
        with pytest.raises(TypeError):
            hw.register(repro.DDR4_1866)


class TestSerialization:
    def test_round_trip_every_preset(self):
        for name in PRESETS:
            spec = hw.get(name)
            again = Hardware.from_json(spec.to_json())
            assert again == spec
            assert again.to_json() == spec.to_json()

    def test_round_trip_calibrated(self):
        spec = Hardware.from_calibration(_synthetic_report())
        assert Hardware.from_json(spec.to_json()) == spec

    def test_future_schema_rejected(self):
        obj = hw.get("tpu_v4").to_dict()
        obj["schema"] = 999
        with pytest.raises(ValueError, match="newer"):
            Hardware.from_dict(obj)

    def test_unknown_fields_ignored(self):
        """A spec written by a slightly newer minor version still loads."""
        obj = hw.get("tpu_v4").to_dict()
        obj["mem"]["brand_new_field"] = 7
        assert Hardware.from_dict(obj).mem == hw.get("tpu_v4").mem


class TestBuilders:
    def test_with_helpers_are_pure(self):
        base = hw.get("stratix10_ddr4_1866")
        derived = base.with_name("x").with_host_factor(3.0) \
            .with_efficiencies(k_stream=0.5)
        assert (base.name, base.host_factor, base.mem.k_stream) == \
            ("stratix10_ddr4_1866", 1.0, 0.92)
        assert (derived.name, derived.host_factor, derived.mem.k_stream) == \
            ("x", 3.0, 0.5)
        with pytest.raises(dataclasses.FrozenInstanceError):
            base.name = "y"
        with pytest.raises(TypeError, match="unknown"):
            base.with_efficiencies(k_vmem=0.5)

    def test_from_parts_views_round_trip(self):
        spec = Hardware.from_parts("board", dram=repro.DDR4_2666,
                                   bsp=repro.STRATIX10_BSP)
        assert spec.dram_params() == repro.DDR4_2666
        assert spec.bsp_params() == repro.STRATIX10_BSP
        assert spec.mem.peak_bw == pytest.approx(repro.DDR4_2666.bw_mem)


class TestLegacyAliases:
    """The PR-4 alias shims completed their cycle and are gone as of 0.6:
    the old names raise AttributeError; the registry views (and the curated
    repro/repro.core re-exports built from them) are the replacement."""

    CASES = [
        ("repro.core.fpga", "DDR4_1866", "stratix10_ddr4_1866", "dram_params"),
        ("repro.core.fpga", "DDR4_2666", "stratix10_ddr4_2666", "dram_params"),
        ("repro.core.fpga", "DRAM_CONFIGS", "stratix10_ddr4_1866",
         "dram_params"),
        ("repro.core.fpga", "STRATIX10_BSP", "stratix10_ddr4_1866",
         "bsp_params"),
        ("repro.core.hbm", "TPU_V5E", "tpu_v5e", "tpu_params"),
    ]

    @pytest.mark.parametrize("mod,attr,preset,view", CASES)
    def test_alias_removed_and_registry_replaces(self, mod, attr, preset,
                                                 view):
        import importlib

        module = importlib.import_module(mod)
        with pytest.raises(AttributeError, match=attr):
            getattr(module, attr)
        # the documented replacement resolves
        assert getattr(hw.get(preset), view)() is not None

    def test_curated_surfaces_warning_free(self):
        """repro / repro.core / repro.hw re-exports never touch the shims."""
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            assert repro.DDR4_1866.name == "DDR4-1866"
            assert repro.TPU_V5E.hbm_bw == hw.get("tpu_v5e").mem.peak_bw
            from repro.core import DDR4_2666, DRAM_CONFIGS, STRATIX10_BSP
            assert DDR4_2666 in DRAM_CONFIGS.values()
            assert STRATIX10_BSP.burst_cnt == 4


class TestBackendEquivalence:
    """Acceptance: Session.with_hardware(hw.get(...)) estimates bit-identical
    across scalar / numpy-batch / jax-jit on a (Design x Hardware) grid."""

    @pytest.mark.parametrize("name", PRESETS)
    def test_scalar_vs_batch_bit_identical(self, name):
        designs = _designs()
        ref = Session(backend="numpy-batch").with_hardware(hw.get(name))
        got = Session(backend="scalar").with_hardware(hw.get(name))
        for r, g in zip(ref.estimate_many(designs), got.estimate_many(designs)):
            assert g.t_exe == r.t_exe
            assert g.t_ideal == r.t_ideal
            assert g.bound_ratio == r.bound_ratio
            assert g.memory_bound == r.memory_bound

    @pytest.mark.parametrize("name", PRESETS)
    def test_jax_jit_vs_batch_bit_identical(self, name):
        pytest.importorskip("jax")
        designs = _designs()
        ref = Session(backend="numpy-batch").with_hardware(hw.get(name))
        got = Session(backend="jax-jit").with_hardware(hw.get(name))
        for r, g in zip(ref.estimate_many(designs), got.estimate_many(designs)):
            assert g.t_exe == r.t_exe
            assert g.total_bytes == r.total_bytes

    def test_hardware_ordering_is_physical(self):
        """Faster memory systems predict faster streams."""
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, n_elems=1 << 16)
        t = {n: Session().with_hardware(hw.get(n)).estimate(d).t_exe
             for n in PRESETS}
        assert t["stratix10_ddr4_2666"] < t["stratix10_ddr4_1866"]
        assert t["tpu_v4"] < t["tpu_v5e"] < t["stratix10_ddr4_2666"]


class TestSessionIntegration:
    def test_with_hardware_sets_all_views(self):
        spec = hw.get("tpu_v4")
        sess = Session().with_hardware(spec)
        assert sess.hardware is spec
        assert sess.dram == spec.dram_params()
        assert sess.bsp == spec.bsp_params()
        assert sess.hw == spec.tpu_params()
        assert sess.calibration_factor == spec.host_factor
        # constructor path derives identically
        assert Session(hardware=spec) == sess

    def test_diverging_overrides_drop_stale_spec(self):
        """with_dram / with_calibration invalidate the hardware field — a
        stale spec must not leak into cache keys or simulator geometry."""
        sess = Session().with_hardware(hw.get("stratix10_ddr4_2666"))
        assert sess.with_dram(repro.DDR4_1866).hardware is None
        assert sess.with_calibration(_synthetic_report()).hardware is None

    def test_host_factor_scales_estimates(self):
        spec = hw.get("stratix10_ddr4_1866")
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, n_elems=1 << 14)
        base = Session().with_hardware(spec).estimate(d).t_exe
        doubled = Session().with_hardware(
            spec.with_host_factor(2.0)).estimate(d).t_exe
        assert doubled == pytest.approx(2.0 * base, rel=1e-12)

    def test_from_calibration_matches_with_calibration(self):
        """Acceptance: the persisted fold-back predicts what the session-local
        calibration predicts, to 1e-6."""
        rep = _synthetic_report(factor=1.7)
        spec = Hardware.from_calibration(rep)
        for t in ALL_TYPES:
            d = Design.microbench(t, n_ga=2, simd=4, n_elems=1 << 14)
            a = Session().with_calibration(rep).estimate(d)
            b = Session().with_hardware(spec).estimate(d)
            assert b.t_exe == pytest.approx(a.t_exe, rel=1e-6)
            assert b.memory_bound == a.memory_bound
        # ... and survives a disk round trip
        again = Hardware.from_json(spec.to_json())
        d = Design.microbench(LsuType.BC_ALIGNED, n_ga=2, n_elems=1 << 14)
        assert Session().with_hardware(again).estimate(d).t_exe == \
            pytest.approx(Session().with_calibration(rep).estimate(d).t_exe,
                          rel=1e-6)

    def test_from_calibration_folds_class_errors(self):
        spec = Hardware.from_calibration(_synthetic_report())
        assert spec.host_factor == pytest.approx(1.7)
        assert spec.mem.peak_bw == pytest.approx(5e9)
        assert spec.mem.k_stream == pytest.approx(0.92)        # anchor: 1.0
        assert spec.mem.k_strided == pytest.approx(0.92 * 0.8)
        assert spec.mem.k_gather == pytest.approx(0.92 * 0.5)

    def test_predict_and_traffic_accept_hardware(self):
        from repro.core.hbm import AccessClass, Traffic, traffic_time

        spec = hw.get("tpu_v5e")
        t = Traffic(AccessClass.GATHER, 1 << 20, row_bytes=256.0)
        assert traffic_time(t, spec) == traffic_time(t, spec.tpu_params())


class TestSweepHardwareAxis:
    def test_hardware_axis_overrides_and_reports(self):
        specs = [hw.get("stratix10_ddr4_1866"), hw.get("tpu_v5e")]
        res = Session().sweep(Space.grid(
            lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK],
            n_ga=[1, 2], n_elems=[1 << 14], hardware=specs))
        assert res.n_points == 8
        rows = res.rows()
        assert {r["hardware"] for r in rows} == set(s.name for s in specs)
        # the effective dram column reflects the spec, not the default axis
        assert {r["dram"] for r in rows} == {"DDR4-1866", "HBM-v5e"}

    def test_hardware_axis_backend_equivalence(self):
        sp = Space.grid(
            lsu_type=ALL_TYPES, n_ga=[1, 2], simd=[1, 4],
            n_elems=[1 << 14],
            hardware=[hw.get(n) for n in PRESETS])
        ref = Session(backend="numpy-batch").sweep(sp)
        got = Session(backend="scalar").sweep(sp)
        assert ref.n_points == got.n_points == 4 * 2 * 2 * 4
        np.testing.assert_array_equal(got.t_exe, ref.t_exe)
        np.testing.assert_array_equal(np.asarray(got.memory_bound),
                                      np.asarray(ref.memory_bound))

    def test_hardware_axis_applies_host_factor(self):
        base = hw.get("stratix10_ddr4_1866")
        res = Session().sweep(Space.grid(
            n_ga=[1, 2], n_elems=[1 << 14],
            hardware=[base, base.with_host_factor(2.0).with_name("x2")]))
        t = np.asarray(res.t_exe).reshape(2, 2)     # [n_ga, hardware]
        np.testing.assert_allclose(t[:, 1], 2.0 * t[:, 0], rtol=1e-12)

    def test_session_calibration_not_applied_to_overridden_points(self):
        """A calibrated session must not re-scale points whose hardware-axis
        spec fully overrides the session hardware (double scaling)."""
        spec = hw.get("stratix10_ddr4_2666")
        sp = Space.grid(n_ga=[1, 2], n_elems=[1 << 14], hardware=[spec])
        plain = Session().sweep(sp)
        calibrated = dataclasses.replace(
            Session(), calibration_factor=2.0).sweep(sp)
        np.testing.assert_array_equal(calibrated.t_exe, plain.t_exe)
        # ...while points on the session's own hardware still scale
        own = Space.grid(n_ga=[1, 2], n_elems=[1 << 14])
        a = Session().sweep(own)
        b = dataclasses.replace(Session(), calibration_factor=2.0).sweep(own)
        np.testing.assert_allclose(b.t_exe, 2.0 * np.asarray(a.t_exe),
                                   rtol=1e-12)

    def test_random_space_accepts_hardware(self):
        res = Session().sweep(Space.random(
            32, seed=5, n_ga=(1, 4), n_elems=(1 << 12, 1 << 14),
            hardware=[hw.get(n) for n in PRESETS]))
        assert res.n_points == 32
        assert np.all(np.asarray(res.t_exe) > 0)

    def test_vectorized_apply_hardware_axis_matches_reference_loop(self):
        """The factorize + table-gather rewrite of `_apply_hardware_axis`
        keeps the old per-point loop's semantics exactly: same view per
        unique spec (dedup), same host-factor scale, None rows untouched."""
        from repro.core.sweep import _apply_hardware_axis

        specs = [None, hw.get("stratix10_ddr4_1866"),
                 hw.get("tpu_v5e").with_host_factor(1.7)]
        rng = np.random.default_rng(13)
        n = 64
        col = np.empty(n, dtype=object)
        col[:] = [specs[i] for i in rng.integers(0, len(specs), n)]
        base_d, base_b = (hw.get("stratix10_ddr4_2666").dram_params(),
                          hw.get("stratix10_ddr4_2666").bsp_params())
        dram = np.empty(n, dtype=object)
        dram[:] = [base_d] * n
        bsp = np.empty(n, dtype=object)
        bsp[:] = [base_b] * n
        points = {"hardware": col, "dram": dram, "bsp": bsp}

        got_points, got_scale = _apply_hardware_axis(dict(points), n)

        # reference: the pre-vectorization per-point loop
        views = {}
        ref_d, ref_b, ref_s = dram.copy(), bsp.copy(), np.ones(n)
        for i, h in enumerate(col):
            if h is None:
                continue
            v = views.get(id(h))
            if v is None:
                v = views[id(h)] = (h.dram_params(), h.bsp_params(),
                                    float(h.host_factor))
            ref_d[i], ref_b[i], ref_s[i] = v
        np.testing.assert_array_equal(got_scale, ref_s)
        assert all(d == r for d, r in zip(got_points["dram"], ref_d))
        assert all(b == r for b, r in zip(got_points["bsp"], ref_b))
        # dedup contract: one view object per unique spec
        ids = {id(d) for d, h in zip(got_points["dram"], col)
               if h is not None}
        assert len(ids) == len({id(h) for h in col if h is not None})

    def test_all_none_hardware_axis_is_identity(self):
        from repro.core.sweep import _apply_hardware_axis

        n = 8
        col = np.empty(n, dtype=object)
        pts = {"hardware": col}        # dram/bsp untouched when all None
        out, scale = _apply_hardware_axis(pts, n)
        assert out is pts and np.all(scale == 1.0)


class TestCacheKey:
    def test_candidate_key_includes_hardware(self):
        """Satellite regression: a calibrated or swapped memory system must
        change the on-disk analysis/ranking cache key."""
        pytest.importorskip("jax")
        from repro.core import autotune as AT

        @dataclasses.dataclass
        class Cfg:
            a: int = 1

        @dataclasses.dataclass
        class Shape:
            kind: str = "train"

        cand = AT.Candidate("c", {}, {})
        k_default = AT.candidate_key(Cfg(), Shape(), None, cand)
        k_v5e = AT.candidate_key(Cfg(), Shape(), None, cand, hw.get("tpu_v5e"))
        k_v4 = AT.candidate_key(Cfg(), Shape(), None, cand, hw.get("tpu_v4"))
        k_cal = AT.candidate_key(Cfg(), Shape(), None, cand,
                                 hw.get("tpu_v5e").with_host_factor(1.5))
        assert k_default == k_v5e          # None resolves to the default chip
        assert len({k_v5e, k_v4, k_cal}) == 3
        # legacy TpuParams objects key too
        k_tpu = AT.candidate_key(Cfg(), Shape(), None, cand,
                                 hw.get("tpu_v4").tpu_params())
        assert k_tpu != k_v5e


class TestPytree:
    def test_spec_is_a_pytree(self):
        jax = pytest.importorskip("jax")
        assert hw.enable_jax()
        spec = hw.get("tpu_v5e")
        leaves, treedef = jax.tree_util.tree_flatten(spec)
        assert all(isinstance(x, (int, float)) for x in leaves)
        assert jax.tree_util.tree_unflatten(treedef, leaves) == spec

    def test_spec_threads_through_jit(self):
        jax = pytest.importorskip("jax")
        hw.enable_jax()
        spec = hw.get("tpu_v4")

        @jax.jit
        def stream_time(h, nbytes):
            return nbytes / (h.mem.peak_bw * h.mem.k_stream) * h.host_factor

        got = float(stream_time(spec, 1e9))
        assert got == pytest.approx(1e9 / (1228e9 * 0.92), rel=1e-6)
