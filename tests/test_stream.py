"""Streaming sweep engine: bit-equality with the materialized path across
all three backends, chunk-size/order invariance of the folded Pareto front,
reducer semantics, and multi-device chunk sharding."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import Session, Space
from repro.core import DDR4_1866, DDR4_2666, LsuType
from repro.core.stream import (GridEnumerator, ParetoReducer, StatsReducer,
                               TopKReducer, run_stream)
from repro.core.sweep import _grid_points, pareto_front

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]

#: Shared grid of the acceptance criterion: 4*3*3*2*3*2*2 = 864 points.
GRID = dict(
    lsu_type=ALL_TYPES,
    n_ga=[1, 2, 4],
    simd=[1, 4, 16],
    n_elems=[1 << 14, 1 << 16],
    delta=[1, 2, 7],
    include_write=[False, True],
    dram=[DDR4_1866, DDR4_2666],
)


@pytest.fixture(scope="module")
def materialized():
    return Session().sweep(Space.grid(**GRID))


def _assert_stream_matches(st, mat):
    """Front ids, top-k rows, summary and survivor estimates all bit-equal."""
    assert st.is_streaming and st.n_points == mat.n_points
    front_mat = np.asarray(mat.pareto())
    front_st = np.asarray(st.point_ids)[st.pareto()]
    np.testing.assert_array_equal(np.sort(front_st), front_mat)
    assert st.top_k(10) == mat.top_k(10)
    sm = {k: v for k, v in mat.summary().items() if k != "backend"}
    ss = {k: v for k, v in st.summary().items() if k != "backend"}
    assert ss == sm                               # min/counts are exact
    sel = np.asarray(st.point_ids)
    np.testing.assert_array_equal(np.asarray(st.t_exe),
                                  np.asarray(mat.t_exe)[sel])
    np.testing.assert_array_equal(np.asarray(st.resource),
                                  np.asarray(mat.resource)[sel])
    np.testing.assert_array_equal(np.asarray(st.memory_bound),
                                  np.asarray(mat.memory_bound)[sel])
    assert st.rows(st.pareto()) == mat.rows(front_mat)


class TestStreamingEqualsMaterialized:
    def test_numpy_batch_nondividing_chunk(self, materialized):
        """chunk=100 does not divide 864: the padded tail must be masked."""
        st = Session().sweep(Space.grid(**GRID), chunk_size=100)
        _assert_stream_matches(st, materialized)

    def test_numpy_batch_threaded(self, materialized):
        """The thread-pool path folds in submission order — identical."""
        st = Session().sweep(Space.grid(**GRID), chunk_size=64, workers=3)
        _assert_stream_matches(st, materialized)

    def test_scalar_backend(self, materialized):
        st = Session(backend="scalar").sweep(Space.grid(**GRID),
                                             chunk_size=128)
        _assert_stream_matches(st, materialized)

    def test_jax_jit_backend(self, materialized):
        pytest.importorskip("jax")
        st = Session(backend="jax-jit").sweep(
            Space.grid(**GRID).stream(chunk_size=100))
        _assert_stream_matches(st, materialized)

    def test_stats_sums_agree(self, materialized):
        st = Session().sweep(Space.grid(**GRID), chunk_size=37)
        assert st.stats["t_exe_sum"] == pytest.approx(
            float(np.sum(materialized.t_exe)), rel=1e-9)
        assert st.stats["total_bytes_sum"] == pytest.approx(
            float(np.sum(np.asarray(materialized.estimate.total_bytes))),
            rel=1e-9)
        assert st.stats["t_exe_min_id"] == int(np.argmin(materialized.t_exe))

    @pytest.mark.parametrize("chunk", [37, 100, 864, 4096])
    def test_chunk_size_invariance(self, materialized, chunk):
        st = Session().sweep(Space.grid(**GRID), chunk_size=chunk)
        np.testing.assert_array_equal(
            np.asarray(st.point_ids)[st.pareto()],
            np.asarray(materialized.pareto()))
        assert st.top_k(5) == materialized.top_k(5)

    def test_hardware_axis_and_calibration(self):
        """Hardware-axis overrides + session calibration stream identically
        (the no-double-scaling rule of Session.sweep)."""
        import dataclasses

        import repro.hw as hw

        sp = Space.grid(
            lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK],
            n_ga=[1, 2], n_elems=[1 << 14],
            hardware=[None, hw.get("stratix10_ddr4_2666"),
                      hw.get("stratix10_ddr4_1866")
                      .with_host_factor(2.0).with_name("x2")])
        sess = dataclasses.replace(Session(), calibration_factor=1.5)
        mat = sess.sweep(sp)
        st = sess.sweep(sp, chunk_size=5)
        _assert_stream_matches(st, mat)
        assert {r["hardware"] for r in st.rows()} <= \
            {"", "stratix10_ddr4_2666", "x2"}


class TestGridEnumerator:
    def test_codes_match_materialized_grid(self):
        """Mixed-radix decode reproduces the materialized point order."""
        from repro.core.sweep import _normalize_axes

        points, n, cats = _grid_points(GRID)
        enum = GridEnumerator(_normalize_axes(GRID))
        assert enum.n == n
        codes = enum.codes(np.arange(n))
        for name, (table, idx) in cats.items():
            np.testing.assert_array_equal(codes[name], idx)
        rng = np.random.default_rng(0)
        some = rng.integers(0, n, size=50)
        sub = enum.codes(some)
        for name, (table, idx) in cats.items():
            np.testing.assert_array_equal(sub[name], idx[some])

    def test_empty_axis_yields_empty_grid(self):
        """An empty axis makes the grid empty, not invalid: n == 0 and
        codes of an empty id batch decode to empty columns."""
        enum = GridEnumerator({"a": [1, 2], "b": []})
        assert enum.n == 0
        codes = enum.codes(np.empty(0, dtype=np.int64))
        assert set(codes) == {"a", "b"}
        assert all(len(v) == 0 for v in codes.values())


def _synthetic_cols(n, seed=0):
    rng = np.random.default_rng(seed)
    vals = rng.random((n, 2))
    dup = rng.integers(0, n, n // 10)
    vals[dup] = vals[rng.integers(0, n, n // 10)]       # duplicated rows
    return {"id": np.arange(n, dtype=np.int64),
            "t_exe": vals[:, 0], "resource": vals[:, 1]}


def _fold_pareto(cols, bounds, order):
    """Fold ``cols`` chunked at ``bounds``, visiting chunks in ``order``."""
    red = ParetoReducer()
    chunks = np.split(np.arange(len(cols["id"])), bounds)
    for ci in order:
        idx = chunks[ci]
        if len(idx):
            red.update({k: v[idx] for k, v in cols.items()})
    return red.ids


class TestFoldInvariance:
    def test_chunk_partition_and_order_seeded(self):
        """Deterministic version of the property: the folded front equals
        the whole-space front under arbitrary partitions and fold orders."""
        cols = _synthetic_cols(600)
        ref = np.asarray(pareto_front(
            np.stack([cols["t_exe"], cols["resource"]], 1)))
        rng = np.random.default_rng(42)
        for trial in range(10):
            n_cuts = int(rng.integers(0, 12))
            bounds = np.sort(rng.integers(0, 600, n_cuts))
            order = rng.permutation(n_cuts + 1)
            got = _fold_pareto(cols, bounds, order)
            np.testing.assert_array_equal(got, ref), trial

    def test_hypothesis_property(self):
        hypothesis = pytest.importorskip(
            "hypothesis", reason="hypothesis not installed")
        import hypothesis.strategies as st

        cols = _synthetic_cols(300, seed=3)
        ref = np.asarray(pareto_front(
            np.stack([cols["t_exe"], cols["resource"]], 1)))

        @hypothesis.settings(max_examples=30, deadline=None)
        @hypothesis.given(
            cuts=st.lists(st.integers(0, 299), max_size=10),
            seed=st.integers(0, 2**31 - 1))
        def prop(cuts, seed):
            bounds = np.sort(np.asarray(cuts, dtype=np.int64))
            order = np.random.default_rng(seed).permutation(len(bounds) + 1)
            np.testing.assert_array_equal(
                _fold_pareto(cols, bounds, order), ref)

        prop()


class TestReducers:
    def test_topk_matches_stable_argsort(self):
        cols = _synthetic_cols(500, seed=1)
        cols["t_exe"] = np.round(cols["t_exe"], 2)      # force value ties
        red = TopKReducer(k=25, key="t_exe")
        for idx in np.split(np.arange(500), [123, 307, 499]):
            red.update({k: v[idx] for k, v in cols.items()})
        ref = np.argsort(cols["t_exe"], kind="stable")[:25]
        np.testing.assert_array_equal(red.ids, ref)

    def test_topk_fewer_points_than_k(self):
        cols = _synthetic_cols(5)
        red = TopKReducer(k=10)
        red.update(cols)
        assert len(red.ids) == 5

    def test_stats_exact(self):
        cols = _synthetic_cols(400, seed=2)
        cols["memory_bound"] = cols["t_exe"] > 0.5
        cols["total_bytes"] = cols["resource"] * 100
        red = StatsReducer()
        for idx in np.split(np.arange(400), [97, 250]):
            red.update({k: v[idx] for k, v in cols.items()})
        s = red.summary()
        assert s["n_points"] == 400
        assert s["memory_bound_points"] == int(cols["memory_bound"].sum())
        assert s["t_exe_min"] == float(cols["t_exe"].min())
        assert s["t_exe_min_id"] == int(np.argmin(cols["t_exe"]))

    def test_run_stream_pads_and_masks(self):
        seen = []

        def eval_chunk(ids):
            seen.append(ids.copy())
            assert len(ids) == 7                    # fixed shape, always
            return {"id": ids, "t_exe": ids.astype(np.float64),
                    "resource": np.ones(len(ids)),
                    "memory_bound": np.zeros(len(ids), bool),
                    "total_bytes": np.ones(len(ids))}

        stats = StatsReducer()
        out = run_stream(17, 7, eval_chunk, [stats])
        assert out.n_chunks == 3 and stats.n_points == 17
        assert stats.t_exe_sum == float(np.arange(17).sum())  # pad masked
        assert all(len(s) == 7 for s in seen)

    def test_reducer_list_reuse_does_not_contaminate(self):
        """Session.sweep folds into copies, so passing the same reducer
        instances to two sweeps keeps the reports independent."""
        reds = [ParetoReducer(), TopKReducer(3), StatsReducer()]
        r1 = Session().sweep(Space.grid(n_ga=[1, 2], n_elems=[1 << 14]),
                             reducers=reds)
        r2 = Session().sweep(Space.grid(n_ga=[4, 8], n_elems=[1 << 14]),
                             reducers=reds)
        assert r1.stats["n_points"] == 2 and r2.stats["n_points"] == 2
        assert {row["n_ga"] for row in r2.top_k(2)} == {4, 8}
        assert {row["n_ga"] for row in r1.top_k(2)} == {1, 2}
        # the caller's instances are untouched
        assert reds[1].cols is None and reds[2].n_points == 0

    def test_validation(self):
        with pytest.raises(ValueError):
            TopKReducer(k=0)
        with pytest.raises(ValueError):
            ParetoReducer(objectives=())
        with pytest.raises(ValueError):
            run_stream(4, 0, lambda ids: {}, [])


class TestMultiDevice:
    def test_sharded_chunks_match_single_device(self):
        """4 forced host devices: the sharded jax-jit streaming sweep folds
        to the same front/top-k as the numpy materialized path."""
        pytest.importorskip("jax")
        code = textwrap.dedent("""
            import json
            import numpy as np
            from repro import Session, Space, compat
            from repro.core import LsuType

            assert compat.local_device_count() == 4
            sp = Space.grid(
                lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_WRITE_ACK,
                          LsuType.ATOMIC_PIPELINED],
                n_ga=[1, 2, 4], simd=[1, 4, 16], n_elems=[1 << 14],
                delta=[1, 7])
            mat = Session().sweep(sp)
            st = Session(backend="jax-jit").sweep(sp, chunk_size=50)
            front_mat = np.asarray(mat.pareto()).tolist()
            front_st = np.sort(
                np.asarray(st.point_ids)[st.pareto()]).tolist()
            print(json.dumps({
                "front_mat": front_mat, "front_st": front_st,
                "topk_equal": st.top_k(5) == mat.top_k(5),
                "summary_equal": st.summary()["t_exe_min_ms"]
                    == mat.summary()["t_exe_min_ms"],
            }))
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=600,
                             env=env)
        assert out.returncode == 0, out.stderr[-3000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        assert res["front_st"] == res["front_mat"]
        assert res["topk_equal"] and res["summary_equal"]
