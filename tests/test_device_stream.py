"""Device-resident fold (repro.core.device_stream): bit-equality with the
host merge/state_dict protocol under arbitrary chunk partitions across all
three backends, capacity-overflow fallback, per-stage profile attribution,
and the persistent compilation cache."""
import math
import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import Session, Space
from repro.core import DDR4_1866, DDR4_2666, LsuType
from repro.core import device_stream as dev
from repro.core.stream import (ParetoReducer, StatsReducer, TopKReducer,
                               default_reducers, make_range_folder)

ALL_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
             LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]

#: Same 864-point grid as tests/test_stream.py (the acceptance grid).
GRID = dict(
    lsu_type=ALL_TYPES,
    n_ga=[1, 2, 4],
    simd=[1, 4, 16],
    n_elems=[1 << 14, 1 << 16],
    delta=[1, 2, 7],
    include_write=[False, True],
    dram=[DDR4_1866, DDR4_2666],
)
N = 864

multi_device = pytest.mark.skipif(
    jax.local_device_count() > 1,
    reason="device fold defers to host chunk sharding on multi-device")


def _plan(backend: str, chunk: int):
    return Session(backend=backend).plan(Space.grid(**GRID),
                                         chunk_size=chunk)


def _canon(reducers) -> list:
    """state_dicts normalized to the representation-invariant form.

    Shewchuk partial *lists* are not canonical — ``merge`` re-runs two-sum
    over them and may compact ``[a, b, c, T]`` into ``[a+b+c, T]`` while
    preserving the exact total — so the sums compare through ``math.fsum``
    (exact for non-overlapping partials).  The Pareto front's held order is
    ascending-id on the device path and front-algorithm order on the host,
    so front rows are sorted by id.  Everything else must match exactly.
    """
    out = []
    for r in reducers:
        st = r.state_dict()
        if isinstance(r, StatsReducer):
            st = dict(st, t_exe_sum=math.fsum(st["t_exe_sum"]),
                      total_bytes_sum=math.fsum(st["total_bytes_sum"]))
        elif isinstance(r, ParetoReducer) and st["cols"] is not None:
            order = np.argsort(np.asarray(st["cols"]["id"][1]))
            st = dict(st, cols={c: [d, [v[i] for i in order]]
                                for c, (d, v) in st["cols"].items()})
        out.append(st)
    return out


def _protocol_fold(backend: str, chunk: int, bounds: list[int]) -> list:
    """Fold each ``bounds`` range into fresh reducers, merge the states.

    This is exactly the distributed coordinator/worker protocol
    (repro.core.distributed): per-range states travel as ``state_dict()``
    and merge in range order, so every backend sees the identical merge
    tree and the results must agree bit-for-bit.
    """
    fold = make_range_folder(_plan(backend, chunk))
    base = default_reducers(10)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        fresh = tuple(r.fresh() for r in base)
        fold(lo, hi, fresh)
        for b, r in zip(base, fresh):
            b.merge(type(b).from_state(r.state_dict()))
    return _canon(base)


@pytest.fixture(scope="module")
def materialized():
    return Session().sweep(Space.grid(**GRID))


class TestDeviceFoldBitEquality:
    @multi_device
    @pytest.mark.parametrize("chunk", [37, 100, 864, 4096])
    def test_whole_grid_matches_host_fold(self, chunk):
        """Device fold of [0, n) == host fold, any chunk size (incl. a
        non-dividing chunk with a masked padded tail and one > n)."""
        plan = _plan("jax-jit", chunk)
        drv = dev.DeviceSweep.build(plan)
        assert drv is not None
        device = default_reducers(10)
        assert drv.supports(device)
        drv.fold_range(0, N, device)

        host = default_reducers(10)
        hplan = _plan("numpy-batch", chunk)
        hplan.run_range(0, N, host, eval_chunk=hplan.evaluator())
        assert _canon(device) == _canon(host)

    @multi_device
    def test_session_sweep_takes_device_path(self, materialized):
        """The standard jax-jit streaming sweep actually runs device-fused
        and still bit-matches the materialized report."""
        st = Session(backend="jax-jit").sweep(Space.grid(**GRID),
                                              chunk_size=100, profile=True)
        assert st.summary()["profile"]["path"] == "device-fused"
        np.testing.assert_array_equal(
            np.sort(np.asarray(st.point_ids)[st.pareto()]),
            np.asarray(materialized.pareto()))
        assert st.top_k(10) == materialized.top_k(10)
        assert st.stats["t_exe_min"] == float(np.min(materialized.t_exe))
        assert st.stats["t_exe_min_id"] == int(np.argmin(materialized.t_exe))


def _check_partition(bounds: list[int]) -> None:
    ref = _protocol_fold("numpy-batch", 100, bounds)
    for backend in ("jax-jit", "scalar"):
        assert _protocol_fold(backend, 100, bounds) == ref, \
            f"{backend} diverged from numpy-batch on partition {bounds}"


try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class TestPartitionProperty:
    """Device folds == host folds through the merge/state_dict protocol
    under *arbitrary* chunk-aligned partitions of [0, n)."""

    if HAVE_HYPOTHESIS:
        @multi_device
        @settings(max_examples=8, deadline=None)
        @given(cuts=hyp_st.sets(
            hyp_st.sampled_from(list(range(100, N, 100))), max_size=8))
        def test_random_partitions(self, cuts):
            _check_partition([0, *sorted(cuts), N])
    else:
        @multi_device
        @pytest.mark.parametrize("seed", range(4))
        def test_random_partitions(self, seed):
            rng = random.Random(seed)
            interior = list(range(100, N, 100))
            cuts = sorted(rng.sample(interior,
                                     rng.randint(0, len(interior))))
            _check_partition([0, *cuts, N])

    @multi_device
    def test_degenerate_partitions(self):
        _check_partition([0, N])                    # single range
        _check_partition([0, *range(100, N, 100), N])   # every chunk alone


class TestOverflowFallback:
    @multi_device
    def test_fold_range_raises_and_leaves_reducers_untouched(
            self, monkeypatch):
        monkeypatch.setattr(dev, "FRONT_CAP", 2)
        drv = dev.DeviceSweep.build(_plan("jax-jit", 100))
        assert drv is not None and drv.front_cap == 2
        reducers = default_reducers(10)
        before = [r.state_dict() for r in reducers]
        with pytest.raises(dev.DeviceFoldOverflow):
            drv.fold_range(0, N, reducers)
        assert [r.state_dict() for r in reducers] == before

    @multi_device
    def test_session_sweep_falls_back_to_host(self, monkeypatch,
                                              materialized):
        assert len(materialized.pareto()) > 2   # cap 2 must overflow
        monkeypatch.setattr(dev, "FRONT_CAP", 2)
        st = Session(backend="jax-jit").sweep(Space.grid(**GRID),
                                              chunk_size=100, profile=True)
        assert st.summary()["profile"]["path"] == "host-stream"
        np.testing.assert_array_equal(
            np.sort(np.asarray(st.point_ids)[st.pareto()]),
            np.asarray(materialized.pareto()))
        assert st.top_k(10) == materialized.top_k(10)


class TestEligibility:
    def test_non_jax_backend_is_ineligible(self):
        assert dev.DeviceSweep.build(_plan("numpy-batch", 100)) is None

    @multi_device
    def test_constrained_plan_is_ineligible(self):
        plan = Session(backend="jax-jit").plan(
            Space.grid(**GRID), chunk_size=100,
            constraints=(lambda cols: np.asarray(cols["n_ga"]) > 1,))
        assert dev.DeviceSweep.build(plan) is None

    @multi_device
    def test_custom_reducer_is_unsupported(self):
        class Spy(StatsReducer):
            pass

        drv = dev.DeviceSweep.build(_plan("jax-jit", 100))
        assert drv is not None
        assert drv.supports(default_reducers(10))
        assert not drv.supports((Spy(),))
        assert not drv.supports((TopKReducer(3, key="no_such_column"),))


class TestProfileAndCache:
    def test_host_stream_profile_stages(self):
        st = Session().sweep(Space.grid(**GRID), chunk_size=100,
                             profile=True)
        prof = st.summary()["profile"]
        assert prof["path"] == "host-stream"
        for key in ("enumerate_s", "score_s", "reduce_s", "total_s"):
            assert prof[key] >= 0.0

    @multi_device
    def test_device_profile_stages(self):
        st = Session(backend="jax-jit").sweep(Space.grid(**GRID),
                                              chunk_size=100, profile=True)
        prof = st.summary()["profile"]
        assert prof["path"] == "device-fused"
        for key in ("compile_s", "score_s", "transfer_s", "enumerate_s",
                    "reduce_s", "total_s"):
            assert prof[key] >= 0.0

    def test_compilation_cache_enable_is_idempotent(self):
        from repro import compat

        first = compat.enable_compilation_cache()
        assert compat.enable_compilation_cache() == first
        if first:       # directory really configured, never raises
            assert jax.config.jax_compilation_cache_dir
