"""Multi-device SPMD tests — run in a subprocess with 8 forced host devices
(the main test process must keep 1 device; the dry-run's 512-device trick is
exactly the same mechanism at production scale)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stderr[-3000:]}")
    for line in reversed(out.stdout.strip().splitlines()):
        if line.startswith("{"):
            return json.loads(line)
    raise AssertionError(f"no JSON result in output:\n{out.stdout[-2000:]}")


PREAMBLE = textwrap.dedent("""
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.compat import make_mesh
    from repro.configs import ARCHS, reduced_config
    from repro.configs.shapes import ShapeSpec
    from repro.launch.steps import build_step, TrainConfig
    mesh = make_mesh((4, 2), ("data", "model"))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-7b", "qwen3-moe-235b-a22b",
                                  "recurrentgemma-9b", "xlstm-1.3b"])
def test_train_step_runs_sharded(arch):
    """Compile AND execute one real train step on a 4x2 mesh; loss finite
    and parameters actually sharded."""
    code = PREAMBLE + textwrap.dedent(f"""
        from repro.models import transformer as TF
        from repro.optim import adamw_init
        cfg = reduced_config(ARCHS[{arch!r}])
        shape = ShapeSpec("t", 64, 8, "train")
        built = build_step(cfg, shape, mesh, TrainConfig())
        params = jax.jit(lambda: TF.init_params(jax.random.PRNGKey(0), cfg),
                         out_shardings=built.in_shardings[0])()
        opt = jax.jit(lambda: adamw_init(params, TrainConfig().optimizer),
                      out_shardings=built.in_shardings[1])()
        toks = jnp.zeros((8, 64), jnp.int32)
        batch = dict(tokens=toks, labels=toks)
        if cfg.frontend == "audio":
            batch = dict(features=jnp.zeros((8, 64, cfg.frontend_dim), jnp.bfloat16),
                         labels=toks, mask=jnp.ones((8, 64), jnp.float32))
        elif cfg.frontend == "vision":
            from repro.configs.shapes import vision_patches
            p = vision_patches(64)
            batch = dict(features=jnp.zeros((8, p, cfg.frontend_dim), jnp.bfloat16),
                         tokens=toks[:, :64-p], labels=toks[:, :64-p])
        params, opt, metrics = built.fn(params, opt, batch)
        n_shards = max(len(x.sharding.device_set)
                       for x in jax.tree.leaves(params))
        print(json.dumps(dict(loss=float(metrics["loss"]),
                              n_shards=n_shards)))
    """)
    res = run_sub(code)
    assert res["loss"] == res["loss"] and res["loss"] < 20  # finite, sane
    assert res["n_shards"] > 1


@pytest.mark.slow
def test_decode_step_runs_sharded():
    code = PREAMBLE + textwrap.dedent("""
        from repro.models import transformer as TF
        cfg = reduced_config(ARCHS["command-r-35b"])
        shape = ShapeSpec("d", 64, 8, "decode")
        built = build_step(cfg, shape, mesh, TrainConfig())
        params = jax.jit(lambda: TF.init_params(jax.random.PRNGKey(0), cfg),
                         out_shardings=built.in_shardings[0])()
        caches = jax.jit(lambda: TF.init_caches(cfg, 8, 64),
                         out_shardings=built.in_shardings[2])()
        tok = jnp.zeros((8, 1), jnp.int32)
        nxt, logits, caches = built.fn(params, tok, caches,
                                       jnp.asarray(3, jnp.int32))
        print(json.dumps(dict(ok=bool(jnp.isfinite(logits).all()),
                              shape=list(nxt.shape))))
    """)
    res = run_sub(code)
    assert res["ok"] and res["shape"] == [8, 1]


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Checkpoint written while sharded on a 4x2 mesh restores correctly
    onto a 2x4 mesh (elastic rescale contract)."""
    code = PREAMBLE + textwrap.dedent("""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        tree = {"w": jnp.arange(64.0).reshape(8, 8),
                "b": jnp.arange(8.0)}
        sh1 = NamedSharding(mesh, P("data", "model"))
        tree_s = jax.device_put(tree["w"], sh1)
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": tree_s, "b": tree["b"]})
        # new mesh with swapped factors
        mesh2 = make_mesh((2, 4), ("data", "model"))
        sh2 = {"w": NamedSharding(mesh2, P("model", "data")),
               "b": NamedSharding(mesh2, P(None))}
        restored, step = mgr.restore({"w": tree["w"], "b": tree["b"]},
                                     shardings=sh2)
        ok = bool((np.asarray(restored["w"]) ==
                   np.asarray(tree["w"])).all())
        print(json.dumps(dict(ok=ok, step=step,
                              nshards=len(restored["w"].sharding.device_set))))
    """)
    res = run_sub(code)
    assert res["ok"] and res["step"] == 1 and res["nshards"] == 8


@pytest.mark.slow
def test_grad_compression_changes_wire_dtype():
    """bf16 gradient compression shows up in the compiled HLO (collective or
    conversion on bf16 grads) and trains to a finite loss."""
    code = PREAMBLE + textwrap.dedent("""
        from repro.models import transformer as TF
        from repro.optim import adamw_init
        cfg = reduced_config(ARCHS["stablelm-3b"])
        shape = ShapeSpec("t", 32, 8, "train")
        built = build_step(cfg, shape, mesh, TrainConfig(grad_compression="bf16"))
        params = jax.jit(lambda: TF.init_params(jax.random.PRNGKey(0), cfg),
                         out_shardings=built.in_shardings[0])()
        opt = jax.jit(lambda: adamw_init(params, TrainConfig().optimizer),
                      out_shardings=built.in_shardings[1])()
        toks = jnp.zeros((8, 32), jnp.int32)
        params, opt, metrics = built.fn(params, opt,
                                        dict(tokens=toks, labels=toks))
        print(json.dumps(dict(loss=float(metrics["loss"]))))
    """)
    res = run_sub(code)
    assert res["loss"] < 20
