"""Property-based invariants of the access-class transaction model
(`hbm.traffic_time`) for **every registered Hardware spec**: time is
monotonically non-decreasing in the byte count, and no DRAM-touching class
is ever predicted faster than a pure stream of the same size."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')")
import hypothesis.strategies as st  # noqa: E402

from repro import hw  # noqa: E402
from repro.core.hbm import AccessClass, Traffic, traffic_time  # noqa: E402

#: Classes that reach the memory controller (VMEM is on-chip by definition).
DRAM_CLASSES = [AccessClass.STREAM, AccessClass.STRIDED,
                AccessClass.GATHER, AccessClass.SERIALIZED]

settings = hypothesis.settings(max_examples=50, deadline=None)


@settings
@hypothesis.given(
    cls=st.sampled_from(DRAM_CLASSES),
    log_n=st.integers(6, 26),
    extra=st.integers(0, 1 << 22),
    row=st.sampled_from([1.0, 64.0, 512.0, 1024.0, 4096.0, 1 << 20]),
)
def test_traffic_time_monotone_in_nbytes(cls, log_n, extra, row):
    for name in hw.names():
        spec = hw.get(name)
        nb = float(1 << log_n)
        t_small = sum(traffic_time(Traffic(cls, nb, row_bytes=row), spec))
        t_large = sum(traffic_time(Traffic(cls, nb + extra, row_bytes=row),
                                   spec))
        assert t_large >= t_small, (name, cls)


@settings
@hypothesis.given(
    cls=st.sampled_from(DRAM_CLASSES),
    log_n=st.integers(6, 26),
    row=st.sampled_from([1.0, 64.0, 512.0, 1024.0, 4096.0, 1 << 20]),
)
def test_traffic_time_never_below_stream_bound(cls, log_n, row):
    """A pure stream is the fastest way to move N bytes; strided, gathered
    and serialized traffic of the same size can only be slower."""
    for name in hw.names():
        spec = hw.get(name)
        nb = float(1 << log_n)
        t_cls = sum(traffic_time(Traffic(cls, nb, row_bytes=row), spec))
        t_stream = sum(traffic_time(
            Traffic(AccessClass.STREAM, nb, row_bytes=row), spec))
        assert t_cls >= t_stream * (1.0 - 1e-12), (name, cls)
