"""Faithful-model tests: Eqs. 1-10 against hand-computed values and the
paper's own published numbers (Tables III-V, Figs. 3-5 trends)."""
import math

import pytest

from repro.core import DDR4_1866, DDR4_2666, Lsu, LsuType, STRATIX10_BSP
from repro.core.model import _estimate as estimate   # the scalar reference
from repro.core.apps import APPS, microbench, table4_rows
from repro.core.baselines import hlscope_estimate, wang_estimate
from repro.core import model as M


def _aligned(n_elems=1 << 20, simd=16, delta=1, write=False):
    w = simd * 4
    return Lsu(LsuType.BC_ALIGNED, ls_width=w, ls_acc=n_elems // simd,
               ls_bytes=w, delta=delta, is_write=write)


class TestEquations:
    def test_eq2_t_ideal_is_bytes_over_bw(self):
        lsu = _aligned(n_elems=1 << 20)
        est = estimate([lsu], DDR4_1866)
        expected = (1 << 20) * 4 / DDR4_1866.bw_mem
        assert est.t_ideal == pytest.approx(expected, rel=1e-12)

    def test_eq4_single_lsu_has_no_overhead(self):
        est = estimate([_aligned()], DDR4_1866)
        assert est.t_ovh == 0.0

    def test_eq4_overhead_one_trow_per_burst(self):
        lsus = [_aligned(), _aligned(write=True)]
        est = estimate(lsus, DDR4_1866)
        burst = STRATIX10_BSP.max_transaction_bytes(DDR4_1866)  # 1024 B
        n_bursts = (1 << 20) * 4 / burst
        assert burst == 1024
        assert est.t_ovh == pytest.approx(
            2 * n_bursts * DDR4_1866.t_row, rel=1e-12)

    def test_eq5_burst_size(self):
        assert STRATIX10_BSP.max_transaction_bytes(DDR4_1866) == \
            2 ** STRATIX10_BSP.burst_cnt * DDR4_1866.dq * DDR4_1866.bl

    def test_eq6_eq9_eq10_t_row(self):
        d = DDR4_1866
        assert M.t_row_seconds(_aligned(), d) == d.t_rcd + d.t_rp
        ack = Lsu(LsuType.BC_WRITE_ACK, ls_width=4, ls_acc=10, ls_bytes=4,
                  is_write=True)
        assert M.t_row_seconds(ack, d) == d.t_rcd + d.t_rp + d.t_wr
        atom = Lsu(LsuType.ATOMIC_PIPELINED, ls_width=4, ls_acc=10,
                   ls_bytes=4, is_write=True)
        assert M.t_row_seconds(atom, d) == 2 * (d.t_rcd + d.t_rp) + d.t_wr

    def test_eq3_memory_bound_criterion(self):
        # SIMD=16 int: ls_width = 64 = dq*bl -> each LSU contributes 1.0
        est = estimate([_aligned(simd=16)], DDR4_1866)
        assert est.memory_bound and est.bound_ratio == pytest.approx(1.0)
        # SIMD=1: 4/64 per LSU -> compute bound until 16 LSUs
        est1 = estimate([_aligned(simd=1)], DDR4_1866)
        assert not est1.memory_bound
        est16 = estimate([_aligned(simd=1) for _ in range(16)], DDR4_1866)
        assert est16.memory_bound

    def test_eq7_eq8_max_th_knee_at_delta7(self):
        """Fig. 5b: with SIMD=16 int accesses, the max_th trigger takes over
        exactly at stride 7 for the Stratix-10 BSP parameters."""
        def burst(delta):
            lsu = Lsu(LsuType.BC_NON_ALIGNED, ls_width=64, ls_acc=1024,
                      ls_bytes=64, delta=delta)
            return M.burst_size_bytes(lsu, DDR4_1866, STRATIX10_BSP)

        assert burst(6) == pytest.approx(64 / 6)      # page trigger branch
        assert burst(7) == pytest.approx(
            STRATIX10_BSP.max_th * 64 / 8 / 7)        # max_th branch
        assert burst(7) > burst(6)                    # the knee "optimizes"

    def test_eq10_atomic_constant_merges_by_f(self):
        atom = lambda const: Lsu(LsuType.ATOMIC_PIPELINED, ls_width=4,
                                 ls_acc=1000, ls_bytes=4, is_write=True,
                                 val_constant=const)
        t_var = estimate([atom(False)], DDR4_1866, f=16).t_ovh
        t_const = estimate([atom(True)], DDR4_1866, f=16).t_ovh
        assert t_var == pytest.approx(16 * t_const, rel=1e-9)


class TestPaperNumbers:
    def test_effective_bandwidth_drop(self):
        """SV-A1: DRAM bandwidth 14.2 -> 10.5 GB/s as #lsu grows (26% drop)."""
        one = estimate(microbench(LsuType.BC_ALIGNED, n_ga=1,
                                  include_write=False), DDR4_1866)
        many = estimate(microbench(LsuType.BC_ALIGNED, n_ga=4), DDR4_1866)
        assert one.effective_bandwidth == pytest.approx(14.93e9, rel=0.01)
        assert many.effective_bandwidth == pytest.approx(10.7e9, rel=0.03)
        drop = 1 - many.effective_bandwidth / one.effective_bandwidth
        assert 0.2 < drop < 0.33                      # paper: 26 %

    def test_fig5a_stride_linearity(self):
        """Fig. 5a: aligned time scales ~linearly with delta."""
        times = {}
        for d in (1, 2, 3, 4):
            lsus = microbench(LsuType.BC_ALIGNED, n_ga=2, delta=d)
            times[d] = estimate(lsus, DDR4_1866).t_exe
        for d in (2, 3, 4):
            assert times[d] / times[1] == pytest.approx(d, rel=1e-6)

    def test_table4_errors_below_paper_bound(self):
        """Table IV: all application errors <= 9.2% + the paper's own column
        is reproduced within ~2.5 points (inputs calibrated, error genuine)."""
        rows = table4_rows()
        assert len(rows) == 10
        for r in rows:
            assert r["err_pct"] <= 9.5, r
        mean_err = sum(r["err_pct"] for r in rows) / len(rows)
        assert mean_err <= 7.6 + 1.0                  # paper mean: 7.6 %

    def test_table4_held_out_stride_row(self):
        """VectorAdd delta=2 is predicted from the delta=1 calibration."""
        row = [r for r in table4_rows() if r["kernel"] == "vectoradd_d2"][0]
        assert row["err_pct"] < 9.2

    def test_ack_much_slower_than_aligned(self):
        """SV-A3: write-ACK is an order of magnitude worse than aligned
        (paper measures 24x)."""
        n = 1 << 18
        ali = estimate(microbench(LsuType.BC_ALIGNED, n_ga=1, n_elems=n),
                       DDR4_1866)
        ack = estimate(microbench(LsuType.BC_WRITE_ACK, n_ga=1, n_elems=n),
                       DDR4_1866)
        assert ack.t_exe > 5 * ali.t_exe

    def test_atomic_linear_in_ga(self):
        """Fig. 4d: atomic time grows linearly with #ga."""
        ts = [estimate(microbench(LsuType.ATOMIC_PIPELINED, n_ga=g,
                                  n_elems=1 << 16), DDR4_1866).t_exe
              for g in (1, 2, 3, 4)]
        for g in (2, 3, 4):
            assert ts[g - 1] / ts[0] == pytest.approx(g, rel=0.05)


class TestBaselineComparison:
    """Table V: this work vs Wang [6] and HLScope+ [7]."""

    def test_wang_ack_catastrophic(self):
        """Wang's 8049% / 11279% ACK signature: >= 10x overestimate."""
        lsus = microbench(LsuType.BC_WRITE_ACK, n_ga=1, n_elems=1 << 18)
        ours = estimate(lsus, DDR4_1866).t_exe
        wang = wang_estimate(lsus, DDR4_1866)
        assert wang > 10 * ours

    def test_baselines_do_not_track_dram_change(self):
        """Table V lower half: at DDR4-2666 our estimate scales with the
        faster DRAM; Wang's and HLScope+'s stay put."""
        lsus = microbench(LsuType.BC_ALIGNED, n_ga=1, include_write=False)
        ours_1866 = estimate(lsus, DDR4_1866).t_exe
        ours_2666 = estimate(lsus, DDR4_2666).t_exe
        assert ours_2666 < ours_1866 * 0.75
        assert wang_estimate(lsus, DDR4_2666) == wang_estimate(lsus, DDR4_1866)
        assert hlscope_estimate(lsus, DDR4_2666) == \
            hlscope_estimate(lsus, DDR4_1866)

    def test_at_least_2x_more_accurate(self):
        """Against the dramsim oracle, our max error across the Table V
        microbenchmarks is >= 2x smaller than either baseline's."""
        from repro.core.dramsim import simulate

        cases = [
            microbench(LsuType.BC_ALIGNED, n_ga=1, n_elems=1 << 18,
                       include_write=False),
            microbench(LsuType.BC_ALIGNED, n_ga=4, n_elems=1 << 18),
            microbench(LsuType.ATOMIC_PIPELINED, n_ga=2, n_elems=1 << 12),
        ]
        errs = {"ours": [], "wang": [], "hlscope": []}
        for dram in (DDR4_1866, DDR4_2666):
            for lsus in cases:
                t_meas = simulate(lsus, dram).t_total
                for name, t_est in [
                        ("ours", estimate(lsus, dram).t_exe),
                        ("wang", wang_estimate(lsus, dram)),
                        ("hlscope", hlscope_estimate(lsus, dram))]:
                    errs[name].append(abs(t_est - t_meas) / t_meas)
        assert max(errs["ours"]) * 2 <= max(errs["wang"])
        assert max(errs["ours"]) * 2 <= max(errs["hlscope"])
