"""Distributed sweep substrate: picklable SweepPlan round trips, mergeable
reducer invariance under arbitrary partitions/merge trees, the
coordinator/worker process pool (bit-equality, fault re-issue), the
backend × executor error matrix, and empty grids end-to-end."""
import pickle

import numpy as np
import pytest

import repro
from repro import Session, Space
from repro.core import DDR4_1866, DDR4_2666, LsuType
from repro.core import distributed as dist
from repro.core.stream import (ParetoReducer, StatsReducer, SweepPlan,
                               TopKReducer, default_reducers)

#: Small grid exercising categorical axes (lsu_type/dram) + a hardware axis
#: through every plan/distributed path: 2*3*2*2*2 = 48 points.
GRID = dict(
    lsu_type=[LsuType.BC_ALIGNED, LsuType.ATOMIC_PIPELINED],
    n_ga=[1, 2, 4],
    simd=[1, 16],
    n_elems=[1 << 12, 1 << 14],
    dram=[DDR4_1866, DDR4_2666],
)


@pytest.fixture(scope="module")
def plan():
    return Session().plan(Space.grid(**GRID), chunk_size=8)


@pytest.fixture(scope="module")
def serial(plan):
    """The single-pass serial fold every partitioned run must reproduce."""
    reducers = default_reducers()
    plan.run(reducers)
    return reducers


def _stats(reducers):
    return next(r for r in reducers if isinstance(r, StatsReducer))


def _assert_matches_serial(merged, serial):
    """front membership, top-k order incl. ties, stats (var to 1e-12)."""
    for got, ref in zip(merged, serial):
        if isinstance(got, ParetoReducer):
            np.testing.assert_array_equal(got.ids, ref.ids)
        elif isinstance(got, TopKReducer):
            np.testing.assert_array_equal(got.ids, ref.ids)
            np.testing.assert_array_equal(got.cols["t_exe"],
                                          ref.cols["t_exe"])
        elif isinstance(got, StatsReducer):
            g, r = got.summary(), ref.summary()
            for k in ("n_points", "memory_bound_points", "t_exe_min",
                      "t_exe_min_id", "t_exe_sum", "total_bytes_sum",
                      "t_exe_mean"):
                assert g[k] == r[k], k             # bit-equal by contract
            assert g["t_exe_var"] == pytest.approx(r["t_exe_var"],
                                                   rel=1e-12, abs=1e-24)


def _fold_partition(plan, bounds, reducers=None):
    """Fold each chunk-aligned range [bounds[i], bounds[i+1]) into its own
    fresh reducer set; returns the list of per-range reducer sets."""
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        rs = default_reducers() if reducers is None \
            else [r.fresh() for r in reducers]
        plan.run_range(int(lo), int(hi), rs)
        parts.append(rs)
    return parts


def _merge_tree(parts, order):
    """Merge the per-range reducer sets pairwise in ``order`` (a permutation
    of range indices) — an arbitrary left-deep merge tree."""
    base = [r.fresh() for r in parts[0]]
    for i in order:
        for b, p in zip(base, parts[i]):
            b.merge(type(b).from_state(p.state_dict()))
    return base


def _random_bounds(rng, n, n_chunks, chunk):
    cuts = np.sort(rng.choice(np.arange(1, n_chunks), size=min(
        int(rng.integers(0, 4)), n_chunks - 1), replace=False))
    return [0] + [int(c) * chunk for c in cuts] + [n]


class TestSweepPlan:
    def test_pickle_round_trip(self, plan):
        assert pickle.loads(pickle.dumps(plan)) == plan

    def test_json_round_trip(self, plan):
        assert SweepPlan.from_json(plan.to_json()) == plan

    def test_json_round_trip_hardware_axis(self):
        import repro.hw as hw

        p = Session().plan(Space.grid(
            n_ga=[1, 2], n_elems=[1 << 12],
            hardware=[None, hw.get("tpu_v4")]), chunk_size=4)
        p2 = SweepPlan.from_json(p.to_json())
        assert p2 == p
        # the rebuilt evaluator must score the hardware axis identically
        ids = np.arange(p.n, dtype=np.int64)
        a, b = p.evaluator()(ids), p2.evaluator()(ids)
        np.testing.assert_array_equal(a["t_exe"], b["t_exe"])

    def test_rebuilt_plan_scores_identically(self, plan, serial):
        clone = SweepPlan.from_json(plan.to_json())
        reducers = default_reducers()
        clone.run(reducers)
        _assert_matches_serial(reducers, serial)

    def test_plan_matches_session_sweep(self, plan, serial):
        rep = Session().sweep(Space.grid(**GRID), chunk_size=8)
        assert rep.stats["t_exe_sum"] == _stats(serial).summary()["t_exe_sum"]
        np.testing.assert_array_equal(
            np.sort(np.asarray(rep.point_ids)[rep.pareto()]),
            np.sort(serial[0].ids))

    def test_run_range_requires_chunk_alignment(self, plan):
        with pytest.raises(ValueError, match="chunk"):
            plan.run_range(3, plan.n, default_reducers())
        with pytest.raises(ValueError, match="chunk"):
            plan.run_range(0, 9, default_reducers())

    def test_bad_backend_rejected(self, plan):
        with pytest.raises(ValueError, match="backend"):
            SweepPlan(lists=dict(plan.lists), backend="cuda")


class TestMergeInvariance:
    """Folding any partition of id ranges and merging in any tree order is
    equivalent to the serial fold (satellite: property tests)."""

    @pytest.mark.parametrize("backend", ["numpy-batch", "scalar", "jax-jit"])
    def test_partition_and_merge_tree_seeded(self, backend):
        if backend == "jax-jit":
            pytest.importorskip("jax")
        plan = Session(backend=backend).plan(Space.grid(**GRID),
                                             chunk_size=8)
        ref = default_reducers()        # same-backend serial fold
        plan.run(ref)
        rng = np.random.default_rng(7)
        for trial in range(4 if backend == "numpy-batch" else 2):
            bounds = _random_bounds(rng, plan.n, plan.n_chunks,
                                    plan.chunk_size)
            parts = _fold_partition(plan, bounds)
            order = rng.permutation(len(parts))
            merged = _merge_tree(parts, order)
            _assert_matches_serial(merged, ref)

    def test_merge_preserves_topk_id_ties(self):
        """Equal values order by id whatever partition held them."""
        cols = {"id": np.arange(8, dtype=np.int64),
                "t_exe": np.zeros(8), "resource": np.zeros(8)}
        serial_r = TopKReducer(k=4)
        serial_r.update(cols)
        a, b = TopKReducer(k=4), TopKReducer(k=4)
        a.update({k: v[4:] for k, v in cols.items()})   # high ids first
        b.update({k: v[:4] for k, v in cols.items()})
        a.merge(b)
        np.testing.assert_array_equal(a.ids, serial_r.ids)
        np.testing.assert_array_equal(a.ids, np.arange(4))

    def test_merge_type_mismatch_rejected(self):
        with pytest.raises(TypeError):
            StatsReducer().merge(TopKReducer())
        with pytest.raises(ValueError):
            TopKReducer(k=3).merge(TopKReducer(k=5))
        with pytest.raises(ValueError):
            ParetoReducer().merge(ParetoReducer(objectives=("t_exe",)))

    def test_hypothesis_property(self, plan, serial):
        hypothesis = pytest.importorskip(
            "hypothesis", reason="hypothesis not installed")
        import hypothesis.strategies as st

        n_chunks, chunk = plan.n_chunks, plan.chunk_size

        @hypothesis.settings(max_examples=20, deadline=None)
        @hypothesis.given(
            cuts=st.lists(st.integers(1, n_chunks - 1), unique=True,
                          max_size=n_chunks - 1),
            seed=st.integers(0, 2**31 - 1))
        def prop(cuts, seed):
            bounds = [0] + sorted(int(c) * chunk for c in cuts) + [plan.n]
            parts = _fold_partition(plan, bounds)
            order = np.random.default_rng(seed).permutation(len(parts))
            _assert_matches_serial(_merge_tree(parts, order), serial)

        prop()


class TestDistributedExecutor:
    def test_processes_bit_equal_to_threads(self, serial):
        rep_t = Session().sweep(Space.grid(**GRID), chunk_size=8)
        rep_p = Session().sweep(Space.grid(**GRID), chunk_size=8,
                                executor="processes", workers=2)
        np.testing.assert_array_equal(rep_p.point_ids, rep_t.point_ids)
        np.testing.assert_array_equal(rep_p.front_idx, rep_t.front_idx)
        np.testing.assert_array_equal(rep_p.topk_idx, rep_t.topk_idx)
        assert rep_p.rows() == rep_t.rows()
        assert rep_p.stats["t_exe_sum"] == rep_t.stats["t_exe_sum"]
        assert rep_p.stats["t_exe_var"] == pytest.approx(
            rep_t.stats["t_exe_var"], rel=1e-12)
        assert rep_p.summary() == rep_t.summary()

    def test_killed_worker_reissued(self, plan, serial, tmp_path,
                                    monkeypatch):
        """A unit whose worker hard-exits mid-fold is re-issued and the
        merged result still matches the serial fold exactly."""
        marker = tmp_path / "killed"
        monkeypatch.setenv(dist._FAULT_ENV, f"1:kill:{marker}")
        reducers = default_reducers()
        out = dist.run_distributed(plan, reducers, workers=2, unit_chunks=2)
        assert marker.exists(), "fault never fired"
        _assert_matches_serial(out.reducers, serial)

    def test_straggling_worker_reissued(self, plan, serial, tmp_path,
                                        monkeypatch):
        """A hung worker trips the straggler timeout; the re-issued unit
        completes elsewhere (first result wins)."""
        marker = tmp_path / "hung"
        monkeypatch.setenv(dist._FAULT_ENV, f"1:hang:{marker}")
        reducers = default_reducers()
        out = dist.run_distributed(plan, reducers, workers=2, unit_chunks=2,
                                   straggler_timeout_s=1.0)
        assert marker.exists(), "fault never fired"
        _assert_matches_serial(out.reducers, serial)

    def test_custom_reducer_configuration_survives_transport(self, plan):
        """Workers rebuild reducers from state, so non-default k/objectives
        must round-trip through the task protocol."""
        reducers = (TopKReducer(k=3, key="resource"),)
        out = dist.run_distributed(plan, reducers, workers=1)
        ref = (TopKReducer(k=3, key="resource"), StatsReducer())
        plan.run(ref)
        np.testing.assert_array_equal(out.reducers[0].ids, ref[0].ids)


class TestExecutorErrorMatrix:
    """Every rejected backend × executor combination has a clear message."""

    def test_unknown_executor(self):
        with pytest.raises(ValueError, match="unknown executor 'mpi'"):
            Session().sweep(Space.grid(n_ga=[1]), executor="mpi")

    def test_workers_below_one(self):
        with pytest.raises(ValueError, match="workers must be >= 1"):
            Session().sweep(Space.grid(n_ga=[1]), workers=0)

    def test_threads_workers_on_jax_jit(self):
        with pytest.raises(ValueError, match="shards chunks across"):
            Session(backend="jax-jit").sweep(Space.grid(n_ga=[1]),
                                             workers=2)

    def test_threads_workers_on_scalar(self):
        with pytest.raises(ValueError, match="GIL-bound"):
            Session(backend="scalar").sweep(Space.grid(n_ga=[1]), workers=2)

    def test_processes_on_random_space(self):
        with pytest.raises(TypeError, match="grid space"):
            Session().sweep(Space.random(4, seed=0, n_ga=(1, 8)),
                            executor="processes")

    @pytest.mark.parametrize("backend", ["numpy-batch", "scalar", "jax-jit"])
    def test_processes_accepts_every_backend_plan(self, backend):
        """executor='processes' is legal on all three backends (the plan
        rebuilds each backend's evaluator in the worker)."""
        if backend == "jax-jit":
            pytest.importorskip("jax")
        plan = Session(backend=backend).plan(Space.grid(n_ga=[1, 2]),
                                             chunk_size=2)
        assert plan.backend == backend      # would raise in __post_init__


class TestEmptyGrids:
    def test_materialized_empty(self):
        rep = Session().sweep(Space.grid(n_ga=[], simd=[1, 2]))
        assert rep.n_points == 0 and rep.rows() == []
        assert rep.summary()["n_points"] == 0
        assert rep.summary()["t_exe_min_ms"] == float("inf")
        with pytest.raises(ValueError, match="empty"):
            rep.best()

    def test_streaming_empty(self):
        rep = Session().sweep(Space.grid(n_ga=[], simd=[1, 2]),
                              chunk_size=4)
        assert rep.is_streaming and rep.n_points == 0
        assert rep.rows() == [] and len(rep.pareto()) == 0
        assert rep.top_k(5) == []
        assert rep.stats["t_exe_sum"] == 0.0

    def test_distributed_empty(self):
        rep = Session().sweep(Space.grid(n_ga=[], simd=[1, 2]),
                              executor="processes", workers=2)
        assert rep.n_points == 0 and rep.rows() == []

    def test_empty_plan_round_trips(self):
        p = Session().plan(Space.grid(n_ga=[], simd=[1]), chunk_size=4)
        assert p.n == 0 and p.n_chunks == 0
        assert SweepPlan.from_json(p.to_json()) == p


class TestServerSweep:
    def test_cached_and_bit_equal(self):
        sess = Session()
        with sess.serve() as srv:
            rep = srv.sweep(Space.grid(**GRID), chunk_size=8)
            again = srv.sweep(Space.grid(**GRID), chunk_size=8)
            assert again is rep                     # content-hash cache hit
            ref = sess.sweep(Space.grid(**GRID), chunk_size=8)
            assert rep.rows() == ref.rows()
            assert rep.summary() == ref.summary()

    def test_custom_reducers_bypass_cache(self):
        with Session().serve() as srv:
            a = srv.sweep(Space.grid(n_ga=[1, 2]), chunk_size=2,
                          reducers=[TopKReducer(k=1)])
            b = srv.sweep(Space.grid(n_ga=[1, 2]), chunk_size=2,
                          reducers=[TopKReducer(k=1)])
            assert a is not b

    def test_closed_server_rejects(self):
        srv = Session().serve()
        srv.close()
        with pytest.raises(repro.ServerClosed):
            srv.sweep(Space.grid(n_ga=[1]))
