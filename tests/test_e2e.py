"""End-to-end training/serving behaviour on a single device: loss goes down,
restart-resume is bit-compatible, serving generates, autotune ranks."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainConfig, build_step
from repro.launch.train import train_loop
from repro.optim import OptimizerConfig
from repro.runtime import PreemptionHandler


def _tcfg(steps=30):
    return TrainConfig(optimizer=OptimizerConfig(
        lr=5e-3, warmup_steps=2, total_steps=steps, weight_decay=0.0))


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    cfg = reduced_config(ARCHS["stablelm-3b"])
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 64, 4, "train")
    tcfg = _tcfg()
    built = build_step(cfg, shape, mesh, tcfg)
    data_cfg = DataConfig(seq_len=64, batch_size=4, seed=1)

    from repro.data.pipeline import SyntheticDataset
    from repro.models import transformer as TF
    from repro.optim import adamw_init
    ds = SyntheticDataset(cfg, data_cfg)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, tcfg.optimizer)
    losses = []
    for step in range(30):
        params, opt, m = built.fn(params, opt, ds.get_batch(0))  # fixed batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


@pytest.mark.slow
def test_resume_matches_uninterrupted(tmp_path):
    """Train 10 steps; vs train 5, 'crash', resume, train 5 — same loss."""
    cfg = reduced_config(ARCHS["xlstm-1.3b"])
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 32, 4, "train")
    tcfg = _tcfg(10)
    built = build_step(cfg, shape, mesh, tcfg)
    data_cfg = DataConfig(seq_len=32, batch_size=4, seed=3)

    d1 = str(tmp_path / "uninterrupted")
    m1 = train_loop(cfg, built, tcfg, steps=10, ckpt_dir=d1,
                    data_cfg=data_cfg, ckpt_every=100, log_every=100,
                    preemption=PreemptionHandler())

    d2 = str(tmp_path / "resumed")
    train_loop(cfg, built, tcfg, steps=5, ckpt_dir=d2, data_cfg=data_cfg,
               ckpt_every=100, log_every=100, preemption=PreemptionHandler())
    m2 = train_loop(cfg, built, tcfg, steps=10, ckpt_dir=d2,
                    data_cfg=data_cfg, ckpt_every=100, log_every=100,
                    preemption=PreemptionHandler())
    assert m2["final_step"] == 10
    assert m1["loss"] == pytest.approx(m2["loss"], rel=1e-4)


@pytest.mark.slow
def test_preemption_checkpoints_and_stops(tmp_path):
    cfg = reduced_config(ARCHS["stablelm-3b"])
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 32, 4, "train")
    tcfg = _tcfg(100)
    built = build_step(cfg, shape, mesh, tcfg)
    pre = PreemptionHandler()
    pre.trigger()  # preempt immediately after the first step
    out = train_loop(cfg, built, tcfg, steps=100,
                     ckpt_dir=str(tmp_path / "pre"),
                     data_cfg=DataConfig(seq_len=32, batch_size=4),
                     ckpt_every=1000, log_every=1000, preemption=pre)
    assert out["final_step"] == 1   # stopped at the first boundary
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(str(tmp_path / "pre")).latest_step() == 1


@pytest.mark.slow
def test_serve_generates_tokens():
    from repro.launch.serve import BatchedServer, Request
    cfg = dataclasses.replace(reduced_config(ARCHS["qwen2-7b"]),
                              dtype="float32")
    mesh = make_host_mesh()
    server = BatchedServer(cfg, mesh, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).tolist(),
                    max_new=6) for i in range(4)]
    server.run(reqs)
    for r in reqs:
        assert len(r.generated) == 6
        assert all(0 <= t < cfg.padded_vocab for t in r.generated)
    # timing-honesty regression: prompt-feeding steps are bucketed apart
    # from token-producing steps, and every generated token is accounted
    m = server.metrics
    assert m["new_tokens"] == sum(len(r.generated) for r in reqs)
    assert m["prefill_steps"] > 0 and m["prefill_s"] > 0.0
    assert m["decode_steps"] > 0 and m["decode_s"] > 0.0


def test_serve_metrics_exclude_prefill_from_decode_window():
    """run() buckets pure-prefill steps out of the decode clock — the
    tokens/sec denominator no longer includes steps that emit nothing.
    (Accounting-only: step() is stubbed, no model or device work.)"""
    import time as _time

    from repro.launch.serve import BatchedServer

    server = object.__new__(BatchedServer)       # skip heavy __init__
    server.pending, server.active = [], {0: None}  # one live slot
    server.metrics = {"prefill_s": 0.0, "decode_s": 0.0,
                      "prefill_steps": 0, "decode_steps": 0, "new_tokens": 0}
    script = [0, 0, 0, 2, 2, 1]                  # 3 prefill, then 5 tokens
    state = {"i": 0}

    def fake_step():
        _time.sleep(1e-3)
        n = script[state["i"]]
        state["i"] += 1
        if state["i"] == len(script):
            server.active.clear()
        else:
            server.active[0] = None              # keep the loop going
        return n

    server.step = fake_step
    server.submit = lambda r: None
    server.run([])
    m = server.metrics
    assert (m["prefill_steps"], m["decode_steps"]) == (3, 3)
    assert m["new_tokens"] == 5
    assert m["prefill_s"] > 0.0 and m["decode_s"] > 0.0
    # the honest rate beats the wholesale one exactly because prefill
    # time left the denominator
    wholesale = m["new_tokens"] / (m["prefill_s"] + m["decode_s"])
    assert m["new_tokens"] / m["decode_s"] > wholesale


@pytest.mark.slow
def test_autotune_ranks_candidates():
    from repro import Session
    from repro.core.autotune import Candidate
    cfg = reduced_config(ARCHS["stablelm-3b"])
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 32, 4, "train")
    cands = [Candidate("baseline", {}, {}),
             Candidate("no-remat", {"remat": False}, {})]
    results = Session().autotune(cfg, shape, mesh, cands)
    assert len(results) == 2
    assert results[0].t_step <= results[1].t_step
    for r in results:
        assert r.prediction.flops > 0
        assert r.prediction.hbm_bytes > 0
