"""Property-based cross-validation: closed-form model vs the event-driven
DRAM simulator oracle (the board substitute — DESIGN.md S5)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')")
import hypothesis.strategies as st  # noqa: E402

from repro.core import DDR4_1866, DDR4_2666, Lsu, LsuType  # noqa: E402
from repro.core.model import _estimate as estimate  # noqa: E402 — scalar ref
from repro.core.apps import microbench  # noqa: E402
from repro.core.dramsim import simulate  # noqa: E402

pytestmark = pytest.mark.slow

settings = hypothesis.settings(max_examples=30, deadline=None)


@settings
@hypothesis.given(
    n_ga=st.integers(1, 4),
    simd=st.sampled_from([1, 4, 8, 16]),
    log_n=st.integers(14, 20),
    dram=st.sampled_from(["DDR4-1866", "DDR4-2666"]),
)
def test_aligned_model_matches_sim(n_ga, simd, log_n, dram):
    """Burst-coalesced aligned: paper's own error envelope is <10%; we allow
    15% against the independent oracle."""
    from repro.core import DRAM_CONFIGS
    d = DRAM_CONFIGS[dram]
    lsus = microbench(LsuType.BC_ALIGNED, n_ga=n_ga, simd=simd,
                      n_elems=1 << log_n)
    t_model = estimate(lsus, d).t_exe
    t_sim = simulate(lsus, d).t_total
    assert t_model == pytest.approx(t_sim, rel=0.15)


@settings
@hypothesis.given(
    delta=st.integers(1, 4),
    n_ga=st.integers(1, 3),
    log_n=st.integers(14, 18),
)
def test_aligned_strided_model_matches_sim(delta, n_ga, log_n):
    lsus = microbench(LsuType.BC_ALIGNED, n_ga=n_ga, simd=16,
                      n_elems=1 << log_n, delta=delta)
    t_model = estimate(lsus, DDR4_1866).t_exe
    t_sim = simulate(lsus, DDR4_1866).t_total
    assert t_model == pytest.approx(t_sim, rel=0.2)


@settings
@hypothesis.given(
    n_ga=st.integers(1, 3),
    log_n=st.integers(10, 14),
    const=st.booleans(),
)
def test_atomic_model_matches_sim(n_ga, log_n, const):
    """Atomic-pipelined: paper's error is 16% (unaccounted ~5ns/op); we allow
    20% against the oracle."""
    lsus = microbench(LsuType.ATOMIC_PIPELINED, n_ga=n_ga,
                      n_elems=1 << log_n, val_constant=False)
    t_model = estimate(lsus, DDR4_1866).t_exe
    t_sim = simulate(lsus, DDR4_1866).t_total
    assert t_model == pytest.approx(t_sim, rel=0.2)


@settings
@hypothesis.given(
    log_n=st.integers(12, 16),
    span_kb=st.sampled_from([8, 64, 1024]),
)
def test_ack_ordering_vs_sim(log_n, span_kb):
    """Write-ACK is the paper's weakest class (27.9% error); we assert the
    oracle and the model agree on ordering and within a loose factor."""
    lsus = microbench(LsuType.BC_WRITE_ACK, n_ga=1, n_elems=1 << log_n,
                      span_bytes=span_kb << 10)
    ali = microbench(LsuType.BC_ALIGNED, n_ga=1, n_elems=1 << log_n)
    t_model = estimate(lsus, DDR4_1866).t_exe
    t_sim = simulate(lsus, DDR4_1866).t_total
    t_ali = estimate(ali, DDR4_1866).t_exe
    assert t_model > t_ali and t_sim > t_ali
    assert t_model == pytest.approx(t_sim, rel=3.0)


# ---- invariants -----------------------------------------------------------

@settings
@hypothesis.given(
    log_n=st.integers(12, 20),
    simd=st.sampled_from([1, 2, 4, 8, 16]),
    delta=st.integers(1, 6),
)
def test_monotone_in_size_and_stride(log_n, simd, delta):
    base = microbench(LsuType.BC_ALIGNED, n_ga=2, simd=simd,
                      n_elems=1 << log_n, delta=delta)
    bigger = microbench(LsuType.BC_ALIGNED, n_ga=2, simd=simd,
                        n_elems=1 << (log_n + 1), delta=delta)
    wider = microbench(LsuType.BC_ALIGNED, n_ga=2, simd=simd,
                       n_elems=1 << log_n, delta=delta + 1)
    t = estimate(base, DDR4_1866).t_exe
    assert estimate(bigger, DDR4_1866).t_exe > t
    assert estimate(wider, DDR4_1866).t_exe > t


@settings
@hypothesis.given(log_n=st.integers(12, 20), n_ga=st.integers(1, 4))
def test_faster_dram_is_faster(log_n, n_ga):
    lsus = microbench(LsuType.BC_ALIGNED, n_ga=n_ga, n_elems=1 << log_n)
    assert (estimate(lsus, DDR4_2666).t_exe
            < estimate(lsus, DDR4_1866).t_exe)


@settings
@hypothesis.given(log_n=st.integers(12, 18))
def test_t_exe_at_least_t_ideal(log_n):
    for t in (LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
              LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED):
        lsus = microbench(t, n_ga=2, n_elems=1 << log_n)
        est = estimate(lsus, DDR4_1866)
        assert est.t_exe >= est.t_ideal > 0
