"""Make the src/ layout importable when the package is not pip-installed.

``pip install -e .`` (what CI does) makes ``repro`` importable on its own;
this fallback lets ``python -m pytest`` work from a raw checkout too,
without a manual ``PYTHONPATH=src``.  This is the *only* bootstrap in the
repo: benchmark/example entry points assume an installed package or
``PYTHONPATH=src`` instead of carrying per-file copies of this block.
"""
import pathlib
import sys

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "src"))
