"""Benchmark driver — one function per paper table/figure plus the TPU
roofline harness and the design-space sweep engine.  Prints
``name,us_per_call,derived`` CSV summary rows (the harness contract)
followed by the detailed per-table CSVs.

Usage:
    python -m benchmarks.run [--details] [--roofline-only] [--hw <name>]
    python -m benchmarks.run --smoke --out json         # fast CI job

``--smoke`` runs only the fast, simulator-free subset (paper Table IV,
Fig. 5 stride, a reduced design-space sweep, the 1M-point streaming
sweep whose per-backend points/sec + peak RSS feed the CI perf gate,
the 10M-point device-vs-host streaming sweep (jax-jit pipeline against
the numpy-batch fold, agreement-gated),
the distributed-sweep scaling bench at 1/2/4 process workers,
the 32-client serving-latency bench whose p50/p99 feed the CI latency
gate, and the whole-model ``model_e2e`` bench — transformer train +
decode steps composed through ``Session.estimate_model`` on two hardware
presets, agreement- and wall-time-gated) and,
with ``--out``, writes the full results as a JSON artifact for CI upload.  ``--out json``
resolves to ``BENCH_smoke.json`` at the repository root — the recorded
perf-trajectory artifact CI uploads.  ``--hw <name>`` re-runs everything
against a ``repro.hw`` registry spec (e.g. ``stratix10_ddr4_2666``,
``tpu_v5e``).
"""
from __future__ import annotations

import argparse
import csv
import io
import json
import pathlib
import sys
import time

# `pip install -e .` or the root conftest.py make `repro` importable; the
# per-entry-file src/ bootstrap this file used to carry is gone.  Run from
# an installed checkout or with PYTHONPATH=src.


def _csv(rows: list[dict]) -> str:
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    return buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--details", action="store_true",
                    help="print full per-table CSVs")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--roofline-only", action="store_true")
    mode.add_argument("--smoke", action="store_true",
                      help="fast subset: model-only tables + reduced sweep")
    ap.add_argument("--out", type=str, default=None,
                    help="write results as JSON to this path; the literal "
                         "value 'json' resolves to BENCH_smoke.json (or "
                         "BENCH_full.json) at the repository root")
    ap.add_argument("--hw", type=str, default=None, metavar="NAME",
                    help="evaluate against a repro.hw registry spec "
                         "(e.g. stratix10_ddr4_2666, tpu_v5e)")
    args = ap.parse_args()

    from benchmarks import paper_tables as PT
    from benchmarks import sweep_bench as SB

    session = None
    if args.hw:
        import repro.hw as hwreg
        from repro import Session

        session = Session().with_hardware(hwreg.get(args.hw))
        PT.set_session(session)

    summary: list[tuple[str, float, str]] = []
    details: dict[str, list[dict]] = {}

    if args.smoke:
        tables = {k: PT.ALL[k] for k in ("table4_applications", "fig5_stride")
                  if k in PT.ALL}
        sweep_fn = lambda: SB.sweep_speedup(SB.SMOKE_AXES,  # noqa: E731
                                            session=session)
    else:
        tables = {} if args.roofline_only else dict(PT.ALL)
        sweep_fn = lambda: SB.sweep_speedup(session=session)  # noqa: E731

    for name, fn in tables.items():
        rows, us = PT.timed(fn)
        details[name] = rows
        summary.append((name, us, _derive(name, rows)))

    if not args.roofline_only:
        rows, us = PT.timed(sweep_fn)
        details["sweep"] = rows
        summary.append(("sweep", us, _derive("sweep", rows)))

        # 1M-point streaming sweep: points/sec + peak RSS per backend vs the
        # materialize-everything baseline (the perf-gate entry CI watches).
        rows, us = PT.timed(lambda: SB.stream_bench(session=session))
        details["stream_1m"] = rows
        summary.append(("stream_1m", us, _derive("stream_1m", rows)))

        # 10M-point streaming sweep: the device-resident jax-jit pipeline
        # vs the numpy-batch host fold at a scale too large to materialize
        # (device==host agreement + per-backend points/sec feed the gate).
        rows, us = PT.timed(lambda: SB.stream10_bench(session=session))
        details["stream_10m"] = rows
        summary.append(("stream_10m", us, _derive("stream_10m", rows)))

        # distributed streaming sweep: the same 1M-point grid through the
        # coordinator/worker process pool at 1/2/4 workers (points/sec +
        # agreement with the single-process fold — the scaling-gate entry).
        rows, us = PT.timed(lambda: SB.stream_dist(session=session))
        details["stream_dist"] = rows
        summary.append(("stream_dist", us, _derive("stream_dist", rows)))

        # gradient-based search vs the exhaustive grid: Session.optimize
        # must bit-match the 1M-point optimum and recover the Pareto front
        # while evaluating <1% of the points (the optimize-gate entry).
        rows, us = PT.timed(lambda: SB.optimize_1m(session=session))
        details["optimize_1m"] = rows
        summary.append(("optimize_1m", us, _derive("optimize_1m", rows)))

        # serving layer: 32 concurrent clients against Session.serve() —
        # hot (cache-warm interactive) p50/p99 latency vs the single-request
        # baseline, plus cold micro-batched throughput (the latency-gate
        # entry CI watches).
        from benchmarks import serve_bench as SVB
        rows, us = PT.timed(lambda: SVB.serve_bench(session=session))
        details["serve_smoke"] = rows
        summary.append(("serve_smoke", us, _derive("serve_smoke", rows)))

        # whole-model estimation: transformer train + decode steps composed
        # through Session.estimate_model on two hardware presets; the
        # composed-total == summed-parts agreement plus a wall-time ratchet
        # feed the model gate.
        from benchmarks import model_bench as MB
        rows, us = PT.timed(lambda: MB.model_e2e(session=session))
        details["model_e2e"] = rows
        summary.append(("model_e2e", us, _derive("model_e2e", rows)))

    if not args.smoke:
        # roofline (reads dry-run artifacts if present)
        try:
            from benchmarks import roofline as RL
            t0 = time.perf_counter()
            cells = RL.load_cells(hw=session.hw if session else None)
            us = (time.perf_counter() - t0) / max(1, len(cells)) * 1e6
            if cells:
                import statistics
                ufl = [c.useful_flops_ratio for c in cells
                       if c.shape == "train_4k" and c.mesh == "16x16"]
                coll = sum(1 for c in cells if c.dominant == "collective")
                derived = (f"cells={len(cells)} "
                           f"train_useful_flops_median={statistics.median(ufl):.2f} "
                           f"collective_dominant={coll}")
            else:
                derived = "no dry-run artifacts yet"
            summary.append(("roofline", us, derived))
            details["roofline"] = [c.as_row() for c in cells]
        except Exception as e:  # noqa: BLE001
            summary.append(("roofline", 0.0, f"error: {e}"))

    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.1f},{derived}")

    if args.details:
        for name, rows in details.items():
            print(f"\n== {name} ==")
            sys.stdout.write(_csv(rows))

    if args.out:
        payload = {
            "hw": args.hw or "default",
            "summary": [{"name": n, "us_per_call": round(u, 1), "derived": d}
                        for n, u, d in summary],
            "details": details,
        }
        if args.out == "json":
            # canonical perf-trajectory artifact at the repository root
            root = pathlib.Path(__file__).resolve().parents[1]
            out = root / ("BENCH_smoke.json" if args.smoke
                          else "BENCH_full.json")
        else:
            out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(payload, indent=2, default=str))
        print(f"wrote {out}")


def _derive(name: str, rows: list[dict]) -> str:
    if name == "table4_applications":
        errs = [r["err_pct"] for r in rows]
        return (f"max_err={max(errs):.1f}% mean_err={sum(errs)/len(errs):.1f}% "
                f"(paper: 9.2%/7.6%)")
    if name == "table5_comparison":
        ours = max(r["err_ours_pct"] for r in rows)
        wang = max(r["err_wang_pct"] for r in rows)
        hls = max(r["err_hlscope_pct"] for r in rows)
        return f"max_err ours={ours}% wang={wang}% hlscope={hls}%"
    if name == "fig4_lsu_microbench":
        errs = [r["err_vs_sim_pct"] for r in rows if r["memory_bound"]]
        return f"mean_err_vs_sim={sum(errs)/max(1,len(errs)):.1f}% (mem-bound only)"
    if name == "fig5_stride":
        bca = {r["delta"]: r["t_norm"] for r in rows if r["lsu"] == "bca"}
        return f"bca_linear_delta4={bca.get(4)} (expect ~4.0)"
    if name == "fig3_membound":
        mb = sum(1 for r in rows if r["memory_bound"])
        return f"membound_points={mb}/{len(rows)}"
    if name == "sweep":
        r = rows[0]
        return (f"points={r['n_points']} speedup={r['speedup']}x "
                f"agree={r['agree_rtol_1e6']} pareto={r['pareto_points']}")
    if name == "stream_1m":
        parts = [f"{r['backend']}={r['points_per_sec']:,.0f}pps/"
                 f"{r['peak_rss_mb']:.0f}MB" for r in rows]
        agree = all(r["agree_1e6"] for r in rows)
        return f"points={rows[0]['n_points']} {' '.join(parts)} agree={agree}"
    if name == "stream_10m":
        parts = [f"{r['backend']}={r['points_per_sec']:,.0f}pps/"
                 f"{r['peak_rss_mb']:.0f}MB" for r in rows]
        agree = all(r["agree_device_host"] for r in rows)
        dev = next((r for r in rows if r["backend"] == "jax-jit"), None)
        su = f" device_speedup={dev['speedup_vs_host']}x" if dev else ""
        return (f"points={rows[0]['n_points']} {' '.join(parts)}"
                f"{su} agree_device_host={agree}")
    if name == "stream_dist":
        parts = [f"w{r['workers']}={r['points_per_sec']:,.0f}pps"
                 f"(x{r['speedup_vs_1worker']})" for r in rows]
        agree = all(r["agree"] for r in rows)
        return (f"points={rows[0]['n_points']} {' '.join(parts)} "
                f"agree={agree} cpus={rows[0]['cpus']}")
    if name == "optimize_1m":
        r = rows[0]
        return (f"points={r['n_points']} evals={r['n_evals']} "
                f"({100 * r['evals_fraction']:.2f}%) "
                f"matched_optimum={r['matched_optimum']} "
                f"front_recall={r['front_recall']} "
                f"speedup_vs_full_grid={r['speedup_vs_full_grid']}x")
    if name == "serve_smoke":
        by = {r["scenario"]: r for r in rows}
        single, hot, cold = by["single"], by["serve_hot"], by["serve_cold"]
        return (f"clients={hot['clients']} "
                f"hot_p50={hot['p50_us']:.0f}us "
                f"hot_p99={hot['p99_us']:.0f}us "
                f"({hot['x_single']:.2f}x single {single['p50_us']:.0f}us, "
                f"budget {hot['p99_budget']:.0f}x) "
                f"hot={hot['qps']:,.0f}qps hit={hot['cache_hit_rate']:.2f} "
                f"cold={cold['qps']:,.0f}qps "
                f"mean_batch={cold['mean_batch']:.1f}")
    if name == "model_e2e":
        total = next(r for r in rows if r["hardware"] == "total")
        parts = [f"{r['hardware']}/{r['phase']}={r['t_total_ms']}ms"
                 for r in rows if r["hardware"] != "total"]
        return (f"agree={total['agree']} wall={total['wall_s']}s "
                f"{' '.join(parts)}")
    if name == "table6_kernel_validation":
        errs = [r["err_pct"] for r in rows if isinstance(r["err_pct"], float)]
        fails = len(rows) - len(errs)
        return (f"kernels={len(errs)} max_err={max(errs, default=0):.1f}% "
                f"failures={fails} (measured vs Eqs. 1-10, calibrated)")
    return f"rows={len(rows)}"


if __name__ == "__main__":
    main()
