"""Benchmark driver — one function per paper table/figure plus the TPU
roofline harness.  Prints ``name,us_per_call,derived`` CSV summary rows (the
harness contract) followed by the detailed per-table CSVs.

Usage:  PYTHONPATH=src python -m benchmarks.run [--details] [--roofline-only]
"""
from __future__ import annotations

import argparse
import csv
import io
import sys


def _csv(rows: list[dict]) -> str:
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    return buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--details", action="store_true",
                    help="print full per-table CSVs")
    ap.add_argument("--roofline-only", action="store_true")
    args = ap.parse_args()

    from benchmarks import paper_tables as PT

    summary: list[tuple[str, float, str]] = []
    details: dict[str, list[dict]] = {}

    if not args.roofline_only:
        for name, fn in PT.ALL.items():
            rows, us = PT.timed(fn)
            details[name] = rows
            derived = _derive(name, rows)
            summary.append((name, us, derived))

    # roofline (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline as RL
        import time
        t0 = time.perf_counter()
        cells = RL.load_cells()
        us = (time.perf_counter() - t0) / max(1, len(cells)) * 1e6
        if cells:
            import statistics
            ufl = [c.useful_flops_ratio for c in cells
                   if c.shape == "train_4k" and c.mesh == "16x16"]
            coll = sum(1 for c in cells if c.dominant == "collective")
            derived = (f"cells={len(cells)} "
                       f"train_useful_flops_median={statistics.median(ufl):.2f} "
                       f"collective_dominant={coll}")
        else:
            derived = "no dry-run artifacts yet"
        summary.append(("roofline", us, derived))
        details["roofline"] = [c.as_row() for c in cells]
    except Exception as e:  # noqa: BLE001
        summary.append(("roofline", 0.0, f"error: {e}"))

    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.1f},{derived}")

    if args.details:
        for name, rows in details.items():
            print(f"\n== {name} ==")
            sys.stdout.write(_csv(rows))


def _derive(name: str, rows: list[dict]) -> str:
    if name == "table4_applications":
        errs = [r["err_pct"] for r in rows]
        return (f"max_err={max(errs):.1f}% mean_err={sum(errs)/len(errs):.1f}% "
                f"(paper: 9.2%/7.6%)")
    if name == "table5_comparison":
        ours = max(r["err_ours_pct"] for r in rows)
        wang = max(r["err_wang_pct"] for r in rows)
        hls = max(r["err_hlscope_pct"] for r in rows)
        return f"max_err ours={ours}% wang={wang}% hlscope={hls}%"
    if name == "fig4_lsu_microbench":
        errs = [r["err_vs_sim_pct"] for r in rows if r["memory_bound"]]
        return f"mean_err_vs_sim={sum(errs)/max(1,len(errs)):.1f}% (mem-bound only)"
    if name == "fig5_stride":
        bca = {r["delta"]: r["t_norm"] for r in rows if r["lsu"] == "bca"}
        return f"bca_linear_delta4={bca.get(4)} (expect ~4.0)"
    if name == "fig3_membound":
        mb = sum(1 for r in rows if r["memory_bound"])
        return f"membound_points={mb}/{len(rows)}"
    return f"rows={len(rows)}"


if __name__ == "__main__":
    main()
