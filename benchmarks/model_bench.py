"""model_e2e smoke bench: whole-model estimation on two hardware presets.

Walks the shipped transformer config (reduced to a 2-layer smoke shape so
CPU lowering stays fast), composes train-step and decode-step estimates
through ``Session.estimate_model`` on two ``repro.hw`` presets, and
re-sums the per-op estimates through individual ``Session.estimate``
calls — the ``agree`` column is the composed-total == summed-parts
invariant the CI gate enforces unconditionally.  ``wall_s`` on the
``total`` row (lower + compile + walk + compose for everything) feeds the
>30% wall-time ratchet.
"""
from __future__ import annotations

import time

#: Two presets with genuinely different memory systems: the paper's FPGA
#: board and the TPU transplant.
HARDWARE = ("stratix10_ddr4_1866", "tpu_v5e")
PHASES = ("train", "decode")


def model_e2e(session=None) -> list[dict]:
    import repro
    from repro import hw as hwreg
    from repro.configs import ARCHS, reduced_config
    from repro.workload import steps

    cfg = reduced_config(ARCHS[sorted(ARCHS)[0]], layers_scale=2)
    t0 = time.perf_counter()
    # Lower + walk once; the per-preset sessions re-score the same records.
    texts = {p: steps.phase_hlo(cfg, p, batch=2, seq_len=64)
             for p in PHASES}

    rows: list[dict] = []
    for hw_name in HARDWARE:
        sess = (session or repro.Session()).with_hardware(
            hwreg.get(hw_name))
        rep = sess.estimate_model(texts, name=cfg.name)
        for phase in rep.phases:
            parts = sum(sess.estimate(op.design).t_exe
                        for op in phase.ops)
            agree = abs(phase.t_total - parts) <= 1e-6 * max(parts, 1e-30)
            rows.append({
                "hardware": hw_name,
                "phase": phase.name,
                "model": cfg.name,
                "t_total_ms": round(phase.t_total * 1e3, 6),
                "n_ops": phase.n_ops,
                "n_scored": len(phase.ops),
                "bytes_mb": round(phase.total_bytes / 1e6, 3),
                "flops_m": round(phase.flops / 1e6, 3),
                "bottleneck": phase.bottleneck,
                "memory_bound_share": round(
                    sum(op.t_exe for op in phase.ops
                        if op.estimate.memory_bound)
                    / phase.t_total if phase.t_total else 0.0, 3),
                "agree": bool(agree),
            })
    rows.append({
        "hardware": "total", "phase": "all", "model": cfg.name,
        "wall_s": round(time.perf_counter() - t0, 3),
        "agree": all(r["agree"] for r in rows),
    })
    return rows
