"""One function per paper table/figure (SIV-V).

Every function returns a list of CSV-ready row dicts and is independently
runnable; ``benchmarks.run`` drives them all and prints the
``name,us_per_call,derived`` summary rows the harness contract requires.

All scoring routes through the unified ``Design``/``Session`` API; the
paper tables use the scalar backend (the readable per-LSU reference path,
whose breakdown fields the tables print).
"""
from __future__ import annotations

import time

from repro import Design, Session
from repro.core import DDR4_1866, DDR4_2666, LsuType
from repro.core.apps import APPS, table4_rows
from repro.core.baselines import hlscope_estimate, wang_estimate
from repro.core.dramsim import simulate
from repro.core.model import pipeline_time

#: Paper Table III hardware, scalar reference backend.
_SESSION = Session(dram=DDR4_1866, backend="scalar")


def set_session(sess: Session) -> None:
    """Point every table at a different evaluation context (scalar backend
    enforced — the tables print its per-LSU breakdown).  Used by
    ``benchmarks.run --hw <name>`` to re-run the tables against a registry
    hardware spec."""
    global _SESSION
    _SESSION = sess.with_backend("scalar")


def _simulate_session(lsus) -> "object":
    """Simulator run against the session hardware, including the spec's
    controller interleave when a ``repro.hw`` spec is active."""
    interleave = (_SESSION.hardware.dram.interleave_bytes
                  if _SESSION.hardware is not None else 1024)
    return simulate(lsus, _SESSION.dram, interleave_bytes=interleave)


def fig3_membound() -> list[dict]:
    """Fig. 3: execution time vs kernel frequency — memory-bound kernels are
    frequency-insensitive; compute-bound ones scale with f_kernel."""
    rows = []
    for n_lsu in (1, 2, 4):
        for simd in (1, 4, 16):
            est = _SESSION.estimate(Design.microbench(
                LsuType.BC_ALIGNED, n_ga=n_lsu, simd=simd,
                n_elems=1 << 20, include_write=False).with_f(1))
            for f_kernel in (150e6, 300e6, 450e6):
                t_pipe = pipeline_time((1 << 20) // simd, f=1,
                                       f_kernel=f_kernel)
                t = max(est.t_exe, t_pipe) if not est.memory_bound else est.t_exe
                rows.append({
                    "n_lsu": n_lsu, "simd": simd,
                    "f_kernel_mhz": f_kernel / 1e6,
                    "memory_bound": est.memory_bound,
                    "t_ms": round(t * 1e3, 4),
                })
    return rows


def fig4_lsu_microbench() -> list[dict]:
    """Fig. 4: measured(sim) vs estimated time per LSU type x SIMD x #ga."""
    rows = []
    cases = [
        (LsuType.BC_ALIGNED, "bca"),
        (LsuType.BC_NON_ALIGNED, "bcna"),
        (LsuType.BC_WRITE_ACK, "ack"),
        (LsuType.ATOMIC_PIPELINED, "atomic"),
    ]
    for lsu_type, tag in cases:
        for simd in (1, 4, 16):
            for n_ga in (1, 2, 4):
                n = 1 << (14 if lsu_type is LsuType.ATOMIC_PIPELINED else 18)
                design = Design.microbench(lsu_type, n_ga=n_ga, simd=simd,
                                           n_elems=n).with_f(1)
                est = _SESSION.estimate(design)
                sim = _simulate_session(list(design.lsus))
                err = (abs(est.t_exe - sim.t_total) / sim.t_total * 100
                       if sim.t_total else 0.0)
                rows.append({
                    "lsu": tag, "simd": simd, "n_ga": n_ga,
                    "memory_bound": est.memory_bound,
                    "t_ideal_ms": round(est.t_ideal * 1e3, 4),
                    "t_ovh_ms": round(est.t_ovh * 1e3, 4),
                    "t_est_ms": round(est.t_exe * 1e3, 4),
                    "t_sim_ms": round(sim.t_total * 1e3, 4),
                    "err_vs_sim_pct": round(err, 1),
                })
    return rows


def fig5_stride() -> list[dict]:
    """Fig. 5: normalized time vs stride delta (aligned: linear; non-aligned:
    the max_th knee at delta=7)."""
    rows = []
    for lsu_type, tag in ((LsuType.BC_ALIGNED, "bca"),
                          (LsuType.BC_NON_ALIGNED, "bcna")):
        base = None
        for delta in range(1, 9):
            if lsu_type is LsuType.BC_ALIGNED and delta == 5:
                # paper: delta=5 cannot be compiled aligned (page alignment)
                continue
            t = _SESSION.estimate(Design.microbench(
                lsu_type, n_ga=3, simd=16, n_elems=1 << 18,
                delta=delta).with_f(1)).t_exe
            if base is None:
                base = t
            rows.append({"lsu": tag, "delta": delta,
                         "t_norm": round(t / base, 3)})
    return rows


def table4_applications() -> list[dict]:
    """Table IV: the nine memory-bound applications + VectorAdd delta=2."""
    return table4_rows(_SESSION.dram, _SESSION.bsp)


def table5_comparison() -> list[dict]:
    """Table V: this work vs Wang [6] vs HLScope+ [7] at two DRAM speeds.
    Ground truth = the event-driven simulator (board substitute); the
    paper's own reported errors are attached for reference."""
    paper_errors = {
        ("DDR4-1866", "bca_1"): (17.3, 12.7, 5.6),
        ("DDR4-1866", "bca_4"): (0.3, 10.6, 4.4),
        ("DDR4-1866", "ack_2"): (8049.9, 63.2, 27.9),
        ("DDR4-1866", "vectoradd"): (19.3, 21.0, 5.1),
        ("DDR4-2666", "bca_1"): (69.6, 57.8, 4.7),
        ("DDR4-2666", "bca_4"): (37.8, 19.6, 5.8),
        ("DDR4-2666", "ack_2"): (11279.4, 47.6, 8.8),
        ("DDR4-2666", "vectoradd"): (67.9, 63.3, 1.0),
    }
    cases = {
        "bca_1": Design.microbench(LsuType.BC_ALIGNED, n_ga=1,
                                   n_elems=1 << 18, include_write=False),
        "bca_4": Design.microbench(LsuType.BC_ALIGNED, n_ga=4,
                                   n_elems=1 << 18),
        "ack_2": Design.microbench(LsuType.BC_WRITE_ACK, n_ga=1,
                                   n_elems=1 << 14),
        "vectoradd": Design(lsus=tuple(APPS["vectoradd"].lsus(1 << 20)),
                            name="vectoradd"),
    }
    rows = []
    for dram in (DDR4_1866, DDR4_2666):
        for tag, design in cases.items():
            design = design.with_dram(dram).with_f(1)
            lsus = list(design.lsus)
            t_meas = simulate(lsus, dram).t_total
            t_ours = _SESSION.estimate(design).t_exe
            t_wang = wang_estimate(lsus, dram)
            t_hls = hlscope_estimate(lsus, dram)
            perr = paper_errors.get((dram.name, tag), (None, None, None))
            rows.append({
                "dram": dram.name, "bench": tag,
                "err_wang_pct": round(abs(t_wang - t_meas) / t_meas * 100, 1),
                "err_hlscope_pct": round(abs(t_hls - t_meas) / t_meas * 100, 1),
                "err_ours_pct": round(abs(t_ours - t_meas) / t_meas * 100, 1),
                "paper_wang": perr[0], "paper_hlscope": perr[1],
                "paper_ours": perr[2],
            })
    return rows


def table6_kernel_validation() -> list[dict]:
    """Beyond-paper Table VI: measured vs predicted time per Pallas kernel.

    The paper's Table IV/V error-table shape applied to this repo's own
    kernels: bandwidth + host-factor calibration on the stream anchor, then
    per-kernel |measured - predicted| errors (`repro.core.validate`).  Runs
    in interpret mode on CPU, compiled on accelerators; jax is imported
    lazily so the numpy-only tables stay jax-free, and a jax-less install
    gets a placeholder row instead of a crashed benchmark run.
    """
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"kernel": "(all)", "backend": "-", "interpret": "-",
                 "measured_ms": "-", "predicted_ms": "-", "bytes_mb": "-",
                 "flops_m": "-", "memory_bound": "-",
                 "err_pct": "error: jax not installed"}]

    rep = _SESSION.validate()
    rows = rep.rows()
    for f in rep.failures:
        rows.append({"kernel": f["kernel"], "backend": "-", "interpret": "-",
                     "measured_ms": "-", "predicted_ms": "-", "bytes_mb": "-",
                     "flops_m": "-", "memory_bound": "-",
                     "err_pct": f"error: {f['error']}"})
    return rows


ALL = {
    "fig3_membound": fig3_membound,
    "fig4_lsu_microbench": fig4_lsu_microbench,
    "fig5_stride": fig5_stride,
    "table4_applications": table4_applications,
    "table5_comparison": table5_comparison,
    "table6_kernel_validation": table6_kernel_validation,
}


def timed(fn) -> tuple[list[dict], float]:
    t0 = time.perf_counter()
    rows = fn()
    dt = time.perf_counter() - t0
    return rows, dt / max(1, len(rows)) * 1e6
