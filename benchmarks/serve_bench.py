"""Serving-layer latency benchmark: N concurrent clients vs one Server.

The serving layer's claim is that an interactive advisor can hammer one
``Session.serve()`` front door from many threads and see single-request
latency (cache steady state) or batched throughput (cold unique queries)
without giving up the bit-equal numbers of serial ``Session.estimate``.
This benchmark measures the claim three ways, client-side (submit ->
result, the latency a caller actually observes):

* ``single``     — serial ``Session.estimate`` on one thread: the baseline
  every serving number is judged against (and the in-run machine-speed
  control the CI gate uses to tell a slow runner from a regression).
* ``serve_hot``  — ``N_CLIENTS`` interactive threads replaying a shared
  design pool (advisor steady state, cache warm) with a short per-request
  think time, as an interactive client has: p50/p99/qps + hit rate, think
  time excluded from the latencies.  The acceptance invariant rides on
  this row: p99 must stay within ``HOT_P99_BUDGET`` x the single-request
  latency.
* ``serve_cold`` — every request a distinct design, result cache off, no
  think time: the micro-batcher's throughput (qps, mean batch) under
  closed-loop saturation.  (Under a saturating closed loop the *latency*
  of any single-interpreter server degenerates to clients x service time,
  so the latency budget is judged on the interactive row and this row is
  judged on throughput.)

Run:  python -m benchmarks.serve_bench   (or via benchmarks/run.py --smoke)
"""
from __future__ import annotations

import itertools
import threading
import time

import numpy as np

from repro import Design, Session
from repro.core.lsu import LsuType

N_CLIENTS = 32          #: acceptance floor: >= 32 concurrent clients
HOT_POOL = 64           #: distinct designs in the shared hot pool
HOT_PASSES = 4          #: passes each hot client makes over the pool
COLD_PER_CLIENT = 48    #: distinct designs per client in the cold run
HOT_P99_BUDGET = 5.0    #: hot p99 must stay within this x single latency
HOT_THINK_S = (0.5e-3, 2e-3)   #: per-request think time range, hot clients

_TYPES = [LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
          LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED]


def _pool(n: int, tag: str) -> list[Design]:
    combos = itertools.cycle(
        (t, g, s, d) for t in _TYPES for g in (1, 2, 3, 4)
        for s in (1, 4, 16) for d in (1, 3, 7))
    return [Design.microbench(t, n_ga=g, simd=s, delta=d,
                              n_elems=1 << (12 + i % 5),
                              name=f"{tag}-{i}")
            for i, (t, g, s, d) in zip(range(n), combos)]


def _pcts(lat_s: list[float]) -> dict:
    """p50/p99/mean in microseconds (same index convention as Server.stats)."""
    lat = sorted(lat_s)
    n = len(lat)
    pct = lambda q: lat[min(n - 1, int(q * (n - 1) + 0.999999))]  # noqa: E731
    return {"p50_us": pct(0.50) * 1e6, "p99_us": pct(0.99) * 1e6,
            "mean_us": sum(lat) / n * 1e6}


def _hammer(estimate, worklists: list[list[Design]], *,
            think_s: tuple[float, float] | None = None,
            ) -> tuple[list[float], float]:
    """One client thread per worklist; returns per-request latencies + wall.

    ``think_s`` adds a seeded uniform pause between a client's requests
    (the interactive profile); the pause is outside the timed region.
    """
    lats: list[list[float]] = [[] for _ in worklists]
    start = threading.Barrier(len(worklists))

    def client(i: int) -> None:
        rng = np.random.default_rng(i)
        start.wait()
        for d in worklists[i]:
            t0 = time.perf_counter()
            estimate(d)
            lats[i].append(time.perf_counter() - t0)
            if think_s is not None:
                time.sleep(rng.uniform(*think_s))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(len(worklists))]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return [x for per in lats for x in per], dt


def serve_bench(session: Session | None = None, *,
                n_clients: int = N_CLIENTS) -> list[dict]:
    sess = session if session is not None else Session()
    rows: list[dict] = []

    # -- single: the serial baseline + machine-speed control ----------------
    pool = _pool(HOT_POOL, "hot")
    for d in pool:                               # warm any lazy state
        sess.estimate(d)
    lat = []
    for d in pool * 2:
        t0 = time.perf_counter()
        sess.estimate(d)
        lat.append(time.perf_counter() - t0)
    single = {"scenario": "single", "clients": 1, "requests": len(lat),
              **_pcts(lat), "qps": len(lat) / sum(lat)}
    rows.append(single)

    # -- serve_hot: shared pool, cache warm (advisor steady state) ----------
    with sess.serve(max_batch=64, max_wait_ms=0.5) as srv:
        for d in pool:                           # one miss per design
            srv.estimate(d)
        work = [[pool[(i * 7 + k) % len(pool)]   # per-client phase shift
                 for k in range(HOT_PASSES * len(pool))]
                for i in range(n_clients)]
        lat, dt = _hammer(srv.estimate, work, think_s=HOT_THINK_S)
        st = srv.stats()
    hot = {"scenario": "serve_hot", "clients": n_clients,
           "requests": len(lat), **_pcts(lat), "qps": len(lat) / dt,
           "cache_hit_rate": round(st["cache_hit_rate"], 4)}
    hot["x_single"] = hot["p99_us"] / single["p50_us"]
    hot["p99_budget"] = HOT_P99_BUDGET
    rows.append(hot)

    # -- serve_cold: all-unique designs, cache off (pure micro-batching) ----
    cold_work = [_pool(COLD_PER_CLIENT, f"cold-{i}") for i in range(n_clients)]
    with sess.serve(max_batch=n_clients, max_wait_ms=0.25,
                    cache_size=0) as srv:
        lat, dt = _hammer(srv.estimate, cold_work)
        st = srv.stats()
    rows.append({"scenario": "serve_cold", "clients": n_clients,
                 "requests": len(lat), **_pcts(lat), "qps": len(lat) / dt,
                 "mean_batch": round(st["mean_batch"], 2),
                 "batches": st["batches"]})
    return rows


def main() -> None:
    for r in serve_bench():
        print(r)


if __name__ == "__main__":
    main()
