"""Re-run the static HLO analysis over the archived dry-run modules
(results/dryrun/*.hlo.gz) and refresh the JSON records in place — the
offline half of the paper's workflow (new model, same early artifacts).

Usage:  PYTHONPATH=src python -m benchmarks.reanalyze [--tag TAG]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.core import hlo_counter as HC

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def reanalyze(path_json: str) -> dict | None:
    gz = path_json[:-5] + ".hlo.gz"
    if not os.path.exists(gz):
        return None
    with open(path_json) as f:
        record = json.load(f)
    if record.get("status") != "ok":
        return None
    with gzip.open(gz, "rt") as f:
        text = f.read()
    hc = HC.analyze(text)
    record.update({
        "hlo_flops_per_chip": hc.flops,
        "hlo_bytes_per_chip": hc.total_bytes,
        "bytes_by_class": dict(hc.bytes_by_class),
        "collective_operand_bytes": hc.collective_operand_bytes,
        "collective_wire_bytes": hc.collective_wire_bytes,
        "collective_by_kind": dict(hc.collective_by_kind),
        "n_collectives": hc.n_collectives,
        "warnings": hc.warnings[:10],
    })
    with open(path_json, "w") as f:
        json.dump(record, f, indent=1, default=float)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="*")
    args = ap.parse_args()
    n = 0
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                              args.pattern + ".json"))):
        r = reanalyze(path)
        if r:
            n += 1
            print(f"[reanalyzed] {os.path.basename(path)} "
                  f"flops={r['hlo_flops_per_chip']:.3g} "
                  f"bytes={r['hlo_bytes_per_chip']:.3g}", flush=True)
    print(f"done: {n} records")


if __name__ == "__main__":
    main()
