"""SRoofline harness: per (arch x shape x mesh) three-term roofline from the
dry-run artifacts in ``results/dryrun/`` (see repro.launch.dryrun).

Formulas (per-chip semantics; the SPMD module IS the per-chip program):

    compute term    = HLO_FLOPs / peak_FLOP/s            (197 TF/s bf16)
    memory term     = HLO_bytes / HBM_bw                 (819 GB/s)
    collective term = collective_bytes / (links x link_bw) (4 x 50 GB/s)

plus the refined memory term from the paper's access-class model, the
MODEL_FLOPS/HLO_FLOPs useful ratio, and the dominant bottleneck.
"""
from __future__ import annotations

import glob
import json
import os

from repro import hw as hwreg
from repro.core.roofline import RooflineCell, markdown_table
from repro.configs import get_config
from repro.configs.shapes import SHAPES

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_cells(pattern: str = "*", tag: str = "",
               hw=None) -> list[RooflineCell]:
    """Build roofline cells from dry-run artifacts.

    ``hw`` is the chip parameter set (a ``TpuParams`` view); default is the
    registry default chip.  ``benchmarks.run --hw <name>`` threads the
    selected spec through here.
    """
    if hw is None:
        hw = hwreg.get(hwreg.DEFAULT_CHIP).tpu_params()
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                              pattern + ".json"))):
        mesh_part = os.path.basename(path)[:-5].split("__")[-1]
        want = (f"16x16-{tag}", f"2x16x16-{tag}") if tag else \
            ("16x16", "2x16x16")
        if mesh_part not in want:
            continue
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        wire = r["collective_wire_bytes"]
        cfg = get_config(r["arch"])
        sh = SHAPES[r["shape"]]
        model_bytes = cfg.model_bytes(r.get("tokens_per_step", 0),
                                      kind=r.get("kind", "train"),
                                      batch=sh.global_batch,
                                      seq_len=sh.seq_len)
        cells.append(RooflineCell(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            chips=int(r["chips"]),
            flops_per_chip=r["hlo_flops_per_chip"],
            bytes_per_chip=r["hlo_bytes_per_chip"],
            collective_operand_bytes=r["collective_operand_bytes"],
            collective_wire_bytes=wire,
            n_collectives=r["n_collectives"],
            model_flops_global=r["model_flops_global"],
            model_bytes_global=model_bytes,
            t_compute=r["hlo_flops_per_chip"] / hw.peak_flops,
            t_memory_naive=r["hlo_bytes_per_chip"] / hw.hbm_bw,
            t_memory_refined=_refined_memory(r, hw),
            t_collective=(wire / (hw.ici_bw * hw.ici_links)
                          + r["n_collectives"] * hw.ici_hop_latency),
            extra={"mem_gb_per_chip":
                   (r.get("memory_analysis") or {}).get("total_bytes", 0) / 1e9,
                   "tokens_per_step": r.get("tokens_per_step"),
                   "kind": r.get("kind")},
            hw=hw,
        ))
    return cells


def _refined_memory(r: dict, hw) -> float:
    from repro.core.hbm import AccessClass, Traffic, memory_time
    comps = []
    for name, b in (r.get("bytes_by_class") or {}).items():
        cls = {"stream": AccessClass.STREAM, "strided": AccessClass.STRIDED,
               "gather": AccessClass.GATHER}.get(name, AccessClass.STREAM)
        comps.append(Traffic(cls, b, row_bytes=512.0, name=name))
    return memory_time(comps, hw)


def status_rows() -> list[dict]:
    """All 40 cells incl. skipped, for the SDry-run status table."""
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        if base.split("__")[-1] not in ("16x16", "2x16x16"):
            continue  # tagged variant
        with open(path) as f:
            r = json.load(f)
        rows.append({k: r.get(k) for k in
                     ("arch", "shape", "mesh", "status", "reason",
                      "compile_s")}
                    | {"mem_gb": (r.get("memory_analysis") or {}).get(
                        "total_bytes", 0) / 1e9})
    return rows


def main() -> None:
    cells = load_cells()
    print(markdown_table(cells))


if __name__ == "__main__":
    main()
