"""Design-space sweep benchmark: batched vs scalar scoring of Eqs. 1-10.

The paper's value proposition is exploration speed; this benchmark measures
it.  It scores the same >= 10k-point design space twice — once per point
through ``Session(backend="scalar")``, once through the batched
``Session.sweep`` — verifies element-wise agreement, and reports the
speedup plus the Pareto front of the space.

Run:  python -m benchmarks.sweep_bench  (or via benchmarks/run.py [--smoke])
"""
from __future__ import annotations

import time

import numpy as np

from repro import Design, Session, Space
from repro.core import DDR4_1866, DDR4_2666, LsuType, STRATIX10_BSP
from repro.core.fpga import BspParams
from repro.core.sweep import SweepResult

#: >= 10k-point space over every GMI LSU type, LSU count, SIMD width, input
#: size, stride, write inclusion, DRAM part and BSP variant.
FULL_AXES = dict(
    lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
              LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED],
    n_ga=[1, 2, 3, 4, 5],
    simd=[1, 2, 4, 8, 16],
    n_elems=[1 << 12, 1 << 14, 1 << 16, 1 << 18],
    delta=[1, 2, 3, 5, 7],
    include_write=[False, True],
    dram=[DDR4_1866, DDR4_2666],
    bsp=[STRATIX10_BSP, BspParams(burst_cnt=5, max_th=64)],
)

SMOKE_AXES = dict(
    lsu_type=[LsuType.BC_ALIGNED, LsuType.BC_NON_ALIGNED,
              LsuType.BC_WRITE_ACK, LsuType.ATOMIC_PIPELINED],
    n_ga=[1, 2, 4],
    simd=[1, 4, 16],
    n_elems=[1 << 14, 1 << 18],
    delta=[1, 2, 7],
    dram=[DDR4_1866, DDR4_2666],
)


def scalar_loop(res: SweepResult, session: Session | None = None) -> np.ndarray:
    """Score every point of ``res``'s design space with the scalar path."""
    P = res.points
    out = np.empty(res.n_points)
    sess = (session or Session()).with_backend("scalar")
    for i in range(res.n_points):
        design = Design.microbench(
            P["lsu_type"][i],
            n_ga=int(P["n_ga"][i]),
            simd=int(P["simd"][i]),
            n_elems=int(P["n_elems"][i]),
            delta=int(P["delta"][i]),
            elem_bytes=int(P["elem_bytes"][i]),
            include_write=bool(P["include_write"][i]),
            val_constant=bool(P["val_constant"][i]),
            dram=P["dram"][i], bsp=P["bsp"][i],
        )
        out[i] = sess.estimate(design).t_exe
    return out


def sweep_speedup(axes: dict | None = None, *,
                  session: Session | None = None) -> list[dict]:
    """One-row summary: points, batched/scalar wall time, speedup, fidelity.

    ``session`` selects the hardware context (e.g. built from a ``--hw``
    registry name); the default board otherwise.  A session carrying a
    hardware spec pins the memory system, so the explicit dram/bsp axes are
    dropped in its favor.
    """
    sess = (session or Session()).with_backend("numpy-batch")
    axes = dict(axes or FULL_AXES)
    if sess.hardware is not None:
        axes.pop("dram", None)
        axes.pop("bsp", None)
    space = Space.grid(**axes)
    t0 = time.perf_counter()
    res = sess.sweep(space)
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    scalar = scalar_loop(res, session)
    t_scalar = time.perf_counter() - t0

    agree = bool(np.allclose(scalar, res.t_exe, rtol=1e-6, atol=0.0))
    max_rel = float(np.max(np.abs(scalar - res.t_exe)
                           / np.maximum(np.abs(scalar), 1e-300)))
    front = res.pareto()
    return [{
        "n_points": res.n_points,
        "batched_ms": round(t_batch * 1e3, 3),
        "scalar_ms": round(t_scalar * 1e3, 3),
        "speedup": round(t_scalar / t_batch, 1),
        "agree_rtol_1e6": agree,
        "max_rel_err": f"{max_rel:.2e}",
        "pareto_points": int(len(front)),
        "memory_bound_points": int(res.memory_bound.sum()),
    }]


def main() -> None:
    rows = sweep_speedup()
    for row in rows:
        print(", ".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
